"""Docs checker: run ``python`` code fences, verify intra-repo links.

Usage::

    PYTHONPATH=src:. python tools/check_docs.py [files...]

Default file set: ``docs/*.md`` + ``README.md``. Two checks:

* **links** — every relative markdown link (``[x](path)``, optionally
  with a ``#fragment``) must resolve to an existing file/directory,
  relative to the page. External (``http``/``mailto``) and pure-anchor
  links are skipped.
* **snippets** — all ``python`` code fences of a page are concatenated
  in order and executed in ONE fresh subprocess (cwd = repo root,
  ``PYTHONPATH=src:.``), so a page reads top-to-bottom as a script and
  may set ``XLA_FLAGS`` before its first jax import. ``text``/``bash``
  fences are never executed.

Exit code 0 iff everything passes; per-page results on stdout. CI runs
this as the docs job, and ``tests/test_docs.py`` runs it in tier-1.
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\n(.*?)^```", re.S | re.M)
SNIPPET_TIMEOUT = 600


def default_files() -> list[pathlib.Path]:
    return sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]


def check_links(path: pathlib.Path) -> list[str]:
    errors = []
    for m in LINK_RE.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).resolve().exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link "
                          f"-> {target}")
    return errors


def python_blocks(path: pathlib.Path) -> str:
    return "\n\n".join(code for lang, code in
                       FENCE_RE.findall(path.read_text())
                       if lang == "python")


def run_snippets(path: pathlib.Path) -> list[str]:
    code = python_blocks(path)
    if not code.strip():
        return []
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:." + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run([sys.executable, "-c", code], cwd=ROOT, env=env,
                       capture_output=True, text=True,
                       timeout=SNIPPET_TIMEOUT)
    if r.returncode != 0:
        tail = (r.stdout + r.stderr)[-2000:]
        return [f"{path.relative_to(ROOT)}: snippet execution failed:\n"
                f"{tail}"]
    return []


def check(files=None, snippets: bool = True) -> list[str]:
    errors = []
    for path in files or default_files():
        path = pathlib.Path(path).resolve()
        errs = check_links(path)
        if snippets:
            errs += run_snippets(path)
        status = "FAIL" if errs else "ok"
        print(f"{status:4} {path.relative_to(ROOT)}", flush=True)
        errors += errs
    return errors


def main(argv: list[str]) -> int:
    files = [pathlib.Path(a) for a in argv] or None
    errors = check(files)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"{'FAILED' if errors else 'PASSED'} "
          f"({len(errors)} error(s))")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
