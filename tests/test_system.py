"""End-to-end behaviour tests for the paper's system claims."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alto, cpals, cpapr, encoding as E
from repro.sparse import synthetic, read_tns, write_tns
from repro.sparse.tensor import SparseTensor


def test_storage_always_leq_coo():
    """Paper §3.1: ALTO metadata compression ratio vs COO is always >= 1,
    across every synthetic regime (Fig. 12 behaviour)."""
    for name in synthetic.PAPER_LIKE:
        x = synthetic.paper_like(name)
        enc = E.make_encoding(x.dims)
        for wb in (8, 32, 64):
            assert enc.storage_bits_alto(wb) <= enc.storage_bits_coo(wb), \
                (name, wb)


def test_storage_beats_sfc():
    """Eq. 3: for irregular shapes ALTO is strictly smaller than a fractal
    space-filling curve encoding."""
    irregular = [(1600, 4200, 1600, 4200, 868_100),
                 (183, 24, 1024, 1664),
                 (23_300_000, 23_300_000, 166)]
    for dims in irregular:
        enc = E.make_encoding(dims)
        assert enc.total_bits < enc.storage_bits_sfc()


def test_format_generation_and_roundtrip():
    """COO -> ALTO -> COO preserves the tensor exactly."""
    x = synthetic.paper_like("uber_like")
    at = alto.build(x, n_partitions=8)
    back = alto.to_sparse(at)
    a = sorted(map(tuple, np.c_[x.coords, x.values].tolist()))
    b = sorted(map(tuple, np.c_[back.coords, back.values].tolist()))
    assert a == b


def test_tns_io_roundtrip(tmp_path):
    x = synthetic.uniform_tensor((10, 12, 8), 200, seed=1)
    p = os.path.join(tmp_path, "t.tns")
    write_tns(p, x)
    y = read_tns(p, dims=x.dims)
    np.testing.assert_array_equal(x.coords, y.coords)
    np.testing.assert_allclose(x.values, y.values, rtol=1e-6)


def test_end_to_end_cp_als_on_count_tensor():
    """The full pipeline on a paper-regime tensor: build format, decompose,
    fit improves and the result is usable."""
    x = synthetic.paper_like("uber_like")
    at = alto.build(x, n_partitions=8)
    res = cpals.cp_als(at, rank=8, n_iters=8, tol=0, seed=0)
    assert res.fits[-1] > res.fits[0]
    assert all(np.isfinite(np.asarray(f)).all() for f in res.factors)


def test_end_to_end_cp_apr_adaptive_policies():
    """CP-APR with the adaptive heuristics end-to-end on a skewed count
    tensor; the chosen policy must be recorded and the run must converge."""
    x, _ = synthetic.lowrank_count((40, 30, 20), rank=4, nnz_target=6000,
                                   seed=8)
    at = alto.build(x, n_partitions=8)
    r = cpapr.cp_apr(at, rank=4, seed=1, track_ll=True,
                     params=cpapr.CpaprParams(k_max=8))
    assert r.pi_policy in ("pre", "otf")
    assert set(r.traversals) <= {"recursive", "oriented", "oriented_carry"}
    assert r.log_likelihoods[-1] > r.log_likelihoods[0]


def test_dedup_and_padding_are_invisible():
    """Duplicate coords collapse; padding contributes nothing to MTTKRP."""
    coords = np.array([[1, 2, 3], [1, 2, 3], [0, 1, 2]], dtype=np.int32)
    vals = np.array([1.0, 2.0, 5.0], dtype=np.float32)
    x = SparseTensor((4, 4, 4), coords, vals).deduplicate()
    assert x.nnz == 2
    at = alto.build(x, n_partitions=4)        # forces padding (2 -> 4)
    from repro.core import mttkrp
    factors = [jnp.ones((4, 2)) for _ in range(3)]
    out = mttkrp.mttkrp_recursive(at, factors, 0)
    dense = x.todense()
    ref = mttkrp.dense_mttkrp_reference(dense, factors, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5)
