"""Multi-tenant serving: shape classes, batched sweeps, concurrency fixes.

Pins the acceptance conditions of the serving layer:

* shape-class bucketing is EXACT — a tenant decomposed through the
  batched vmapped executable matches its own solo `cp_als`/`cp_apr` run
  (bitwise against solo-on-the-padded-tensor at equal tiling; to tier-1
  tolerance against solo-on-the-raw-tensor);
* per-tenant convergence masking freezes a converged tenant while its
  bucket-mates keep sweeping;
* K tenants with distinct shapes but few shape classes cost one ingest
  trace and one batched-sweep trace PER CLASS, not per tenant (the PR 5
  trace counters prove it);
* the view cache survives a threaded stress (N threads x M tensors)
  with exactly one build per distinct (tensor, mode) key;
* degenerate tenants (empty, singleton) admit and return well-defined
  results;
* a warm plan store dispatches a known class with zero timing runs.

Runs on the hermetic `tests/proptest.py` harness (no hypothesis in the
offline image).
"""
import threading

import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.core import alto, batched, cpals, cpapr, shapeclass
from repro.core import plan as plan_mod
from repro.core import views as views_mod
from repro.kernels import ops
from repro.launch.serve_cpd import CpdService
from repro.sparse.synthetic import uniform_tensor
from repro.sparse.tensor import SparseTensor


RANK = 4


def _empty_tensor(dims):
    return SparseTensor(tuple(dims), np.zeros((0, len(dims)), np.int32),
                        np.zeros((0,), np.float32))


def _class_members(x, sc, plan):
    """pad -> device ingest -> canonical meta -> cached views."""
    xp = shapeclass.pad_to_class(x, sc)
    at = alto.build_device(xp, n_partitions=sc.n_partitions,
                           compute_reuse=False)
    at = shapeclass.canonicalize_tensor(at, sc)
    return at, plan_mod.build_views(at, plan)


# ---------------------------------------------------------------------------
# Shape classes
# ---------------------------------------------------------------------------

def test_classify_collapses_shapes():
    """Distinct dims/nnz in the same pow2 envelope share one class."""
    xs = [uniform_tensor((9, 7, 5), 90, seed=1),
          uniform_tensor((12, 6, 8), 100, seed=2),
          uniform_tensor((16, 8, 8), 128, seed=3)]
    scs = {shapeclass.classify(x, RANK) for x in xs}
    assert len(scs) == 1
    (sc,) = scs
    assert sc.dims == (16, 8, 8) and sc.nnz == 128
    assert all(sc.admits(x) for x in xs)


@settings(max_examples=20, deadline=None)
@given(dims=st.lists(st.integers(1, 40), min_size=2, max_size=4),
       nnz=st.integers(0, 200), seed=st.integers(0, 2**31 - 1))
def test_pad_to_class_preserves_content(dims, nnz, seed):
    """Padding adds only zero-valued elements inside the class envelope,
    and the padded stream length always equals the class nnz (a whole
    number of balanced partitions)."""
    x = (uniform_tensor(tuple(dims), nnz, seed=seed) if nnz
         else _empty_tensor(dims))
    m = x.nnz                       # generators deduplicate: m <= nnz
    sc = shapeclass.classify(x, RANK)
    xp = shapeclass.pad_to_class(x, sc)
    assert xp.nnz == sc.nnz and xp.dims == sc.dims
    assert sc.nnz % sc.n_partitions == 0
    np.testing.assert_array_equal(np.asarray(xp.coords)[:m],
                                  np.asarray(x.coords))
    np.testing.assert_array_equal(np.asarray(xp.values)[:m],
                                  np.asarray(x.values))
    assert not np.asarray(xp.values)[m:].any()
    # canonical meta is a pure function of the class: no data leaks in
    meta = shapeclass.canonical_meta(sc)
    assert meta.nnz == sc.nnz and meta.dims == sc.dims
    assert meta.fiber_reuse == (1.0,) * len(sc.dims)


# ---------------------------------------------------------------------------
# Bucketed vs individual parity
# ---------------------------------------------------------------------------

def test_bucketed_bitwise_matches_solo_on_padded():
    """At equal tiling — solo `cp_als` run on the SAME class-padded
    tensor, class plan, and embedded init — the batched path is the
    identical computation and the factors match bitwise."""
    xs = [uniform_tensor((9, 7, 5), 90, seed=1),
          uniform_tensor((12, 6, 8), 100, seed=2)]
    sc = shapeclass.classify(xs[0], RANK)
    plan = plan_mod.make_class_plan(sc, backend="reference")
    ats, views, rdims, inits = [], [], [], []
    for i, x in enumerate(xs):
        at, vs = _class_members(x, sc, plan)
        ats.append(at)
        views.append(vs)
        rdims.append(x.dims)
        inits.append(cpals.init_factors(x.dims, RANK, seed=i))
    res = batched.batched_cp_als(ats, views, rdims, RANK, plan=plan,
                                 n_iters=4, tol=0.0, init_factors=inits,
                                 capacity=len(xs))
    for i, x in enumerate(xs):
        solo = cpals.cp_als(
            ats[i], RANK, n_iters=4, tol=0.0, plan=plan, views=views[i],
            factors=batched.embed_factors(inits[i], sc.dims))
        for n, (A, B) in enumerate(zip(res.results[i].factors,
                                       solo.factors)):
            np.testing.assert_array_equal(
                np.asarray(A), np.asarray(B)[:x.dims[n]],
                err_msg=f"tenant {i} mode {n} not bitwise equal")
        np.testing.assert_array_equal(np.asarray(res.results[i].lam),
                                      np.asarray(solo.lam))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       nnz_a=st.integers(40, 128), nnz_b=st.integers(40, 128))
def test_tenant_matches_individual_cp_als(seed, nnz_a, nnz_b):
    """Against each tenant's OWN solo run on the raw (unpadded) tensor
    with its own meta: the embedded-zero-rows argument says the batched
    trajectory is the solo trajectory, up to traversal reordering."""
    xs = [uniform_tensor((9, 7, 5), nnz_a, seed=seed),
          uniform_tensor((12, 6, 8), nnz_b, seed=seed + 1)]
    sc = shapeclass.ShapeClass(dims=(16, 8, 8), nnz=128, n_partitions=8,
                               rank=RANK)
    assert all(sc.admits(x) for x in xs)
    plan = plan_mod.make_class_plan(sc, backend="reference")
    ats, views, rdims = [], [], []
    for x in xs:
        at, vs = _class_members(x, sc, plan)
        ats.append(at)
        views.append(vs)
        rdims.append(x.dims)
    res = batched.batched_cp_als(ats, views, rdims, RANK, plan=plan,
                                 n_iters=4, tol=0.0, capacity=4)
    for i, x in enumerate(xs):
        solo = cpals.cp_als(alto.build(x), RANK, n_iters=4, tol=0.0,
                            seed=0)
        for A, B in zip(res.results[i].factors, solo.factors):
            np.testing.assert_allclose(np.asarray(A), np.asarray(B),
                                       rtol=2e-4, atol=2e-5)
        assert res.results[i].fits[-1] == pytest.approx(
            solo.fits[-1], abs=1e-6)


def test_tenant_matches_individual_cp_apr():
    xs = [uniform_tensor((9, 7, 5), 90, seed=5, count_data=True),
          uniform_tensor((16, 8, 8), 128, seed=6, count_data=True)]
    sc = shapeclass.classify(xs[0], RANK)
    plan = plan_mod.make_class_plan(sc, backend="reference")
    ats, views, rdims = [], [], []
    for x in xs:
        at, vs = _class_members(x, sc, plan)
        ats.append(at)
        views.append(vs)
        rdims.append(x.dims)
    p = cpapr.CpaprParams(k_max=4)
    res = batched.batched_cp_apr(ats, views, rdims, RANK, plan=plan,
                                 params=p, capacity=3)
    for i, x in enumerate(xs):
        solo = cpapr.cp_apr(alto.build(x), RANK, params=p, seed=0)
        for A, B in zip(res.results[i].factors, solo.factors):
            np.testing.assert_allclose(np.asarray(A), np.asarray(B),
                                       rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(res.results[i].lam),
                                   np.asarray(solo.lam), rtol=2e-4)


# ---------------------------------------------------------------------------
# Per-tenant convergence masking
# ---------------------------------------------------------------------------

def test_convergence_masking_freezes_converged_tenant():
    """A rank-1 tenant converges in a couple of sweeps; its bucket-mate
    needs many more. The frozen tenant's result must equal its solo
    early-stopped run — if masking leaked, the extra sweeps the mate
    forces would keep mutating the converged factors."""
    rng = np.random.default_rng(0)
    # Exactly representable rank-1 tensor: converges almost immediately.
    u, v, w = (rng.random(9) + 0.5, rng.random(7) + 0.5,
               rng.random(5) + 0.5)
    dense = np.einsum("i,j,k->ijk", u, v, w).astype(np.float32)
    mask = rng.random(dense.shape) < 0.4
    coords = np.argwhere(mask).astype(np.int32)[:100]
    easy = SparseTensor((9, 7, 5), coords,
                        dense[tuple(coords.T)].astype(np.float32))
    hard = uniform_tensor((12, 6, 8), 128, seed=7)
    sc = shapeclass.ShapeClass(dims=(16, 8, 8), nnz=128, n_partitions=8,
                               rank=1)
    plan = plan_mod.make_class_plan(sc, backend="reference")
    ats, views, rdims = [], [], []
    for x in (easy, hard):
        at, vs = _class_members(x, sc, plan)
        ats.append(at)
        views.append(vs)
        rdims.append(x.dims)
    tol = 1e-4
    res = batched.batched_cp_als(ats, views, rdims, 1, plan=plan,
                                 n_iters=20, tol=tol, capacity=2)
    easy_r, hard_r = res.results
    assert easy_r.n_iters < hard_r.n_iters, (
        "easy tenant should converge first")
    assert res.n_sweeps == hard_r.n_iters
    solo = cpals.cp_als(alto.build(easy), 1, n_iters=20, tol=tol, seed=0)
    assert easy_r.n_iters == solo.n_iters
    for A, B in zip(easy_r.factors, solo.factors):
        np.testing.assert_allclose(np.asarray(A), np.asarray(B),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# Threaded view-cache stress (the per-key build-latch fix)
# ---------------------------------------------------------------------------

def test_view_cache_threaded_stress():
    """N threads hammer M tensors x all modes concurrently: every thread
    gets the right view, and builds == distinct keys (one build per key,
    no duplicated O(nnz) work, no lost inserts)."""
    n_threads, n_tensors = 8, 6
    xs = [uniform_tensor((8, 6, 4), 64, seed=100 + i)
          for i in range(n_tensors)]
    ats = [alto.build(x) for x in xs]
    n_modes = 3
    views_mod.cache_clear()
    base = views_mod.cache_stats()
    assert base["builds"] == 0
    results: dict[int, list] = {}
    errors: list[BaseException] = []
    start = threading.Barrier(n_threads)

    def worker(tid):
        rng = np.random.default_rng(tid)
        try:
            start.wait()
            got = []
            order = [(i, m) for i in range(n_tensors)
                     for m in range(n_modes)]
            rng.shuffle(order)
            for i, m in order:
                got.append((i, m, views_mod.get_view(ats[i], m)))
            results[tid] = got
        except BaseException as e:  # noqa: BLE001 — surfaced below
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    stats = views_mod.cache_stats()
    n_keys = n_tensors * n_modes
    assert stats["builds"] == n_keys, stats
    assert stats["hits"] == n_threads * n_keys - n_keys, stats
    # Every thread saw the one cached object per key.
    canon = {(i, m): views_mod.get_view(ats[i], m)
             for i in range(n_tensors) for m in range(n_modes)}
    for got in results.values():
        for i, m, view in got:
            assert view is canon[(i, m)]


def test_ops_timing_counter_threaded():
    """`ops.median_time` bumps its proof-of-measurement counter under a
    lock now; concurrent timings must not lose increments."""
    before = ops.timing_runs()
    n_threads, per_thread = 8, 5

    def worker():
        for _ in range(per_thread):
            ops.median_time(lambda: np.add(1, 1), warmup=0, iters=1)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ops.timing_runs() - before == n_threads * per_thread


# ---------------------------------------------------------------------------
# Degenerate tenants
# ---------------------------------------------------------------------------

def test_pad_sorted_stream_empty():
    """The padding rule's empty-stream branch: no crash, zero rows."""
    import jax.numpy as jnp
    rows = jnp.zeros((0,), jnp.int32)
    words = jnp.zeros((0, 2), jnp.uint32)
    values = jnp.zeros((0,), jnp.float32)
    r, w, v, pi = ops.pad_sorted_stream(rows, words, values, mult=8)
    assert r.shape == (8,) and w.shape == (8, 2) and v.shape == (8,)
    assert not np.asarray(v).any() and not np.asarray(r).any()


def test_empty_and_singleton_direct():
    """`cp_als`/`cp_apr` on empty and single-nonzero tensors return
    well-defined results instead of raising or NaN-ing."""
    empty = _empty_tensor((6, 5, 4))
    for build in (alto.build, alto.build_device):
        at = build(empty)
        r = cpals.cp_als(at, RANK, n_iters=5)
        assert r.fits == [1.0] and r.n_iters == 0
        assert all(not np.asarray(A).any() for A in r.factors)
        assert not np.asarray(r.lam).any()
        ra = cpapr.cp_apr(at, RANK, params=cpapr.CpaprParams(k_max=3))
        assert all(not np.asarray(A).any() for A in ra.factors)
        assert np.isfinite(np.asarray(ra.lam)).all()
    single = SparseTensor((6, 5, 4), np.array([[2, 3, 1]], np.int32),
                          np.array([2.5], np.float32))
    r = cpals.cp_als(alto.build(single), RANK, n_iters=10)
    assert np.isfinite(r.fits).all()
    assert r.fits[-1] == pytest.approx(1.0, abs=1e-3)


def test_degenerate_tenants_through_service():
    """admit -> bucket -> decompose for empty and singleton tenants."""
    svc = CpdService(RANK, capacity=4, n_iters=5, tune="off",
                     backend="reference")
    ids = [svc.submit(_empty_tensor((6, 5, 4))),
           svc.submit(SparseTensor((6, 5, 4),
                                   np.array([[1, 1, 1]], np.int32),
                                   np.array([3.0], np.float32))),
           svc.submit(uniform_tensor((6, 5, 4), 30, seed=9))]
    responses = {r.request_id: r for r in svc.process()}
    assert set(responses) == set(ids)
    r_empty = responses[ids[0]].result
    assert r_empty.fits[-1] == pytest.approx(1.0, abs=1e-6)
    assert all(not np.asarray(A).any() for A in r_empty.factors)
    assert [A.shape for A in r_empty.factors] == [(6, RANK), (5, RANK),
                                                  (4, RANK)]
    for rid in ids[1:]:
        res = responses[rid].result
        assert np.isfinite(np.asarray(res.fits)).all()
        assert all(np.isfinite(np.asarray(A)).all() for A in res.factors)


# ---------------------------------------------------------------------------
# Zero-warmup dispatch via the class-keyed plan store
# ---------------------------------------------------------------------------

def test_class_plan_key_is_tenant_independent():
    xs = [uniform_tensor((9, 7, 5), 90, seed=1),
          uniform_tensor((12, 6, 8), 100, seed=2)]
    keys = {shapeclass.classify(x, RANK) for x in xs}
    assert len(keys) == 1
    from repro.core import autotune
    (sc,) = keys
    assert (autotune.class_plan_key(sc, "reference")
            == autotune.class_plan_key(sc, "reference"))
    sc2 = shapeclass.ShapeClass(dims=sc.dims, nnz=sc.nnz * 2,
                                n_partitions=sc.n_partitions, rank=sc.rank)
    assert (autotune.class_plan_key(sc, "reference")
            != autotune.class_plan_key(sc2, "reference"))


def test_zero_warmup_second_service(tmp_path, monkeypatch):
    """A class tuned once dispatches measurement-free forever after: the
    second service instance (fresh process state modulo the on-disk
    store) serves the same class with ZERO additional timing runs."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
    xs = [uniform_tensor((9, 7, 5), 90, seed=i) for i in range(3)]

    svc1 = CpdService(RANK, capacity=4, n_iters=3, tune="auto",
                      backend="reference")
    for x in xs:
        svc1.submit(x)
    svc1.process()
    runs_after_first = ops.timing_runs()

    svc2 = CpdService(RANK, capacity=4, n_iters=3, tune="auto",
                      backend="reference")
    for x in xs:
        svc2.submit(x)
    out = svc2.process()
    assert len(out) == len(xs)
    assert ops.timing_runs() == runs_after_first, (
        "store hit must cost zero timing runs")


# ---------------------------------------------------------------------------
# End-to-end acceptance: K tenants, few classes, per-class trace bound
# ---------------------------------------------------------------------------

def test_acceptance_bucketed_serving():
    """K=9 tenants with distinct shapes collapse onto <= 3 shape classes;
    ingest-build and batched-sweep traces are bounded by the CLASS count,
    and every tenant matches its individual run to tier-1 tolerance."""
    specs = [((9, 7, 5), 90), ((12, 6, 8), 100), ((16, 8, 8), 128),
             ((20, 12, 9), 200), ((30, 14, 16), 250), ((32, 16, 16), 256),
             ((6, 8, 5), 60), ((8, 8, 8), 64), ((7, 5, 8), 55)]
    xs = [uniform_tensor(d, m, seed=20 + i)
          for i, (d, m) in enumerate(specs)]
    classes = {shapeclass.classify(x, RANK) for x in xs}
    assert len(xs) >= 8 and len(classes) <= 3

    ingest0 = alto.device_ingest_traces()
    sweep0 = batched.sweep_traces()
    svc = CpdService(RANK, capacity=4, n_iters=4, tol=0.0, tune="off",
                     backend="reference")
    ids = [svc.submit(x) for x in xs]
    responses = {r.request_id: r for r in svc.process()}
    assert set(responses) == set(ids)

    ingest1 = alto.device_ingest_traces()
    sweep1 = batched.sweep_traces()
    assert ingest1["build"] - ingest0["build"] <= len(classes)
    assert sweep1["als"] - sweep0["als"] <= len(classes)
    n_modes = 3
    assert ingest1["view"] - ingest0["view"] <= len(classes) * n_modes

    stats = svc.stats()
    assert stats["tenants_done"] == len(xs)
    assert stats["shape_classes"] == len(classes)
    assert stats["latency_p50_s"] <= stats["latency_p99_s"]

    for i, x in enumerate(xs):
        solo = cpals.cp_als(alto.build(x), RANK, n_iters=4, tol=0.0,
                            seed=0)
        got = responses[ids[i]].result
        assert [A.shape for A in got.factors] == [(I, RANK)
                                                  for I in x.dims]
        for A, B in zip(got.factors, solo.factors):
            np.testing.assert_allclose(np.asarray(A), np.asarray(B),
                                       rtol=2e-4, atol=2e-5)
