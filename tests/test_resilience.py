"""Resilient serving runtime: fault injection, health guards, self-healing.

Pins the PR 9 tentpole contract (`docs/resilience.md`): every failure
the runtime claims to survive has a named fault site (`core.faults`)
threaded through the real hot path, and arming it produces a structured
error or a degraded-but-finite result for the affected request ONLY —
no crash, no poisoned bucket-mates, no torn on-disk state:

* the fault registry is deterministic, env-configurable, and zero-cost
  disabled;
* spilled streams carry content checksums: corruption is a load-time
  `StreamIntegrityError`, the respill is crash-safe (old generation
  stays byte-identical), and `load_or_rebuild` is the rebuild rung;
* the per-sweep health guards roll a poisoned solve back to its last
  good iterate (solo and per-tenant in a bucket) and change NOTHING on
  finite inputs — guarded runs stay bitwise identical to unguarded;
* the service walks the recovery ladders: transient retry with backoff,
  plan degradation (OOM -> halve chunk_m, Pallas -> reference), stored
  plan eviction, bucket bisection -> solo -> quarantine; deadlines and
  the deadline-aware flush bound tail latency; the background worker
  loop survives a 16-thread submit/delta/shutdown stress.

Runs on the hermetic `tests/proptest.py` harness (no hypothesis in the
offline image).
"""
import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st

from repro.core import alto, autotune, batched, faults, health, ingest
from repro.core import cpals, cpapr, shapeclass
from repro.core import plan as plan_mod
from repro.core import stream as stream_mod
from repro.core import views as views_mod
from repro.kernels import ops
from repro.launch.serve_cpd import CpdService
from repro.sparse.synthetic import uniform_tensor

RANK = 3
DIMS = (9, 7, 5)


@pytest.fixture(autouse=True)
def _clean_faults():
    """Every test starts and ends with nothing armed (a leaked arm in
    one test must not fire in another) and fresh integrity counters."""
    faults.reset()
    stream_mod.integrity_stats_clear()
    yield
    faults.reset()


def _tensor(seed=0, dims=DIMS, nnz=80, count_data=False):
    return uniform_tensor(dims, nnz, seed=seed, count_data=count_data)


def _service(**kw):
    kw.setdefault("capacity", 2)
    kw.setdefault("n_iters", 4)
    kw.setdefault("tune", "off")
    kw.setdefault("retry_base_s", 1e-4)
    return CpdService(RANK, **kw)


# ---------------------------------------------------------------------------
# The fault registry
# ---------------------------------------------------------------------------

class TestFaultRegistry:

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            faults.arm("nope.such_site")
        with pytest.raises(ValueError, match="unknown fault site"):
            faults.configure("stream.chunk_io,typo.site:3")

    def test_deterministic_times(self):
        faults.arm("ingest.merge", times=2)
        for _ in range(2):
            with pytest.raises(faults.InjectedInterrupt):
                faults.inject("ingest.merge")
        faults.inject("ingest.merge")        # exhausted: no-op
        assert faults.fired()["ingest.merge"] == 2
        assert not faults.armed("ingest.merge")

    def test_zero_overhead_disabled(self):
        assert faults._ENABLED is False
        assert faults.fire("batched.nan") is None
        faults.inject("ops.chunk_oom")       # returns, does not raise

    def test_injected_scopes_the_arm(self):
        with faults.injected("stream.chunk_io", times=5):
            assert faults.armed("stream.chunk_io")
        assert not faults.armed("stream.chunk_io")
        assert faults._ENABLED is False

    def test_env_spec_parsing(self):
        faults.configure("stream.chunk_io:2, batched.nan")
        assert faults.armed("stream.chunk_io")
        assert faults.armed("batched.nan")
        faults.configure(None)
        assert faults._ENABLED is False

    def test_exception_classes_mimic_real_faults(self):
        assert faults.is_transient(faults.InjectedIOError("x"))
        assert faults.is_transient(
            faults.InjectedResourceExhausted("ops.chunk_oom"))
        assert not faults.is_transient(faults.InjectedDispatchError("x"))
        assert not faults.is_transient(faults.InjectedInterrupt("x"))
        assert isinstance(faults.InjectedCorruption("x"), ValueError)

    def test_after_skips_leading_hits(self):
        faults.arm("ingest.merge", times=1, after=2)
        faults.inject("ingest.merge")            # hit 1: let through
        faults.inject("ingest.merge")            # hit 2: let through
        with pytest.raises(faults.InjectedInterrupt):
            faults.inject("ingest.merge")        # hit 3: fires
        assert faults.fired()["ingest.merge"] == 1

    def test_data_rides_along(self):
        faults.arm("batched.nan", data={"tenant": 2, "value": 7.0})
        assert faults.fire("batched.nan") == {"tenant": 2, "value": 7.0}
        assert faults.fire("batched.nan") is None


# ---------------------------------------------------------------------------
# Stream integrity: checksums, crash-safe respill, rebuild rung
# ---------------------------------------------------------------------------

def _spilled(tmp_path, seed=0):
    at = alto.build(_tensor(seed=seed), n_partitions=2)
    hs = stream_mod.to_memmap(stream_mod.host_stream(at, 0), tmp_path)
    return at, hs


class TestStreamIntegrity:

    def test_checksum_roundtrip(self, tmp_path):
        at, hs = _spilled(tmp_path)
        assert hs.checksum is not None
        assert hs.checksum == stream_mod.stream_checksum(
            hs.rows, hs.words, hs.values)
        again = stream_mod.from_memmap(tmp_path, at.meta, 0)
        assert again.checksum == hs.checksum

    def test_corruption_detected_at_load(self, tmp_path):
        at, _ = _spilled(tmp_path)
        faults.arm("stream.checksum")
        with pytest.raises(stream_mod.StreamIntegrityError,
                           match="fails its checksum"):
            stream_mod.from_memmap(tmp_path, at.meta, 0)
        assert stream_mod.integrity_stats()["checksum_failures"] == 1

    def test_load_or_rebuild_recovers_corruption(self, tmp_path):
        at, hs = _spilled(tmp_path)
        faults.arm("stream.checksum")
        rebuilt = stream_mod.load_or_rebuild(tmp_path, at, 0)
        assert stream_mod.integrity_stats()["rebuilds"] == 1
        for a, b in ((rebuilt.rows, hs.rows), (rebuilt.words, hs.words),
                     (rebuilt.values, hs.values)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # the rebuilt spill verifies clean on the next load
        assert stream_mod.from_memmap(
            tmp_path, at.meta, 0).checksum == rebuilt.checksum

    def test_respill_crash_leaves_old_generation_intact(self, tmp_path):
        at, hs = _spilled(tmp_path)
        x2 = _tensor(seed=1, nnz=30)
        at2 = ingest.append_delta(at, x2.coords, x2.values)
        faults.arm("stream.respill")
        with pytest.raises(faults.InjectedInterrupt):
            stream_mod.append_stream(hs, at2)
        # crash between write and replace phases: the previous
        # generation still loads and verifies byte-identical
        old = stream_mod.from_memmap(tmp_path, at.meta, 0)
        assert old.checksum == hs.checksum
        assert np.array_equal(np.asarray(old.words), np.asarray(hs.words))
        # the retry completes and matches a from-scratch rebuild
        fresh = stream_mod.host_stream(at2, 0)
        redo = stream_mod.append_stream(hs, at2)
        assert np.array_equal(np.asarray(redo.words),
                              np.asarray(fresh.words))
        assert np.array_equal(np.asarray(redo.values),
                              np.asarray(fresh.values))

    def test_memmap_load_fault_is_transient(self, tmp_path):
        at, hs = _spilled(tmp_path)
        faults.arm("stream.memmap_load")
        with pytest.raises(OSError):
            stream_mod.from_memmap(tmp_path, at.meta, 0)
        # one retry later the same call succeeds — the definition of
        # transient the service's ladder relies on
        again = stream_mod.from_memmap(tmp_path, at.meta, 0)
        assert again.checksum == hs.checksum

@settings(max_examples=10)
@given(idx=st.integers(0, 10_000), seed=st.integers(0, 2**31 - 1))
def test_checksum_detects_any_value_flip(idx, seed):
    at = alto.build(_tensor(seed=seed, nnz=120), n_partitions=2)
    hs = stream_mod.host_stream(at, 0)
    ref = stream_mod.stream_checksum(hs.rows, hs.words, hs.values)
    values = np.array(hs.values, copy=True)
    i = idx % values.shape[0]
    values[i] = values[i] + 1.0 if np.isfinite(values[i]) else 0.0
    assert stream_mod.stream_checksum(hs.rows, hs.words, values) != ref


# ---------------------------------------------------------------------------
# Chunked-executor faults: OOM retry parity and plan degradation
# ---------------------------------------------------------------------------

class TestChunkFaults:

    def _chunked(self, hs_or_view, factors):
        return ops.mttkrp_oriented_chunked(hs_or_view, factors,
                                           chunk_m=16, block_m=8,
                                           r_block=RANK, interpret=True)

    def test_chunk_oom_retry_parity(self):
        at = alto.build(_tensor(seed=4, nnz=100), n_partitions=2)
        view = alto.oriented_view(at, 0)
        factors = cpals.init_factors(at.dims, RANK, seed=4)
        clean = self._chunked(view, factors)
        faults.arm("ops.chunk_oom")
        with pytest.raises(faults.InjectedResourceExhausted,
                           match="RESOURCE_EXHAUSTED"):
            self._chunked(view, factors)
        # allocator exhaustion is transient: the bare retry is bitwise
        retry = self._chunked(view, factors)
        assert jnp.array_equal(clean, retry)

    def test_degrade_plan_halves_chunks(self):
        at = alto.build(_tensor(seed=5, nnz=400, dims=(64, 9, 5)),
                        n_partitions=2)
        plan = plan_mod.make_plan(at.meta, RANK, device_bytes=1)
        assert plan.streaming is not None
        align = max(m.block_m for m in plan.modes)
        # give the plan halving headroom (a tiny budget may already sit
        # at the one-block minimum, where the rung correctly gives up)
        cm = 4 * align
        plan = dataclasses.replace(
            plan, streaming=dataclasses.replace(
                plan.streaming, chunk_m=cm,
                n_chunks=plan_mod.chunk_count(plan.meta, cm)))
        degraded, why = health.degrade_plan(
            plan, faults.InjectedResourceExhausted("ops.chunk_oom"))
        assert degraded is not None and "chunk_m" in why
        assert degraded.streaming.chunk_m < cm
        assert degraded.streaming.chunk_m % align == 0
        assert degraded.streaming.n_chunks == plan_mod.chunk_count(
            plan.meta, degraded.streaming.chunk_m)
        # repeatable until one aligned chunk remains, then out of rungs
        # (reference backend, in-core) -> (None, None)
        while degraded is not None:
            last = degraded
            degraded, _ = health.degrade_plan(
                last, faults.InjectedResourceExhausted("ops.chunk_oom"))
        assert last.streaming.chunk_m == align

    def test_degrade_plan_backend_rung_and_exhaustion(self):
        at = alto.build(_tensor(seed=6), n_partitions=2)
        plan = plan_mod.make_plan(at.meta, RANK, backend="pallas")
        soft, why = health.degrade_plan(
            plan, faults.InjectedDispatchError("kernel build failed"))
        assert soft.backend == "reference" and "reference" in why
        # the reference in-core plan has no softer rung
        out, why2 = health.degrade_plan(
            soft, faults.InjectedDispatchError("again"))
        assert out is None and why2 is None


# ---------------------------------------------------------------------------
# Health guards: solo rollback, bitwise no-op on finite inputs
# ---------------------------------------------------------------------------

class TestGuards:

    def test_guard_is_bitwise_noop_on_finite_inputs(self):
        x = _tensor(seed=7)
        at = alto.build(x, n_partitions=2)
        a = cpals.cp_als(at, RANK, n_iters=5, seed=7, guard=False)
        b = cpals.cp_als(at, RANK, n_iters=5, seed=7, guard=True)
        assert a.fits == b.fits
        assert all(jnp.array_equal(fa, fb)
                   for fa, fb in zip(a.factors, b.factors))
        assert jnp.array_equal(a.lam, b.lam)
        assert b.health.checks > 0 and b.health.violations == 0
        assert not b.health.rolled_back

    def test_nan_poison_rolls_back_to_last_good(self):
        x = _tensor(seed=8)
        at = alto.build(x, n_partitions=2)
        faults.arm("cpals.nan")
        bad = cpals.cp_als(at, RANK, n_iters=5, seed=8, guard=False)
        assert not all(bool(jnp.all(jnp.isfinite(A)))
                       for A in bad.factors), \
            "unguarded run must expose the hazard (poison propagates)"
        faults.arm("cpals.nan")
        good = cpals.cp_als(at, RANK, n_iters=5, seed=8, guard=True)
        assert good.health.rolled_back
        assert "non-finite" in good.health.reason
        assert all(bool(jnp.all(jnp.isfinite(A))) for A in good.factors)
        assert all(np.isfinite(f) for f in good.fits)

    def test_huge_finite_poison_trips_divergence_guard(self):
        # 1e30 is FINITE, so the all-finite check alone would pass it
        # through to the next sweep, whose float32 Grams overflow and
        # whose SVD can then spin forever — the fit-floor guard must
        # stop it at the iteration that produced it.
        at = alto.build(_tensor(seed=9), n_partitions=2)
        faults.arm("cpals.nan", data={"value": 1e30})
        res = cpals.cp_als(at, RANK, n_iters=6, seed=9, guard=True)
        assert res.health.rolled_back
        assert "diverged" in res.health.reason
        assert all(bool(jnp.all(jnp.isfinite(A))) for A in res.factors)

    def test_mild_regression_trips_monotonicity_guard(self):
        # a modest poison that keeps everything finite and well-scaled,
        # landed once a fit history exists (after=2): only the
        # fit-monotonicity check can see it
        at = alto.build(_tensor(seed=16), n_partitions=2)
        faults.arm("cpals.nan", data={"value": 25.0}, after=2)
        res = cpals.cp_als(at, RANK, n_iters=8, seed=16, guard=True,
                           guard_slack=1e-6)
        assert res.health.rolled_back
        assert "regressed" in res.health.reason

    def test_cpapr_guard_rolls_back(self):
        at = alto.build(_tensor(seed=10, count_data=True), n_partitions=2)
        params = cpapr.CpaprParams(k_max=4)
        faults.arm("cpapr.nan")
        bad = cpapr.cp_apr(at, RANK, params=params, seed=10, guard=False)
        assert not all(bool(jnp.all(jnp.isfinite(A))) for A in bad.factors)
        faults.arm("cpapr.nan")
        good = cpapr.cp_apr(at, RANK, params=params, seed=10, guard=True)
        assert good.health.rolled_back
        assert all(bool(jnp.all(jnp.isfinite(A))) for A in good.factors)

    def test_guarded_apr_matches_unguarded_clean(self):
        at = alto.build(_tensor(seed=11, count_data=True), n_partitions=2)
        params = cpapr.CpaprParams(k_max=4)
        a = cpapr.cp_apr(at, RANK, params=params, seed=11, guard=False)
        b = cpapr.cp_apr(at, RANK, params=params, seed=11, guard=True)
        assert all(jnp.array_equal(fa, fb)
                   for fa, fb in zip(a.factors, b.factors))
        assert b.health.violations == 0


# ---------------------------------------------------------------------------
# Batched quarantine: one slot degrades, bucket-mates bitwise untouched
# ---------------------------------------------------------------------------

def _bucket(seeds, guard, n_iters=5):
    xs = [_tensor(seed=s) for s in seeds]
    sc = shapeclass.classify(xs[0], RANK)
    plan = plan_mod.make_class_plan(sc)
    ats, views, rdims = [], [], []
    for x in xs:
        xp = shapeclass.pad_to_class(x, sc)
        at = shapeclass.canonicalize_tensor(
            alto.build_device(xp, n_partitions=sc.n_partitions,
                              compute_reuse=False), sc)
        ats.append(at)
        views.append(plan_mod.build_views(at, plan))
        rdims.append(x.dims)
    return batched.batched_cp_als(ats, views, rdims, RANK, plan=plan,
                                  n_iters=n_iters, seeds=list(seeds),
                                  capacity=4, guard=guard)


class TestBatchedQuarantine:

    def test_poisoned_slot_quarantined_mates_bitwise_clean(self):
        clean = _bucket((0, 1, 2), guard=True)
        assert clean.quarantined == [False, False, False]
        faults.arm("batched.nan", data={"tenant": 1})
        out = _bucket((0, 1, 2), guard=True)
        assert out.quarantined == [False, True, False]
        for i in (0, 2):
            for fa, fb in zip(clean.results[i].factors,
                              out.results[i].factors):
                assert jnp.array_equal(fa, fb), \
                    f"bucket-mate {i} was perturbed by tenant 1's poison"
        assert all(bool(jnp.all(jnp.isfinite(A)))
                   for A in out.results[1].factors)

    def test_unguarded_bucket_returns_poison(self):
        faults.arm("batched.nan", data={"tenant": 1})
        out = _bucket((0, 1, 2), guard=False)
        assert not any(out.quarantined)
        assert not all(bool(jnp.all(jnp.isfinite(A)))
                       for A in out.results[1].factors)

    def test_guard_bitwise_noop_on_clean_bucket(self):
        a = _bucket((3, 4), guard=False)
        b = _bucket((3, 4), guard=True)
        for ra, rb in zip(a.results, b.results):
            assert ra.fits == rb.fits
            assert all(jnp.array_equal(fa, fb)
                       for fa, fb in zip(ra.factors, rb.factors))


# ---------------------------------------------------------------------------
# The service runtime: ladders, bisection, deadlines, worker loop
# ---------------------------------------------------------------------------

class TestServiceResilience:

    def test_poisoned_tenant_gets_structured_error_only(self):
        svc = _service(capacity=3)
        rids = [svc.submit(_tensor(seed=s), seed=s) for s in (0, 1, 2)]
        faults.arm("batched.nan", data={"tenant": 1})
        rs = {r.request_id: r for r in svc.process()}
        assert not rs[rids[1]].ok
        assert "quarantined" in rs[rids[1]].error
        assert rs[rids[1]].result is not None          # last good iterate
        assert rs[rids[0]].ok and rs[rids[2]].ok
        s = svc.stats()
        assert s["quarantined_tenants"] == 1
        assert s["errors"] == 1

    def test_transient_faults_retried_with_backoff(self):
        views_mod.cache_clear()
        faults.arm("views.build", times=2)
        svc = _service()
        rids = [svc.submit(_tensor(seed=s)) for s in (0, 1)]
        rs = svc.process()
        assert all(r.ok for r in rs)
        assert all(r.retries == 2 for r in rs)
        s = svc.stats()
        assert s["retries"] == 2 and s["backoff_s"] > 0

    def test_bucket_failure_bisects_to_solo_runs(self):
        batched.sweep_cache_clear()
        faults.arm("batched.sweep", times=1)
        svc = _service()
        rids = [svc.submit(_tensor(seed=s)) for s in (0, 1)]
        rs = svc.process()
        assert all(r.ok for r in rs)
        # the bucket run died; each member was re-served alone
        assert all(r.bucket_size == 1 for r in rs)

    def test_second_solo_failure_quarantines_offender(self):
        batched.sweep_cache_clear()
        faults.arm("batched.sweep", times=2)
        svc = _service()
        rids = [svc.submit(_tensor(seed=s)) for s in (0, 1)]
        rs = {r.request_id: r for r in svc.process()}
        # shot 1 kills the bucket, shot 2 kills the first solo re-run:
        # that request is quarantined, its bucket-mate is served clean
        assert not rs[rids[0]].ok
        assert "quarantined after repeated failures" in rs[rids[0]].error
        assert rs[rids[1]].ok
        assert svc.stats()["quarantined_tenants"] == 1

    def test_evict_and_retune_on_stored_plan_failure(self, tmp_path,
                                                     monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
        x = _tensor(seed=12, dims=(8, 6, 4), nnz=50)
        warm = _service(tune="auto")
        warm.submit(x)
        assert all(r.ok for r in warm.process())
        assert len(autotune.load_store()) == 1
        # fresh service trusts the store; its stored plan fails at
        # dispatch -> evicted, heuristic plan takes over, request served
        batched.sweep_cache_clear()
        faults.arm("plan.dispatch", times=1)
        svc = _service(tune="auto")
        svc.submit(x)
        rs = svc.process()
        assert all(r.ok and r.degraded for r in rs)
        assert svc.stats()["plan_evictions"] == 1
        assert len(autotune.load_store()) == 0

    def test_corrupt_plan_store_is_a_miss_not_a_crash(self, tmp_path,
                                                      monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
        faults.arm("autotune.store")
        assert autotune.load_store() == {}
        svc = _service(tune="auto")
        svc.submit(_tensor(seed=13, dims=(8, 6, 4), nnz=50))
        assert all(r.ok for r in svc.process())

    def test_deadline_expired_request_gets_error(self):
        svc = _service()
        rid_late = svc.submit(_tensor(seed=0), deadline_s=0.0)
        rid_ok = svc.submit(_tensor(seed=1), deadline_s=3600.0)
        time.sleep(0.005)
        rs = {r.request_id: r for r in svc.process()}
        assert not rs[rid_late].ok
        assert "deadline expired" in rs[rid_late].error
        assert rs[rid_late].result is None
        assert rs[rid_ok].ok
        assert svc.stats()["deadline_expired"] == 1

    def test_deadline_aware_flush(self):
        svc = _service(capacity=4, max_wait_s=0.02)
        svc.submit(_tensor(seed=0))
        assert svc.process(flush=False) == []      # partial, still young
        time.sleep(0.03)
        rs = svc.process(flush=False)              # aged past max_wait_s
        assert len(rs) == 1 and rs[0].ok

    def test_ingest_merge_interrupt_leaves_base_serviceable(self):
        svc = _service(capacity=1)
        rid = svc.submit(_tensor(seed=14))
        base = svc.process()[0]
        assert base.ok
        x2 = _tensor(seed=15, nnz=20)
        faults.arm("ingest.merge")
        did = svc.submit_delta(rid, x2.coords, x2.values)
        r = {r.request_id: r for r in svc.process()}[did]
        assert not r.ok and "resubmit is safe" in r.error
        # the merge is functional: the retained base tensor was never
        # touched, so the clean resubmit serves normally
        did2 = svc.submit_delta(rid, x2.coords, x2.values)
        r2 = {r.request_id: r for r in svc.process()}[did2]
        assert r2.ok
        assert all(bool(jnp.all(jnp.isfinite(A)))
                   for A in r2.result.factors)


class TestWorkerLoop:

    def test_lifecycle(self):
        svc = _service(max_wait_s=0.01)
        assert not svc.serving
        svc.serve(poll_s=0.002)
        svc.serve(poll_s=0.002)                    # idempotent
        assert svc.serving
        rid = svc.submit(_tensor(seed=0))
        resp = svc.wait(rid, timeout=120)
        assert resp.ok
        svc.shutdown()
        assert not svc.serving
        svc.shutdown()                             # idempotent
        assert svc.stats()["worker_recoveries"] == 0

    def test_shutdown_drains_admitted_requests(self):
        svc = _service(capacity=8)                 # never fills a bucket
        svc.serve(poll_s=0.002)
        rids = [svc.submit(_tensor(seed=s)) for s in range(3)]
        svc.shutdown(wait=True)                    # final flush drains
        rs = [svc.wait(r, timeout=5) for r in rids]
        assert all(r.ok for r in rs)

    def test_wait_times_out(self):
        svc = _service()
        with pytest.raises(TimeoutError):
            svc.wait(999, timeout=0.02)

    def test_sixteen_thread_stress(self):
        svc = _service(capacity=4, n_iters=3, max_wait_s=0.01,
                       retain_results=256)
        svc.serve(poll_s=0.002)
        n_threads, per_thread = 16, 2
        failures: list[str] = []
        lock = threading.Lock()

        def client(t):
            try:
                rids = [svc.submit(_tensor(seed=(t * per_thread + j) % 7),
                                   seed=t) for j in range(per_thread)]
                rs = [svc.wait(r, timeout=300) for r in rids]
                for r in rs:
                    if not r.ok:
                        raise AssertionError(f"thread {t}: {r.error}")
                # half the clients chase with a delta against their base
                if t % 2 == 0:
                    x2 = _tensor(seed=t, nnz=15)
                    did = svc.submit_delta(rids[0], x2.coords, x2.values)
                    rd = svc.wait(did, timeout=300)
                    if not rd.ok:
                        raise AssertionError(f"thread {t} delta: {rd.error}")
            except Exception as exc:  # noqa: BLE001 — collected for report
                with lock:
                    failures.append(f"{type(exc).__name__}: {exc}")

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(600)
        svc.shutdown()
        assert not failures, failures
        s = svc.stats()
        assert s["tenants_done"] == n_threads * per_thread
        assert s["deltas_done"] == n_threads // 2
        assert s["worker_recoveries"] == 0
        assert s["errors"] == 0
