"""Execution-plan layer: Pallas kernels (interpret) vs the dense oracle.

Parity on adversarial shapes — non-power-of-two dims, nnz not divisible by
the partition count, empty tensors/modes, ranks whose only divisors are
awkward, duplicate coordinates — for BOTH traversals, plus plan-resolution
and executable-cache behaviour.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alto, heuristics, mttkrp as cm, plan as plan_mod
from repro.kernels import ops
from repro.sparse import synthetic
from repro.sparse.tensor import SparseTensor

TOL = 1e-5


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def _parity_all_modes(x, L, R, seed=0):
    """Both Pallas traversals + plan dispatch vs dense einsum, all modes."""
    at = alto.build(x, n_partitions=L)
    factors = _factors(x.dims, R, seed=seed)
    dense = x.todense()
    plan = plan_mod.make_plan(at.meta, R, backend="pallas", interpret=True)
    views = {m: alto.oriented_view(at, m) for m in range(x.ndim)}
    for mode in range(x.ndim):
        mp = plan.modes[mode]
        assert R % mp.r_block == 0          # plan only picks divisors
        ref = cm.dense_mttkrp_reference(dense, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        rec = ops.mttkrp(at, factors, mode, r_block=mp.r_block,
                         interpret=True)
        ori = ops.mttkrp_oriented(views[mode], factors,
                                  block_m=mp.block_m, r_block=mp.r_block,
                                  interpret=True)
        via_plan = plan_mod.execute_mttkrp(plan, at, views, factors, mode)
        for name, out in (("recursive", rec), ("oriented", ori),
                          ("plan", via_plan)):
            err = float(jnp.max(jnp.abs(out - ref))) / scale
            assert err < TOL, (name, mode, err)


@pytest.mark.parametrize("dims,nnz,L,R", [
    ((13, 7, 5), 97, 4, 6),        # non-pow2 dims, nnz % L != 0
    ((37, 18, 11, 3), 451, 8, 7),  # 4-D, prime-ish rank (r_block in {1,7})
    ((20, 1, 12), 150, 4, 16),     # length-1 mode (zero index bits)
    ((257, 255, 2), 1000, 16, 12), # dims straddling powers of two
])
def test_plan_parity_adversarial_shapes(dims, nnz, L, R):
    x = synthetic.uniform_tensor(dims, nnz, seed=3)
    _parity_all_modes(x, L, R)


def test_plan_parity_empty_tensor():
    """nnz=0: every kernel must return exact zeros of the right shape."""
    x = SparseTensor((9, 6, 4), np.zeros((0, 3), np.int32),
                     np.zeros((0,), np.float32))
    at = alto.build(x, n_partitions=4)
    factors = _factors(x.dims, 5)
    plan = plan_mod.make_plan(at.meta, 5, backend="pallas", interpret=True)
    views = {m: alto.oriented_view(at, m) for m in range(3)}
    for mode in range(3):
        out = plan_mod.execute_mttkrp(plan, at, views, factors, mode)
        assert out.shape == (x.dims[mode], 5)
        assert float(jnp.max(jnp.abs(out))) == 0.0


def test_plan_parity_duplicate_coordinates():
    """Duplicate nonzeros must sum, matching the dense scatter-add oracle."""
    rng = np.random.default_rng(7)
    base = np.stack([rng.integers(0, I, size=60) for I in (11, 9, 7)],
                    axis=1).astype(np.int32)
    coords = np.concatenate([base, base[:25], base[:10]], axis=0)
    values = rng.standard_normal(coords.shape[0]).astype(np.float32)
    x = SparseTensor((11, 9, 7), coords, values)   # NOT deduplicated
    _parity_all_modes(x, L=4, R=8)


def test_plan_parity_rank_not_multiple_of_default_tile():
    """Odd ranks: the plan must fall back to a dividing r_block and the
    kernels must reject a non-dividing override."""
    x = synthetic.uniform_tensor((24, 18, 10), 400, seed=1)
    at = alto.build(x, n_partitions=4)
    for R in (1, 7, 13):
        plan = plan_mod.make_plan(at.meta, R, backend="pallas",
                                  interpret=True)
        for mp in plan.modes:
            assert R % mp.r_block == 0
    factors = _factors(x.dims, 13)
    with pytest.raises(ValueError):
        ops.mttkrp(at, factors, 0, r_block=8, interpret=True)


def test_oriented_blocks_smaller_than_block_m():
    """Streams shorter than one block are padded, not rejected."""
    x = synthetic.uniform_tensor((6, 5, 4), 17, seed=2)
    at = alto.build(x, n_partitions=2)
    factors = _factors(x.dims, 4)
    view = alto.oriented_view(at, 0)
    got = ops.mttkrp_oriented(view, factors, block_m=256, interpret=True)
    ref = cm.dense_mttkrp_reference(x.todense(), factors, 0)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < TOL


def test_phi_oriented_vs_reference_both_policies():
    """Oriented Φ kernel (PRE and OTF) vs the reference-backend Φ."""
    x = synthetic.zipf_tensor((19, 23, 11), 700, seed=4, count_data=True)
    at = alto.build(x, n_partitions=4)
    rng = np.random.default_rng(0)
    R = 6
    factors = [jnp.asarray(np.abs(rng.standard_normal((I, R))
                                  ).astype(np.float32) + 0.05)
               for I in x.dims]
    pallas = plan_mod.make_plan(at.meta, R, backend="pallas",
                                interpret=True)
    ref = plan_mod.make_plan(at.meta, R, backend="reference")
    for mode in range(x.ndim):
        B = jnp.abs(factors[mode]) + 0.1
        view = alto.oriented_view(at, mode)
        coords = alto.delinearize(at.meta.enc, view.words)
        pi = cm.krp_rows(coords, factors, mode)
        want = plan_mod.execute_phi(ref, at, view, B, mode, factors=factors)
        scale = float(jnp.max(jnp.abs(want))) + 1e-9
        otf = plan_mod.execute_phi(pallas, at, view, B, mode,
                                   factors=factors)
        pre = plan_mod.execute_phi(pallas, at, view, B, mode, pi=pi)
        assert float(jnp.max(jnp.abs(otf - want))) / scale < TOL
        assert float(jnp.max(jnp.abs(pre - want))) / scale < TOL


def test_vmem_budgeting_scales_blocks_down():
    """Tighter budgets must shrink r_block/block_m, never break divisors."""
    x = synthetic.uniform_tensor((64, 48, 32), 5000, seed=0)
    at = alto.build(x, n_partitions=4)
    R = 32
    roomy = plan_mod.make_plan(at.meta, R, vmem_limit=plan_mod.VMEM_BYTES)
    tight = plan_mod.make_plan(at.meta, R, vmem_limit=64 * 1024)
    for big, small in zip(roomy.modes, tight.modes):
        assert small.r_block <= big.r_block
        assert small.block_m <= big.block_m
        assert R % small.r_block == 0
        assert small.block_m >= plan_mod.MIN_BLOCK_M
    # the budget estimate itself must be monotone in the block sizes
    assert (plan_mod.oriented_vmem_bytes(at.meta, 0, 256, 8)
            < plan_mod.oriented_vmem_bytes(at.meta, 0, 512, 8))
    assert (plan_mod.recursive_vmem_bytes(at.meta, 0, 4)
            < plan_mod.recursive_vmem_bytes(at.meta, 0, 16))


def test_executable_cache_reuses_compilations():
    """Two calls with identical static meta must share one executable."""
    x = synthetic.uniform_tensor((30, 20, 10), 500, seed=0)
    at = alto.build(x, n_partitions=4)
    factors = _factors(x.dims, 8)
    ops.cache_clear()
    ops.mttkrp(at, factors, 0, interpret=True)
    n1 = ops.cache_size()
    ops.mttkrp(at, factors, 0, interpret=True)   # hit
    assert ops.cache_size() == n1
    ops.mttkrp(at, factors, 1, interpret=True)   # new mode -> new entry
    assert ops.cache_size() == n1 + 1
    # same shape but different meta (different nnz) -> new entry
    y = synthetic.uniform_tensor((30, 20, 10), 400, seed=1)
    ops.mttkrp(alto.build(y, n_partitions=4), factors, 0, interpret=True)
    assert ops.cache_size() == n1 + 2


def test_plan_is_static_and_hashable():
    """Plans must be usable as static jit arguments / cache keys."""
    x = synthetic.uniform_tensor((16, 12, 8), 200, seed=0)
    at = alto.build(x, n_partitions=2)
    a = plan_mod.make_plan(at.meta, 4, backend="reference")
    b = plan_mod.make_plan(at.meta, 4, backend="reference")
    assert a == b and hash(a) == hash(b)
    assert a != plan_mod.make_plan(at.meta, 8, backend="reference")


def test_drivers_reject_mismatched_plan_rank():
    from repro.core import cpals, cpapr
    x = synthetic.uniform_tensor((10, 8, 6), 100, seed=0)
    at = alto.build(x, n_partitions=2)
    plan = plan_mod.make_plan(at.meta, 4)
    with pytest.raises(ValueError, match="rank"):
        cpals.cp_als(at, rank=6, n_iters=1, plan=plan)
    with pytest.raises(ValueError, match="rank"):
        cpapr.cp_apr(at, rank=6, plan=plan)


def test_plan_routes_per_forced_traversal(monkeypatch):
    """The plan layer must dispatch to the kernel its traversal names.

    Low-reuse modes go output-oriented; on this small tensor the stream
    dwarfs the mode dim, so the traffic refinement picks the scratch-carry
    kernel. Capping the VMEM budget below the carry's resident-output
    floor must fall back to the one-hot merge kernel.
    """
    x = synthetic.uniform_tensor((16, 12, 8), 300, seed=0)
    at = alto.build(x, n_partitions=2)
    factors = _factors(x.dims, 4)
    calls = []
    real = {"rec": ops.mttkrp, "ori": ops.mttkrp_oriented,
            "carry": ops.mttkrp_oriented_carry}
    for tag, fn in real.items():
        monkeypatch.setattr(
            ops, {"rec": "mttkrp", "ori": "mttkrp_oriented",
                  "carry": "mttkrp_oriented_carry"}[tag],
            lambda *a, _tag=tag, _fn=fn, **k: calls.append(_tag)
            or _fn(*a, **k))
    # budget below the carry floor for mode 0 (but roomy for one-hot)
    tight = plan_mod.oriented_carry_vmem_bytes(
        at.meta, 0, plan_mod.MIN_BLOCK_M, 1) - 1
    cases = ((10.0, dict(), "rec"),
             (1.5, dict(), "carry"),
             (1.5, dict(vmem_limit=tight), "ori"))
    for reuse, kw, expect in cases:
        meta = dataclasses.replace(at.meta, fiber_reuse=(reuse,) * 3)
        at2 = alto.AltoTensor(meta, at.words, at.values, at.part_start,
                              at.part_end)
        plan = plan_mod.make_plan(meta, 4, backend="pallas",
                                  interpret=True, **kw)
        views = plan_mod.build_views(at2, plan)
        calls.clear()
        plan_mod.execute_mttkrp(plan, at2, views, factors, 0)
        assert calls == [expect], (reuse, kw, calls)
