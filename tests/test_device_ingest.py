"""Device-resident ALTO ingest: host/device parity, cache, jit contracts.

Pins the acceptance conditions of the device ingest stack:

* `alto.build_device` / `alto.oriented_view_device` produce BIT-IDENTICAL
  element order to the host numpy path — duplicate-key ties included —
  on adversarial inputs (empty tensor, extent-1 modes, duplicate
  coordinates, two- and four-word encodings, all-nonzeros-one-row);
* the jitted ingest cores trace once per static meta and contain zero
  host callbacks;
* the view cache (`core.views`) builds once per (tensor, mode) per
  process and the drivers consume cached device-built views end to end.

Runs on the hermetic `tests/proptest.py` harness (no hypothesis in the
offline image).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.core import alto, cpals, cpapr, encoding as E
from repro.core import plan as plan_mod
from repro.core import views as views_mod
from repro.sparse.tensor import SparseTensor


def _random_tensor(dims, nnz, seed, dup_frac=0.3):
    """COO tensor with a controlled fraction of duplicate coordinates."""
    rng = np.random.default_rng(seed)
    dims = tuple(int(d) for d in dims)
    if nnz == 0:
        return SparseTensor(dims, np.zeros((0, len(dims)), np.int32),
                            np.zeros((0,), np.float32))
    base = np.stack([rng.integers(0, I, size=nnz) for I in dims],
                    axis=1).astype(np.int32)
    n_dup = int(nnz * dup_frac)
    if n_dup and nnz > 1:
        # Overwrite a suffix with copies of earlier rows -> duplicate
        # linearized keys at distinct stream positions (tie stability).
        src = rng.integers(0, nnz - n_dup, size=n_dup)
        base[nnz - n_dup:] = base[src]
    vals = rng.random(nnz).astype(np.float32) + 0.1
    return SparseTensor(dims, base, vals)


def _assert_tensor_parity(h, d):
    assert h.meta == d.meta
    np.testing.assert_array_equal(np.asarray(h.words), np.asarray(d.words))
    np.testing.assert_array_equal(np.asarray(h.values),
                                  np.asarray(d.values))
    np.testing.assert_array_equal(np.asarray(h.part_start),
                                  np.asarray(d.part_start))
    np.testing.assert_array_equal(np.asarray(h.part_end),
                                  np.asarray(d.part_end))


def _assert_view_parity(vh, vd):
    assert vh.meta == vd.meta and vh.mode == vd.mode
    np.testing.assert_array_equal(np.asarray(vh.rows), np.asarray(vd.rows))
    np.testing.assert_array_equal(np.asarray(vh.words),
                                  np.asarray(vd.words))
    np.testing.assert_array_equal(np.asarray(vh.values),
                                  np.asarray(vd.values))
    np.testing.assert_array_equal(np.asarray(vh.perm), np.asarray(vd.perm))


# ---------------------------------------------------------------------------
# Device sort primitive vs the host packed-key argsort
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n_words=st.sampled_from([1, 2, 4]), m=st.integers(0, 200),
       seed=st.integers(0, 2**31 - 1))
def test_sort_by_key_matches_host_argsort(n_words, m, seed):
    """`encoding.sort_by_key` == stable `sort_key_np` permutation, with
    a narrow value range so duplicate full keys exercise tie stability."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 7, size=(m, n_words)).astype(np.uint32)
    order = E.sort_key_np(words)
    iota = jnp.arange(m, dtype=jnp.int32)
    sorted_words, perm = E.sort_by_key(jnp.asarray(words), iota)
    np.testing.assert_array_equal(np.asarray(perm), order.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(sorted_words), words[order])


@settings(max_examples=15, deadline=None)
@given(n_words=st.sampled_from([1, 2, 4]), m=st.integers(0, 150),
       seed=st.integers(0, 2**31 - 1))
def test_count_distinct_matches_unique(n_words, m, seed):
    """Both distinct-row counters == the np.unique(axis=0) oracle they
    replaced (the fiber_reuse_stats satellite's parity condition)."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 5, size=(m, n_words)).astype(np.uint32)
    expect = len(np.unique(words, axis=0)) if m else 0
    assert E.count_distinct_np(words) == expect
    assert int(E.count_distinct(jnp.asarray(words))) == expect


def test_extract_mode_matches_delinearize():
    """Masked bit-extract of one mode == that column of the full
    delinearize, on both numpy and jax words."""
    rng = np.random.default_rng(0)
    for dims in [(6, 4, 3), (5000, 4000, 3000), (1, 9, 1, 2**17)]:
        enc = E.make_encoding(dims)
        coords = np.stack([rng.integers(0, I, 64) for I in dims],
                          axis=1).astype(np.int32)
        words = E.linearize_np(enc, coords)
        full = E.delinearize_np(enc, words)
        for mode in range(len(dims)):
            got_np = E.extract_mode(enc, words, mode)
            got_dev = E.extract_mode(enc, jnp.asarray(words), mode)
            np.testing.assert_array_equal(got_np, full[:, mode])
            np.testing.assert_array_equal(np.asarray(got_dev),
                                          full[:, mode])
            assert got_np.dtype == np.int32


# ---------------------------------------------------------------------------
# build_device / oriented_view_device parity (adversarial + property)
# ---------------------------------------------------------------------------

ADVERSARIAL = {
    "empty": ((4, 3, 2), 0),
    "extent_1_modes": ((1, 7, 1, 13), 60),
    "duplicates_heavy": ((12, 9, 5), 160),       # dup_frac below
    "two_word": ((5000, 4000, 3000), 220),       # 36 bits -> 2 u32 words
    "four_word": ((2**17, 2**17, 2**17, 2**17), 150),  # 68 bits -> 4 words
    "single_nonzero": ((30, 20), 1),
}


@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_build_and_view_parity_adversarial(name):
    dims, nnz = ADVERSARIAL[name]
    dup = 0.8 if name == "duplicates_heavy" else 0.3
    x = _random_tensor(dims, nnz, seed=hash(name) % 2**31, dup_frac=dup)
    if name == "extent_1_modes":
        x.coords[:, 1] = 3          # every nonzero in one row of mode 1
    h = alto.build(x, n_partitions=4)
    d = alto.build_device(x, n_partitions=4)
    _assert_tensor_parity(h, d)
    for mode in range(x.ndim):
        _assert_view_parity(alto.oriented_view(h, mode),
                            alto.oriented_view_device(d, mode))


@settings(max_examples=12, deadline=None)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       nnz=st.integers(0, 250), L=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_build_device_parity_property(dims, nnz, L, seed):
    x = _random_tensor(tuple(dims), nnz, seed)
    h = alto.build(x, n_partitions=L)
    d = alto.build_device(x, n_partitions=L)
    _assert_tensor_parity(h, d)
    mode = seed % len(dims)
    _assert_view_parity(alto.oriented_view(h, mode),
                        alto.oriented_view_device(d, mode))


def test_build_device_skips_reuse_like_host():
    x = _random_tensor((20, 15, 10), 120, seed=7)
    h = alto.build(x, compute_reuse=False)
    d = alto.build_device(x, compute_reuse=False)
    assert all(np.isnan(v) for v in d.meta.fiber_reuse)
    assert h.meta.temp_rows == d.meta.temp_rows


# ---------------------------------------------------------------------------
# jit contracts: once-per-meta tracing, zero host callbacks
# ---------------------------------------------------------------------------

def test_build_device_traces_once_per_meta():
    x = _random_tensor((25, 18, 11), 140, seed=3)
    alto.build_device(x, n_partitions=4)
    before = alto.device_ingest_traces()
    d = alto.build_device(x, n_partitions=4)    # same meta: no retrace
    alto.build_device(_random_tensor((25, 18, 11), 140, seed=99),
                      n_partitions=4)           # same meta, other data
    assert alto.device_ingest_traces()["build"] == before["build"]
    alto.oriented_view_device(d, 0)
    mid = alto.device_ingest_traces()
    alto.oriented_view_device(d, 0)             # same (meta, mode)
    assert alto.device_ingest_traces()["view"] == mid["view"]
    # a different static meta (nnz changes Mp) must trace fresh
    alto.build_device(_random_tensor((25, 18, 11), 141, seed=5),
                      n_partitions=4)
    assert alto.device_ingest_traces()["build"] == before["build"] + 1


def test_ingest_cores_have_zero_host_callbacks():
    """The jitted build/view cores must be pure device programs — no
    pure_callback/io_callback/debug.callback primitives in the jaxpr."""
    x = _random_tensor((40, 30, 20), 200, seed=11)
    enc = E.make_encoding(x.dims)
    build_fn = alto._build_device_fn(enc, 4, x.nnz, True, jnp.float32)
    jaxpr = jax.make_jaxpr(build_fn)(jnp.asarray(x.coords),
                                     jnp.asarray(x.values))
    assert "callback" not in str(jaxpr)
    d = alto.build_device(x, n_partitions=4)
    view_fn = alto._view_device_fn(enc, 0, d.words.shape[0], jnp.float32)
    jaxpr = jax.make_jaxpr(view_fn)(d.words, d.values)
    assert "callback" not in str(jaxpr)


def test_build_device_core_runs_under_jit():
    """The cached core composes under an outer jit (jit-compatible end
    to end — e.g. regeneration inside a larger traced program)."""
    x = _random_tensor((16, 12, 9), 90, seed=13)
    enc = E.make_encoding(x.dims)
    fn = alto._build_device_fn(enc, 4, x.nnz, True, jnp.float32)

    @jax.jit
    def outer(coords, values):
        words, vals, ps, pe, fibers = fn(coords, values)
        return words, vals, ps, pe, fibers

    words, *_ = outer(jnp.asarray(x.coords), jnp.asarray(x.values))
    h = alto.build(x, n_partitions=4)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(h.words))


# ---------------------------------------------------------------------------
# View cache: one build per (tensor, mode) per process, shared end to end
# ---------------------------------------------------------------------------

def test_view_cache_one_build_per_tensor_mode():
    views_mod.cache_clear()
    x = _random_tensor((40, 30, 20), 300, seed=17)
    at = alto.build_device(x)
    plan = plan_mod.make_plan(at.meta, rank=4)
    vs1 = plan_mod.build_views(at, plan)
    n = len(vs1)
    assert n > 0
    vs2 = plan_mod.build_views(at, plan)
    stats = views_mod.cache_stats()
    assert stats["builds"] == n
    assert stats["hits"] == n
    assert all(vs1[k] is vs2[k] for k in vs1)
    # same content in a distinct AltoTensor object -> same cached views
    at2 = alto.build_device(x)
    vs3 = plan_mod.build_views(at2, plan)
    assert views_mod.cache_stats()["builds"] == n
    assert all(vs1[k] is vs3[k] for k in vs1)
    # different data -> different fingerprint -> fresh builds
    at3 = alto.build_device(_random_tensor((40, 30, 20), 300, seed=18))
    plan_mod.build_views(at3, plan)
    assert views_mod.cache_stats()["builds"] == 2 * n


def test_view_cache_invalidate_and_byte_bound(monkeypatch):
    views_mod.cache_clear()
    x = _random_tensor((20, 15, 10), 150, seed=41)
    at = alto.build_device(x)
    v = views_mod.get_view(at, 0)
    assert views_mod.cache_stats()["size"] == 1
    assert views_mod.invalidate(at) == 1
    assert views_mod.cache_stats()["size"] == 0
    # a byte budget below two views LRU-evicts down to the newest one
    monkeypatch.setenv("REPRO_VIEW_CACHE_BYTES",
                       str(views_mod._view_bytes(v) + 1))
    views_mod.get_view(at, 0)
    views_mod.get_view(at, 1)
    stats = views_mod.cache_stats()
    assert stats["size"] == 1 and stats["builds"] == 3
    views_mod.cache_clear()


def test_view_cache_routes_match_bitwise():
    views_mod.cache_clear()
    x = _random_tensor((22, 14, 8), 130, seed=23)
    at = alto.build_device(x)
    dev = views_mod.get_view(at, 0, route="device")
    views_mod.cache_clear()
    host = views_mod.get_view(at, 0, route="host")
    _assert_view_parity(host, dev)
    views_mod.cache_clear()


def test_drivers_consume_cached_device_views_end_to_end():
    """CP-ALS and CP-APR run on device-built tensors + cached device
    views, matching the host-ingest path bit-for-bit (identical element
    order => identical reduction order)."""
    views_mod.cache_clear()
    x = _random_tensor((30, 20, 12), 400, seed=29)
    at_h = alto.build(x)
    at_d = alto.build_device(x)
    res_h = cpals.cp_als(at_h, rank=4, n_iters=3,
                         views={m: alto.oriented_view(at_h, m)
                                for m in range(3)})
    res_d = cpals.cp_als(at_d, rank=4, n_iters=3)
    for A_h, A_d in zip(res_h.factors, res_d.factors):
        np.testing.assert_array_equal(np.asarray(A_h), np.asarray(A_d))
    assert res_h.fits == res_d.fits
    # further driver runs on the same tensor: zero additional view builds
    # (CP-APR's plan orients the same rank-free traversal set)
    builds = views_mod.cache_stats()["builds"]
    cpals.cp_als(at_d, rank=4, n_iters=2)
    p = cpapr.CpaprParams(k_max=2, l_max=2)
    cpapr.cp_apr(at_d, rank=3, params=p)
    assert views_mod.cache_stats()["builds"] == builds


def test_resident_bytes_accounts_views():
    x = _random_tensor((26, 17, 9), 180, seed=31)
    at = alto.build_device(x)
    plan = plan_mod.make_plan(at.meta, rank=4)
    views = plan_mod.build_views(at, plan)
    base = plan_mod.resident_bytes(at)
    full = plan_mod.resident_bytes(at, views)
    Mp = at.words.shape[0]
    W = at.meta.enc.n_words
    per_view = Mp * (4 + 4 * W + at.values.dtype.itemsize + 4)
    assert base == (Mp * (4 * W + at.values.dtype.itemsize)
                    + 2 * at.part_start.size * 4)
    assert full == base + len(views) * per_view
    assert full > at.storage_bytes()    # Fig. 12 accounting undercounts


# ---------------------------------------------------------------------------
# Shard-local consumption of the device-built view (dist seam, no mesh)
# ---------------------------------------------------------------------------

def test_device_view_shards_like_host_view():
    """`dist.cpd.local_mttkrp` over contiguous slices of the
    device-built view sums to the unsharded oriented MTTKRP (the psum
    simulation the dist unit tests use, fed by device ingest)."""
    from repro.dist import cpd as dist_cpd
    from repro.core import mttkrp as core_mttkrp
    x = _random_tensor((24, 16, 10), 240, seed=37)
    at = alto.build_device(x)
    view = views_mod.get_view(at, 0)
    plan = plan_mod.make_plan(at.meta, rank=4, backend="reference")
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(rng.random((I, 4)), jnp.float32)
               for I in x.dims]
    full = core_mttkrp.mttkrp_oriented(view, factors)
    Mp = view.rows.shape[0]
    cut = Mp // 2
    parts = [
        dist_cpd.local_mttkrp(plan, 0, view.rows[s], view.words[s],
                              view.values[s], factors)
        for s in (slice(0, cut), slice(cut, Mp))]
    np.testing.assert_allclose(np.asarray(parts[0] + parts[1]),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
