"""Hermetic seeded property-test harness (offline stand-in for hypothesis).

The container has no network access, so ``hypothesis`` cannot be installed.
This module provides the small subset the test-suite uses — ``@given`` with
keyword strategies, ``@settings``, and a ``strategies`` namespace — with the
same decorator syntax, backed by a fixed-seed ``numpy`` RNG so every run
draws the identical example sequence (fully deterministic, fully offline).

Example:

    from proptest import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(dims=st.lists(st.integers(1, 300), min_size=1, max_size=6),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip(dims, seed):
        ...

Failures re-raise with the drawn example appended, plus the example index so
a single case can be replayed via ``PROPTEST_ONLY_EXAMPLE=<idx>``.
"""
from __future__ import annotations

import functools
import inspect
import os
import zlib
from typing import Any, Callable, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 25
_MAX_EXAMPLES_ATTR = "_proptest_max_examples"


class Strategy:
    """A value generator: ``draw(rng) -> value``. Composable via map."""

    def __init__(self, draw: Callable[[np.random.Generator], Any],
                 label: str = "strategy"):
        self._draw = draw
        self.label = label

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: fn(self._draw(rng)),
                        f"{self.label}.map")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Strategy<{self.label}>"


# ---------------------------------------------------------------------------
# strategies namespace (mirrors hypothesis.strategies' call signatures)
# ---------------------------------------------------------------------------

def integers(min_value: int, max_value: int) -> Strategy:
    """Uniform integer in the closed interval [min_value, max_value]."""
    if min_value > max_value:
        raise ValueError(f"empty integer range [{min_value}, {max_value}]")
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value, endpoint=True)),
        f"integers({min_value},{max_value})")


def floats(min_value: float = 0.0, max_value: float = 1.0) -> Strategy:
    """Uniform float in [min_value, max_value] (no NaN/inf corner cases)."""
    lo, hi = float(min_value), float(max_value)
    return Strategy(lambda rng: float(lo + (hi - lo) * rng.random()),
                    f"floats({lo},{hi})")


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans")


def sampled_from(elements: Sequence[Any]) -> Strategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from() needs a non-empty sequence")
    return Strategy(lambda rng: elems[int(rng.integers(len(elems)))],
                    f"sampled_from({len(elems)} options)")


def lists(elements: Strategy, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    def draw(rng: np.random.Generator):
        n = int(rng.integers(min_size, max_size, endpoint=True))
        return [elements.draw(rng) for _ in range(n)]
    return Strategy(draw, f"lists({elements.label},{min_size},{max_size})")


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.draw(rng) for s in strategies),
                    "tuples")


def shapes(min_dims: int = 1, max_dims: int = 5, min_side: int = 1,
           max_side: int = 64) -> Strategy:
    """Random tensor shape: tuple of per-mode extents."""
    return lists(integers(min_side, max_side), min_size=min_dims,
                 max_size=max_dims).map(tuple)


def arrays(dtype: Any, shape: Any, min_value: float = -10.0,
           max_value: float = 10.0) -> Strategy:
    """Random ndarray; ``shape`` may be a tuple or a shape Strategy."""
    dt = np.dtype(dtype)

    def draw(rng: np.random.Generator):
        shp = shape.draw(rng) if isinstance(shape, Strategy) else tuple(shape)
        if np.issubdtype(dt, np.integer):
            return rng.integers(int(min_value), int(max_value),
                                size=shp, endpoint=True).astype(dt)
        return (min_value + (max_value - min_value)
                * rng.random(size=shp)).astype(dt)

    return Strategy(draw, f"arrays({dt},...)")


# ---------------------------------------------------------------------------
# decorators
# ---------------------------------------------------------------------------

def settings(max_examples: int = DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored: Any):
    """Attach example-count settings to a @given-wrapped test.

    ``deadline`` (and any other hypothesis-only knob) is accepted and
    ignored — runs are deterministic, so there is nothing to time-bound.
    """
    def apply(fn):
        setattr(fn, _MAX_EXAMPLES_ATTR, int(max_examples))
        return fn
    return apply


def given(**strategy_kwargs: Strategy):
    """Run the test once per generated example, deterministically.

    The RNG seed for example ``i`` mixes a CRC of the test's qualified name
    with ``i``, so cases are stable across runs/machines yet differ between
    tests that share strategy definitions.
    """
    for name, strat in strategy_kwargs.items():
        if not isinstance(strat, Strategy):
            raise TypeError(f"@given argument {name!r} is not a Strategy")

    def decorate(fn):
        base = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _MAX_EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES)
            only = os.environ.get("PROPTEST_ONLY_EXAMPLE")
            todo = [int(only)] if only else range(n)
            for i in todo:
                rng = np.random.default_rng((base + i) % 2**32)
                drawn = {k: s.draw(rng)
                         for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # noqa: BLE001 — re-raise annotated
                    raise AssertionError(
                        f"proptest example {i}/{n} failed for "
                        f"{fn.__qualname__} with {drawn!r} "
                        f"(replay: PROPTEST_ONLY_EXAMPLE={i}): {e}"
                    ) from e

        setattr(wrapper, _MAX_EXAMPLES_ATTR,
                getattr(fn, _MAX_EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES))
        # Strip the strategy kwargs from the visible signature so pytest
        # does not mistake them for fixtures (hypothesis does the same).
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items()
                if name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=kept)
        return wrapper

    return decorate


class _StrategiesNamespace:
    """`from proptest import strategies as st` — hypothesis-style alias."""
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)
    shapes = staticmethod(shapes)
    arrays = staticmethod(arrays)


strategies = _StrategiesNamespace()
