"""Model-layer unit tests: SSD core, MoE dispatch, RoPE, attention, data
pipeline, optimizers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.configs import reduced_config
from repro.models.ssm import ssd_chunked, ssd_step
from repro.models.rope import apply_mrope, apply_rope
from repro.models.moe import _alto_sort_dispatch


class TestSSD:
    def _naive(self, a, Bm, X, Cm):
        B, S, H = a.shape
        N, P = Bm.shape[-1], X.shape[-1]
        h = jnp.zeros((B, H, N, P), jnp.float32)
        ys = []
        for t in range(S):
            y, h = ssd_step(h, a[:, t], Bm[:, t], X[:, t], Cm[:, t])
            ys.append(y)
        return jnp.stack(ys, 1), h

    @pytest.mark.parametrize("chunk", [4, 8, 32])
    @pytest.mark.parametrize("G", [1, 4])
    def test_chunked_equals_sequential(self, chunk, G):
        rng = np.random.default_rng(0)
        B, S, H, N, P = 2, 32, 4, 8, 16
        a = jnp.asarray(-np.abs(rng.standard_normal((B, S, H))
                                ).astype(np.float32) * 0.3)
        Bm = jnp.asarray(rng.standard_normal((B, S, G, N)
                                             ).astype(np.float32))
        Cm = jnp.asarray(rng.standard_normal((B, S, G, N)
                                             ).astype(np.float32))
        X = jnp.asarray(rng.standard_normal((B, S, H, P)
                                            ).astype(np.float32))
        y, hT = ssd_chunked(a, Bm, X, Cm, chunk)
        y_ref, h_ref = self._naive(a, Bm, X, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_decay_zero_is_cumsum(self):
        """a=0 (no decay) -> h_T = Σ B_t ⊗ X_t exactly."""
        rng = np.random.default_rng(1)
        B, S, H, N, P = 1, 16, 2, 4, 4
        a = jnp.zeros((B, S, H))
        Bm = jnp.asarray(rng.standard_normal((B, S, H, N)
                                             ).astype(np.float32))
        Cm = jnp.asarray(rng.standard_normal((B, S, H, N)
                                             ).astype(np.float32))
        X = jnp.asarray(rng.standard_normal((B, S, H, P)
                                            ).astype(np.float32))
        _, hT = ssd_chunked(a, Bm, X, Cm, 4)
        want = jnp.einsum("bshn,bshp->bhnp", Bm, X)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestMoEDispatch:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), E=st.sampled_from([4, 8, 40]),
           n=st.sampled_from([16, 64, 256]))
    def test_alto_sort_slots_property(self, seed, E, n):
        """Sorted dispatch: per-expert slots are 0..count-1 with no
        duplicates (conflict-free capacity buckets)."""
        rng = np.random.default_rng(seed)
        e = jnp.asarray(rng.integers(0, E, size=n).astype(np.int32))
        order, slot, seg_e = _alto_sort_dispatch(e, E, n)
        e_np = np.asarray(seg_e)
        slot_np = np.asarray(slot)
        assert (np.diff(e_np) >= 0).all()          # expert-major order
        for ex in range(E):
            s = np.sort(slot_np[e_np == ex])
            np.testing.assert_array_equal(s, np.arange(len(s)))

    def test_alto_vs_reference_dispatch(self):
        from repro.models import model as M
        from repro.models.common import materialize
        cfg = reduced_config("granite-moe-3b-a800m")
        params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                        (2, 32)).astype(np.int32))
        batch = {"tokens": toks, "labels": toks}
        lg, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
        cfg2 = dataclasses.replace(cfg, moe_alto_dispatch=False)
        lg2, _ = jax.jit(lambda p, b: M.forward(cfg2, p, b))(params, batch)
        assert float(jnp.max(jnp.abs(lg - lg2))) < 1e-4


class TestRope:
    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 8, 4, 16)
                                            ).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        y = apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                                   np.linalg.norm(np.asarray(y)),
                                   rtol=1e-5)

    def test_rope_relative_shift_invariance(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)
                                            ).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)
                                            ).astype(np.float32))

        def dot(i, j):
            qi = apply_rope(q, jnp.full((1, 1), i), 1e4)
            kj = apply_rope(k, jnp.full((1, 1), j), 1e4)
            return float(jnp.sum(qi * kj))

        assert abs(dot(5, 3) - dot(9, 7)) < 1e-4

    def test_mrope_equal_streams_is_rope(self):
        """Identical t/h/w positions == plain RoPE (text tokens)."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((2, 8, 2, 16)
                                            ).astype(np.float32))
        pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
        a = apply_rope(x, pos, 1e4)
        b = apply_mrope(x, pos3, 1e4, (2, 3, 3))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


class TestPipelineAndOptim:
    def test_pipeline_determinism_and_skip(self):
        from repro.data.pipeline import TokenPipeline
        cfg = reduced_config("smollm-360m")
        p1 = TokenPipeline(cfg, 4, 16, seed=3)
        batches = [next(p1) for _ in range(5)]
        p2 = TokenPipeline(cfg, 4, 16, seed=3)
        p2.skip_to(3)
        b3 = next(p2)
        np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                      np.asarray(b3["tokens"]))

    def test_adamw_decreases_quadratic(self):
        from repro.optim import adamw
        opt = adamw(0.1)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(100):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_adafactor_decreases_quadratic(self):
        from repro.optim import adafactor
        opt = adafactor(0.05)
        params = {"w": jnp.full((4, 4), 3.0)}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_adafactor_state_is_factored(self):
        from repro.optim import adafactor
        opt = adafactor(0.05)
        params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((8,))}
        st_ = opt.init(params)
        assert st_["vr"]["w"].shape == (8,)
        assert st_["vc"]["w"].shape == (16,)
        assert st_["vr"]["b"].shape == (8,)

    def test_grad_accum_equivalence(self):
        """accum=2 must equal accum=1 on the same global batch."""
        from repro.models import model as M
        from repro.models.common import materialize
        from repro.optim import get_optimizer
        from repro.train.steps import make_train_step
        cfg1 = reduced_config("smollm-360m")
        cfg2 = dataclasses.replace(cfg1, grad_accum=2)
        params = materialize(M.model_def(cfg1), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg1.vocab_size,
                                        (4, 16)).astype(np.int32))
        batch = {"tokens": toks, "labels": toks}
        outs = []
        for cfg in (cfg1, cfg2):
            opt = get_optimizer("adamw", lr=1e-2)
            p, s, m = jax.jit(make_train_step(cfg, opt))(
                params, opt.init(params), batch)
            outs.append((p, float(m["ce"])))
        # microbatch means vs full-batch mean differ only by masking noise
        assert abs(outs[0][1] - outs[1][1]) < 1e-2
        for a, b in zip(jax.tree.leaves(outs[0][0]),
                        jax.tree.leaves(outs[1][0])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3)
