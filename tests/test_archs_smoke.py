"""Per-architecture smoke tests: reduced same-family config, one forward
and one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config, shapes_for
from repro.models import model as M
from repro.models.common import materialize
from repro.optim import get_optimizer
from repro.train.steps import make_train_step


def make_batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32))}
    b["labels"] = b["tokens"]
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        vis = cfg.vision_prefix
        b["tokens"] = b["tokens"][:, :S - vis]
        b["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (B, vis, cfg.d_model)).astype(np.float32))
        b["positions3"] = jnp.asarray(
            np.broadcast_to(np.arange(S, dtype=np.int32),
                            (3, B, S)).copy())
        b["labels"] = jnp.concatenate(
            [jnp.full((B, vis), -1, jnp.int32), b["labels"][:, :S - vis]],
            axis=1)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced_config(arch)
    params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
    exp_S = S
    assert logits.shape == (B, exp_S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
    opt = get_optimizer(cfg.optimizer, lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = make_batch(cfg, 2, 32)
    params, state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert all(bool(jnp.isfinite(x).all())
               for x in jax.tree.leaves(params))


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "granite-moe-3b-a800m",
                                  "xlstm-1.3b", "zamba2-7b",
                                  "whisper-base", "qwen2-vl-72b"])
def test_decode_consistency(arch):
    """prefill(S-1) + decode(last token) ≈ forward logits at S-1."""
    cfg = reduced_config(arch)
    params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits_full, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(params,
                                                                batch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode uses text-only continuation (covered in "
                    "dry-run decode cells)")
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    pre["labels"] = batch["labels"][:, :S - 1]
    lg_pre, cache = jax.jit(
        lambda p, b: M.prefill(cfg, p, b, s_max=S))(params, pre)
    lg_dec, _ = jax.jit(
        lambda p, t, c: M.decode_step(cfg, p, t, c, S - 1))(
        params, batch["tokens"][:, S - 1:S], cache)
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    e_pre = float(jnp.max(jnp.abs(lg_pre - logits_full[:, S - 2]))) / scale
    e_dec = float(jnp.max(jnp.abs(lg_dec - logits_full[:, S - 1]))) / scale
    assert e_pre < 2e-2, e_pre
    assert e_dec < 2e-2, e_dec


def test_exact_assigned_configs():
    """The full configs carry the exact assigned hyperparameters."""
    spec = {
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151_936),
        "glm4-9b": (40, 4096, 32, 2, 13_696, 151_552),
        "smollm-360m": (32, 960, 15, 5, 2560, 49_152),
        "minitron-8b": (32, 4096, 32, 8, 16_384, 256_000),
        "whisper-base": (6, 512, 8, 8, 2048, 51_865),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50_304),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29_568, 152_064),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49_155),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
        "zamba2-7b": (81, 3584, 32, 32, 14_336, 32_000),
    }
    for arch, (L, D, H, KV, FF, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == D
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        assert cfg.d_ff == FF and cfg.vocab_size == V
    assert get_config("kimi-k2-1t-a32b").n_experts == 384
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("qwen2-vl-72b").mrope
    assert get_config("qwen2-1.5b").qkv_bias


def test_shape_skip_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    for arch in ARCHS:
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        if arch in ("xlstm-1.3b", "zamba2-7b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names


def test_param_counts_plausible():
    """Sanity: full configs land near their nameplate sizes."""
    expect = {"qwen2-1.5b": (1.2e9, 2.2e9),
              "glm4-9b": (8e9, 12e9),
              "smollm-360m": (0.3e9, 0.5e9),
              "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
              "zamba2-7b": (6e9, 9e9),
              "qwen2-vl-72b": (6.0e10, 8.5e10)}
    for arch, (lo, hi) in expect.items():
        n = M.count_params(get_config(arch))
        assert lo < n < hi, (arch, n)
    # MoE active params far below total
    kimi = get_config("kimi-k2-1t-a32b")
    assert M.count_active_params(kimi) < 0.1 * M.count_params(kimi)
