"""Docs stay true: intra-repo links resolve and code fences execute.

Thin tier-1 wrapper around ``tools/check_docs.py`` (the CI docs job runs
the same checker), so a PR that breaks a documented snippet or moves a
linked file goes red locally, not just in the docs lane.
"""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def _pages():
    return check_docs.default_files()


def test_docs_pages_exist():
    names = {p.name for p in _pages()}
    for required in ("architecture.md", "alto-format.md", "distributed.md",
                     "benchmarks.md", "known-issues.md", "autotuning.md",
                     "serving.md", "out-of-core.md",
                     "dynamic-tensors.md", "resilience.md"):
        assert required in names, f"docs/{required} missing"


@pytest.mark.parametrize("page", _pages(), ids=lambda p: p.name)
def test_docs_links_resolve(page):
    assert check_docs.check_links(page) == []


@pytest.mark.parametrize("page", _pages(), ids=lambda p: p.name)
def test_docs_snippets_execute(page):
    errs = check_docs.run_snippets(page)
    assert errs == [], "\n".join(errs)
