"""Budgeted plan search (core/search.py) + the hardened timing hook.

Covers the ISSUE-10 contracts:

* `ops.timing_stats` — warmup runs can never enter the sample, the
  median averages the middle pair for even n, IQR is the spread, and
  one call is exactly one `ops.timing_runs()` increment (the counter
  contract the store-hit proofs depend on), including under threads.
* search determinism — same seed + same store ⇒ identical winning
  plan; a re-run through `make_plan(tune="search")` is a store hit
  with ZERO extra timing runs.
* budget semantics — `runs_used` never exceeds the run budget and
  matches the real measurement counter; `budget_runs=0` with a warm
  model is a zero-measurement warm start.
* repair feasibility (proptest) — any mutated gene snaps into the
  feasible pool: divisors of rank, pow2 blocks within bounds, carry
  pinned for streaming pools.
* the lifted streaming-tune path — `make_plan(..., tune="search",
  device_bytes=...)` returns a searched StreamPlan and chunked
  CP-ALS / CP-APR on it are bitwise-identical to the in-core carry
  path at equal tiling (the `tests/test_outofcore.py` fence, now on a
  *searched* plan).
* JSONL experiment logging under ``$REPRO_TUNE_LOG``.

Deterministic search-behavior tests monkeypatch the timing closure
with a pure function of the candidate, so no assertion here depends
on real wall-clock rankings. Runs on the hermetic tests/proptest.py
harness.
"""
import dataclasses
import json
import math
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st

from repro.core import alto, autotune, heuristics, search
from repro.core import plan as plan_mod
from repro.core.cpals import cp_als
from repro.core.cpapr import CpaprParams, cp_apr
from repro.kernels import ops
from repro.sparse import synthetic
from repro.sparse.tensor import SparseTensor

RANK = 8
DIMS = (29, 13, 7)


@pytest.fixture
def store(tmp_path, monkeypatch):
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    monkeypatch.delenv("REPRO_TUNE_LOG", raising=False)
    monkeypatch.delenv("REPRO_DEVICE_BYTES", raising=False)
    return path


def _tensor(seed=3, dims=DIMS, nnz=150, count_data=False):
    x = synthetic.uniform_tensor(dims, nnz, seed=seed,
                                 count_data=count_data)
    return alto.build(x, n_partitions=2)


def _fake_timer(monkeypatch, fn=None):
    """Replace the measurement closures with a pure function of the
    candidate — deterministic fitness, no wall clock, no jit."""
    if fn is None:
        def fn(mp, streaming):
            t = 1e-3 * mp.r_block * (1.0 + math.log2(mp.block_m))
            if mp.traversal is heuristics.Traversal.ORIENTED_CARRY:
                t *= 0.5
            if streaming is not None:
                t *= 1.0 + 0.01 * streaming.n_chunks
            return t

    def fake_mttkrp(cand_plan, at, views, factors, mode, warmup, iters):
        return fn(cand_plan.modes[mode], cand_plan.streaming), 1e-6

    def fake_phi(cand_plan, at, view, B, factors, pi, mode, warmup,
                 iters, eps=1e-10):
        return fn(cand_plan.modes[mode], cand_plan.streaming), 1e-6

    monkeypatch.setattr(search, "_time_mttkrp", fake_mttkrp)
    monkeypatch.setattr(search, "_time_phi", fake_phi)


# ---------------------------------------------------------------------------
# ops.timing_stats: the hardened measurement primitive (satellite 1)
# ---------------------------------------------------------------------------

class TestTimingStats:
    def test_counter_contract_one_bump_per_measurement(self):
        """One timing_stats/median_time call == exactly one counted
        measurement, no matter how many warmup/iter executions run."""
        calls = []
        fn = lambda: calls.append(1)                        # noqa: E731
        for warmup, iters in [(0, 1), (1, 3), (5, 7)]:
            before = ops.timing_runs()
            ops.timing_stats(fn, warmup=warmup, iters=iters)
            assert ops.timing_runs() == before + 1
        before = ops.timing_runs()
        ops.median_time(fn, warmup=2, iters=4)
        assert ops.timing_runs() == before + 1

    def test_counter_contract_under_threads(self):
        n = 16
        before = ops.timing_runs()
        barrier = threading.Barrier(n)

        def work():
            barrier.wait()
            ops.median_time(lambda: None, warmup=0, iters=1)

        threads = [threading.Thread(target=work) for _ in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert ops.timing_runs() == before + n

    def test_warmup_runs_but_never_enters_the_sample(self, monkeypatch):
        """A pathologically slow warmup (compilation) must not move the
        reported median: the clock only ticks around timed iterations."""
        calls = {"n": 0}

        def fn():
            calls["n"] += 1

        # scripted clock: each timed iteration takes exactly 1.0s
        ticks = iter([float(i) for i in range(100)])
        monkeypatch.setattr(ops.time, "perf_counter",
                            lambda: next(ticks) * 0.5)
        median, iqr = ops.timing_stats(fn, warmup=3, iters=4)
        assert calls["n"] == 7                  # warmups DID run...
        assert median == pytest.approx(0.5)     # ...but aren't timed
        assert iqr == pytest.approx(0.0)

    def test_even_n_median_averages_middle_pair(self, monkeypatch):
        durations = iter([10.0, 1.0, 3.0, 2.0])   # sorted: 1, 2, 3, 10
        clock = {"t": 0.0}

        def fake_counter():
            return clock["t"]

        def fn():
            clock["t"] += next(durations, 0.0)

        monkeypatch.setattr(ops.time, "perf_counter", fake_counter)
        median, iqr = ops.timing_stats(fn, warmup=0, iters=4)
        assert median == pytest.approx(2.5)       # (2 + 3) / 2
        assert iqr == pytest.approx(8.0)          # q3=10, q1=2

    def test_median_time_is_the_stats_median(self):
        assert ops.median_time(lambda: None, warmup=0, iters=3) >= 0.0


# ---------------------------------------------------------------------------
# Search determinism + budget semantics (satellite 4)
# ---------------------------------------------------------------------------

class TestSearchDeterminism:
    def test_same_seed_same_store_identical_plan(self, store,
                                                 monkeypatch):
        _fake_timer(monkeypatch)
        at = _tensor()
        kw = dict(backend="pallas", interpret=True, budget_runs=10,
                  seed=7, persist=False)
        p1, r1 = search.search_plan(at, RANK, **kw)
        p2, r2 = search.search_plan(at, RANK, **kw)
        assert p1.modes == p2.modes
        assert p1.streaming == p2.streaming
        assert r1.winners == r2.winners
        assert r1.runs_used == r2.runs_used

    def test_rerun_is_a_store_hit_with_zero_timing_runs(self, store):
        at = _tensor()
        plan, rep = search.search_plan(at, RANK, backend="pallas",
                                       interpret=True, budget_runs=4,
                                       seed=0)
        assert rep.runs_used <= 4
        runs = ops.timing_runs()
        again = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                   interpret=True, tune="search", at=at)
        assert ops.timing_runs() == runs        # store hit: zero runs
        assert again.modes == plan.modes
        assert again.streaming == plan.streaming

    def test_budget_is_respected_and_matches_the_counter(self, store):
        at = _tensor()
        before = ops.timing_runs()
        _, rep = search.search_plan(at, RANK, backend="pallas",
                                    interpret=True, budget_runs=5,
                                    seed=1, persist=False)
        assert rep.runs_used <= 5
        assert ops.timing_runs() - before == rep.runs_used

    def test_tie_breaks_keep_the_static_gene(self, store, monkeypatch):
        """Constant fitness everywhere: the deterministic tie-break must
        crown the static analytic gene (pool index 0), proving the
        winner is never worse than the static choice under the
        measurement."""
        _fake_timer(monkeypatch, fn=lambda mp, s: 1e-3)
        at = _tensor()
        plan, rep = search.search_plan(at, RANK, backend="pallas",
                                       interpret=True, budget_runs=12,
                                       seed=3, persist=False)
        assert all(w.is_static for w in rep.winners)
        static = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                    interpret=True)
        assert plan.modes == static.modes

    def test_zero_budget_cold_store_returns_static(self, store,
                                                   monkeypatch):
        _fake_timer(monkeypatch)
        at = _tensor()
        plan, rep = search.search_plan(at, RANK, backend="pallas",
                                       interpret=True, budget_runs=0,
                                       seed=0, persist=False)
        assert rep.runs_used == 0
        assert not rep.warm_start               # no model to warm-start
        assert all(w.is_static for w in rep.winners)

    def test_zero_budget_warm_model_transfers_across_tensors(
            self, store, monkeypatch):
        """Measurements on tensor A train the cost model; tensor B then
        gets a model-picked plan with ZERO measurements (the
        feature-similarity transfer the ISSUE names)."""
        _fake_timer(monkeypatch)
        a = _tensor(seed=3, nnz=150)
        search.search_plan(a, RANK, backend="pallas", interpret=True,
                           budget_runs=max(12, search.MODEL_MIN_SAMPLES),
                           seed=0)
        b = _tensor(seed=9, dims=(31, 11, 6), nnz=200)
        runs = ops.timing_runs()
        plan, rep = search.search_plan(b, RANK, backend="pallas",
                                       interpret=True, budget_runs=0,
                                       seed=0)
        assert ops.timing_runs() == runs
        assert rep.runs_used == 0
        assert rep.model_samples >= search.MODEL_MIN_SAMPLES
        assert rep.warm_start
        assert plan.modes                       # a full, feasible plan
        for mp in plan.modes:
            assert RANK % mp.r_block == 0

    def test_exhaustive_runs_train_the_model_too(self, store):
        at = _tensor()
        autotune.tune_plan(at, RANK, backend="pallas", interpret=True,
                           max_candidates=6)
        plans = autotune.load_store()
        model = search.model_from_store(plans)
        assert model.n_samples >= 6             # every candidate sampled
        assert model.ready == (model.n_samples
                               >= search.MODEL_MIN_SAMPLES)

    def test_neighbor_records_rank_by_meta_distance(self):
        def rec(dims, nnz, rank):
            return {"dims": list(dims), "nnz": nnz, "rank": rank,
                    "modes": [{}], "tuned": {"objective": "mttkrp"}}
        at = _tensor()                           # (29, 13, 7), nnz=150
        plans = {
            "near": rec((30, 12, 8), 160, RANK),
            "far": rec((4096, 2048, 1024), 100000, RANK),
            "wrong_ndim": rec((30, 12), 160, RANK),
            "wrong_obj": {**rec((29, 13, 7), 150, RANK),
                          "tuned": {"objective": "phi"}},
        }
        out = search.store_neighbors(plans, at.meta, RANK,
                                     objective="mttkrp", limit=2)
        assert out[0] is plans["near"]
        assert plans["wrong_ndim"] not in out
        assert plans["wrong_obj"] not in out


# ---------------------------------------------------------------------------
# Repair feasibility + pools (proptest harness)
# ---------------------------------------------------------------------------

class TestRepairFeasibility:
    POOLS = {}

    def _pool(self, streaming):
        if streaming not in self.POOLS:
            at = _tensor()
            self.POOLS[streaming] = search.mode_pool(
                at.meta, 0, RANK, backend="pallas",
                vmem_limit=plan_mod.VMEM_BYTES, streaming=streaming)
        return self.POOLS[streaming]

    @settings(max_examples=40, deadline=None)
    @given(trav=st.sampled_from(list(heuristics.Traversal)),
           rb=st.integers(1, 64), bm=st.integers(1, 4096),
           streaming=st.booleans())
    def test_any_mutation_repairs_into_the_feasible_pool(
            self, trav, rb, bm, streaming):
        pool = self._pool(streaming)
        i = search.repair(pool, trav, rb, bm)
        g = pool[i]
        assert 0 <= i < len(pool)
        assert RANK % g.r_block == 0
        assert plan_mod.MIN_BLOCK_M <= g.block_m <= plan_mod.MAX_BLOCK_M
        assert g.block_m & (g.block_m - 1) == 0      # power of two
        if streaming:
            assert g.traversal is heuristics.Traversal.ORIENTED_CARRY

    def test_exact_pool_member_snaps_to_itself(self):
        pool = self._pool(False)
        for i, g in enumerate(pool):
            j = search.repair(pool, g.traversal, g.r_block, g.block_m)
            assert pool[j] == g or (
                search._gene_distance(pool[j], g.traversal, g.r_block,
                                      g.block_m) == 0.0)

    def test_streaming_pool_pins_carry_and_keeps_static_first(self):
        at = _tensor()
        pool = search.mode_pool(at.meta, 0, RANK, backend="pallas",
                                vmem_limit=0, streaming=True)
        # vmem_limit=0: the carry gate is unsatisfiable, yet the static
        # force-carry gene survives (advisory budget, as in make_plan)
        assert len(pool) == 1
        assert pool[0].traversal is heuristics.Traversal.ORIENTED_CARRY
        static = plan_mod.static_mode_plan(at.meta, 0, RANK,
                                           vmem_limit=0, force_carry=True)
        assert pool[0] == static

    def test_chunk_ladder_aligned_descending_feasible(self):
        at = _tensor()
        budget = (plan_mod.streaming_resident_bytes(at.meta, RANK)
                  + 2 * plan_mod.stream_elem_bytes(at.meta) * 64)
        ladder = search.chunk_ladder(at.meta, RANK, budget, align=8)
        assert ladder
        assert ladder[0] == plan_mod.choose_chunk_m(at.meta, RANK,
                                                    budget, align=8)
        assert all(c % 8 == 0 for c in ladder)
        assert all(a > b for a, b in zip(ladder, ladder[1:]))
        assert all(plan_mod.chunk_hbm_bytes(at.meta, c, RANK) <= max(
            budget, plan_mod.chunk_hbm_bytes(at.meta, ladder[0], RANK))
            for c in ladder)

    def test_gene_features_shape_and_finiteness(self):
        at = _tensor()
        for trav in heuristics.Traversal:
            f = search.gene_features(at.meta, RANK, 0, trav, 4, 64,
                                     chunk_m=128)
            assert len(f) == search.N_FEATURES
            assert all(np.isfinite(f))


# ---------------------------------------------------------------------------
# JSONL experiment log (satellite 2)
# ---------------------------------------------------------------------------

class TestTuneLog:
    def test_log_disabled_without_env(self, store, monkeypatch):
        logger = search.TuneLogger()
        assert not logger.enabled
        logger.write("measure", x=1)            # no-op, no crash

    def test_every_measurement_is_logged(self, store, tmp_path,
                                         monkeypatch):
        log = tmp_path / "tune.jsonl"
        monkeypatch.setenv("REPRO_TUNE_LOG", str(log))
        _fake_timer(monkeypatch)
        at = _tensor()
        _, rep = search.search_plan(at, RANK, backend="pallas",
                                    interpret=True, budget_runs=6, seed=0)
        lines = [json.loads(l) for l in
                 log.read_text().strip().splitlines()]
        events = [l["event"] for l in lines]
        assert events[0] == "search_start"
        assert events[-1] == "search_end"
        measures = [l for l in lines if l["event"] == "measure"]
        assert len(measures) == rep.runs_used
        for m in measures:
            for field in ("generation", "mode", "traversal", "r_block",
                          "block_m", "measured_us", "iqr_us",
                          "budget_runs_used", "budget_seconds_used"):
                assert field in m, field
        spent = [m["budget_runs_used"] for m in measures]
        assert spent == sorted(spent) and spent[-1] == rep.runs_used
        end = lines[-1]
        assert end["runs_used"] == rep.runs_used
        assert len(end["winners"]) == len(DIMS)

    def test_predicted_vs_measured_once_model_is_warm(self, store,
                                                      tmp_path,
                                                      monkeypatch):
        log = tmp_path / "tune.jsonl"
        monkeypatch.setenv("REPRO_TUNE_LOG", str(log))
        _fake_timer(monkeypatch)
        at = _tensor()
        search.search_plan(at, RANK, backend="pallas", interpret=True,
                           budget_runs=max(10, search.MODEL_MIN_SAMPLES),
                           seed=0)
        search.search_plan(_tensor(seed=8), RANK, backend="pallas",
                           interpret=True, budget_runs=4, seed=0)
        measures = [json.loads(l) for l in
                    log.read_text().strip().splitlines()
                    if json.loads(l)["event"] == "measure"]
        # the second (warm-store) search logs model predictions next to
        # measurements — the greppable regression signal
        assert any(m["predicted_us"] is not None for m in measures)


# ---------------------------------------------------------------------------
# The lifted streaming-tune path (satellite 4): searched chunked plans
# run CP-ALS / CP-APR bitwise-identically to in-core at equal tiling
# ---------------------------------------------------------------------------

def _stream_tensor(seed, count_data=True):
    """Duplicates-heavy mode-0 layout (the adversarial chunk shape)."""
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 8, size=DIMS[0])
    counts[3] = 4 * plan_mod.MIN_BLOCK_M
    rows = np.repeat(np.arange(DIMS[0], dtype=np.int32), counts)
    coords = np.stack(
        [rows] + [rng.integers(0, I, size=rows.shape[0]).astype(np.int32)
                  for I in DIMS[1:]], axis=1)
    values = rng.integers(1, 5, size=rows.shape[0]).astype(np.float32) \
        if count_data else rng.standard_normal(rows.shape[0]) \
        .astype(np.float32)
    return alto.build(SparseTensor(DIMS, coords, values), n_partitions=2)


class TestStreamingSearch:
    R = 4

    def _searched_plan(self, at, store, objective="mttkrp", budget=6):
        meta = at.meta
        budget_bytes = (plan_mod.streaming_resident_bytes(meta, self.R)
                        + 2 * plan_mod.stream_elem_bytes(meta)
                        * (2 * plan_mod.MIN_BLOCK_M))
        plan = plan_mod.make_plan(
            meta, self.R, backend="pallas", interpret=True, vmem_limit=0,
            device_bytes=budget_bytes, tune="search",
            tune_objective=objective, at=at, search_budget=budget)
        assert plan.streaming is not None
        assert plan.streaming.n_chunks >= 2
        return plan

    def test_search_returns_multi_chunk_streaming_plan(self, store):
        at = _stream_tensor(seed=5)
        plan = self._searched_plan(at, store)
        align = max(m.block_m for m in plan.modes)
        assert plan.streaming.chunk_m % align == 0
        assert plan.streaming.n_chunks == plan_mod.chunk_count(
            at.meta, plan.streaming.chunk_m)
        assert all(m.traversal is heuristics.Traversal.ORIENTED_CARRY
                   for m in plan.modes)
        # the winner persisted: a second process-equivalent lookup is
        # measurement-free and identical
        runs = ops.timing_runs()
        again = plan_mod.make_plan(
            at.meta, self.R, backend="pallas", interpret=True,
            vmem_limit=0, device_bytes=plan.streaming.device_bytes,
            tune="auto")
        assert ops.timing_runs() == runs
        assert again.modes == plan.modes
        assert again.streaming == plan.streaming

    def test_cp_als_bitwise_on_searched_plan(self, store):
        at = _stream_tensor(seed=6)
        plan_s = self._searched_plan(at, store)
        plan_i = dataclasses.replace(plan_s, streaming=None)
        rs = cp_als(at, self.R, n_iters=3, plan=plan_s,
                    views=plan_mod.build_views(at, plan_s))
        ri = cp_als(at, self.R, n_iters=3, plan=plan_i,
                    views=plan_mod.build_views(at, plan_i))
        assert rs.fits == ri.fits
        assert jnp.array_equal(rs.lam, ri.lam)
        for a, b in zip(rs.factors, ri.factors):
            assert jnp.array_equal(a, b)

    def test_cp_apr_bitwise_on_searched_plan(self, store):
        at = _stream_tensor(seed=7)
        plan_s = self._searched_plan(at, store, objective="phi")
        plan_i = dataclasses.replace(plan_s, streaming=None)
        p = CpaprParams(k_max=2, l_max=3)
        rs = cp_apr(at, self.R, params=p, plan=plan_s,
                    views=plan_mod.build_views(at, plan_s))
        ri = cp_apr(at, self.R, params=p, plan=plan_i,
                    views=plan_mod.build_views(at, plan_i))
        assert rs.kkt_violations == ri.kkt_violations
        assert jnp.array_equal(rs.lam, ri.lam)
        for a, b in zip(rs.factors, ri.factors):
            assert jnp.array_equal(a, b)

    def test_streaming_search_determinism(self, store, monkeypatch):
        _fake_timer(monkeypatch)
        at = _stream_tensor(seed=8)
        budget_bytes = (plan_mod.streaming_resident_bytes(at.meta, self.R)
                        + 2 * plan_mod.stream_elem_bytes(at.meta) * 16)
        kw = dict(backend="pallas", interpret=True, vmem_limit=0,
                  device_bytes=budget_bytes, budget_runs=8, seed=11,
                  persist=False)
        p1, r1 = search.search_plan(at, self.R, **kw)
        p2, r2 = search.search_plan(at, self.R, **kw)
        assert p1.modes == p2.modes
        assert p1.streaming == p2.streaming
        assert r1.chunk_m == r2.chunk_m
        assert p1.streaming.chunk_m == r1.chunk_m

    def test_drivers_accept_tune_search(self, store, monkeypatch):
        """`cp_als(..., tune="search")` end to end on an in-core tensor:
        the driver path threads the mode through make_plan (fake-timed —
        the default budget is sized for the real space, not a test)."""
        _fake_timer(monkeypatch)
        at = _tensor(seed=4, nnz=80)
        res = cp_als(at, 4, n_iters=2, tune="search")
        assert res.plan is not None
        assert len(res.fits) >= 1
