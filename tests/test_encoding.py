"""ALTO encoding: paper-example exactness + hypothesis round-trip laws."""
import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.core import encoding as E
from repro.core import alto
from repro.sparse.tensor import SparseTensor

PAPER_DIMS = (4, 8, 2)
PAPER_COORDS = np.array([[0, 3, 0], [1, 0, 0], [1, 6, 1], [2, 2, 1],
                         [3, 1, 1], [3, 4, 0]], dtype=np.int32)


def test_paper_example_linearization():
    """Fig. 4/7: the six nonzeros land at line positions {2,15,20,25,42,51}
    and 2-partitioning yields segments [2-20] / [25-51] with the paper's
    bounding boxes."""
    enc = E.make_encoding(PAPER_DIMS)
    assert enc.mode_bits == (2, 3, 1)
    assert enc.total_bits == 6
    w = E.linearize_np(enc, PAPER_COORDS)
    assert sorted(int(x[0]) for x in w) == [2, 15, 20, 25, 42, 51]

    x = SparseTensor(PAPER_DIMS, PAPER_COORDS,
                     np.arange(1, 7, dtype=np.float32))
    at = alto.build(x, n_partitions=2)
    ps = np.asarray(at.part_start)
    pe = np.asarray(at.part_end)
    assert ps[0].tolist() == [0, 0, 0] and pe[0].tolist() == [3, 3, 1]
    assert ps[1].tolist() == [1, 2, 0] and pe[1].tolist() == [3, 6, 1]


def test_paper_storage_equations():
    """Eq. 1-3 on the paper example with byte addressing: COO 3 bytes,
    ALTO 1 byte (3x compression), Z-Morton needs 9 bits."""
    enc = E.make_encoding(PAPER_DIMS)
    assert enc.storage_bits_alto(word_bits=8) == 8
    assert enc.storage_bits_coo(word_bits=8) == 24
    assert enc.storage_bits_sfc() == 9


dims_strategy = st.lists(st.integers(1, 300), min_size=1, max_size=6)


@settings(max_examples=60, deadline=None)
@given(dims=dims_strategy, seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 200))
def test_roundtrip_property(dims, seed, n):
    """linearize ∘ delinearize == id for arbitrary shapes/coords."""
    rng = np.random.default_rng(seed)
    coords = np.stack([rng.integers(0, I, size=n) for I in dims],
                      axis=1).astype(np.int32)
    enc = E.make_encoding(dims)
    w = E.linearize_np(enc, coords)
    back = E.delinearize_np(enc, w)
    np.testing.assert_array_equal(back, coords)


@settings(max_examples=40, deadline=None)
@given(dims=dims_strategy)
def test_bit_budget_property(dims):
    """Every mode gets exactly ceil(log2 I) bits; total == sum (Eq. 1);
    ALTO index bits <= COO bits <= SFC bits for any shape."""
    enc = E.make_encoding(dims)
    for n, I in enumerate(dims):
        expect = (I - 1).bit_length() if I > 1 else 0
        assert enc.mode_bits[n] == expect
    assert enc.total_bits == sum(enc.mode_bits)
    if enc.total_bits > 0:
        assert enc.storage_bits_alto(64) <= enc.storage_bits_coo(64)


@settings(max_examples=40, deadline=None)
@given(dims=dims_strategy, seed=st.integers(0, 2**31 - 1))
def test_order_preserving_within_mode(dims, seed):
    """Within a mode (others fixed), the linearized index is monotone —
    the encoding preserves spatial order on every axis."""
    if all(d == 1 for d in dims):
        return
    rng = np.random.default_rng(seed)
    n_axis = int(rng.integers(0, len(dims)))
    if dims[n_axis] < 2:
        return
    base = np.array([[rng.integers(0, I) for I in dims]], dtype=np.int32)
    a = base.copy()
    b = base.copy()
    lo, hi = sorted(rng.choice(dims[n_axis], size=2, replace=False))
    a[0, n_axis], b[0, n_axis] = lo, hi
    enc = E.make_encoding(dims)
    wa = E.linearize_np(enc, a)[0]
    wb = E.linearize_np(enc, b)[0]
    # multiword compare: most significant word last
    assert tuple(wa[::-1].tolist()) < tuple(wb[::-1].tolist())


def test_mode_masks_disjoint_and_complete():
    enc = E.make_encoding((100, 37, 5, 2))
    masks = enc.mode_masks()
    acc = np.zeros(enc.n_words, dtype=np.uint64)
    for m in masks:
        assert np.all((acc & m.astype(np.uint64)) == 0)
        acc |= m.astype(np.uint64)
    total_set = sum(int(bin(int(w)).count("1")) for w in acc)
    assert total_set == enc.total_bits


def test_sorted_after_build():
    from repro.sparse import synthetic
    x = synthetic.uniform_tensor((64, 64, 64), 5000, seed=1)
    at = alto.build(x, n_partitions=4)
    w = np.asarray(at.words)
    key = tuple(w[:, i] for i in range(w.shape[1] - 1, -1, -1))
    as_tuple = list(zip(*[k.tolist() for k in key]))
    assert as_tuple == sorted(as_tuple)
