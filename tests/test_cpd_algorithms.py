"""CP-ALS and CP-APR system behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alto, cpals, cpapr, heuristics
from repro.sparse import synthetic


class TestCpals:
    def test_recovers_planted_model_warm_start(self):
        x, tf = synthetic.sparse_lowrank((30, 40, 25), rank=4,
                                         col_support=0.25, seed=1)
        at = alto.build(x, n_partitions=4)
        rng = np.random.default_rng(0)
        init = [jnp.asarray(A + 0.05 * rng.standard_normal(
            A.shape).astype(np.float32)) for A in tf]
        res = cpals.cp_als(at, rank=4, n_iters=100, tol=1e-9, factors=init)
        assert res.fits[-1] > 0.99

    def test_fit_monotone_from_random_init(self):
        x, _ = synthetic.sparse_lowrank((25, 30, 20), rank=3,
                                        col_support=0.3, seed=2)
        at = alto.build(x, n_partitions=4)
        res = cpals.cp_als(at, rank=5, n_iters=25, tol=0, seed=3)
        fits = np.asarray(res.fits)
        assert (np.diff(fits) > -1e-3).all(), fits

    def test_dense_rank_exact(self):
        rng = np.random.default_rng(0)
        fs = [rng.standard_normal((12, 3)).astype(np.float32)
              for _ in range(3)]
        from repro.sparse.tensor import from_dense
        x = from_dense(np.einsum("ar,br,cr->abc", *fs))
        at = alto.build(x, n_partitions=2)
        res = cpals.cp_als(at, rank=3, n_iters=150, tol=1e-10, seed=1)
        assert res.fits[-1] > 0.999

    def test_reconstruct_values(self):
        x, tf = synthetic.sparse_lowrank((20, 20, 20), rank=3,
                                         col_support=0.4, seed=4)
        at = alto.build(x, n_partitions=2)
        rng = np.random.default_rng(0)
        init = [jnp.asarray(A + 0.02 * rng.standard_normal(
            A.shape).astype(np.float32)) for A in tf]
        res = cpals.cp_als(at, rank=3, n_iters=60, tol=1e-10, factors=init)
        vals = cpals.reconstruct_values(jnp.asarray(x.coords), res.lam,
                                        res.factors)
        err = float(jnp.max(jnp.abs(vals - jnp.asarray(x.values))))
        assert err < 0.05 * float(jnp.max(jnp.abs(jnp.asarray(x.values))))


class TestCpapr:
    @pytest.fixture(scope="class")
    def count_tensor(self):
        x, _ = synthetic.lowrank_count((25, 30, 20), rank=3,
                                       nnz_target=4000, seed=5)
        return alto.build(x, n_partitions=4)

    def test_loglikelihood_increases(self, count_tensor):
        r = cpapr.cp_apr(count_tensor, rank=3, seed=3, track_ll=True,
                         params=cpapr.CpaprParams(k_max=10))
        ll = r.log_likelihoods
        assert ll[-1] > ll[0]
        # tail should be (almost) monotone
        assert all(b - a > -1.0 for a, b in zip(ll[3:], ll[4:]))

    def test_factors_nonnegative_and_normalized(self, count_tensor):
        r = cpapr.cp_apr(count_tensor, rank=3, seed=3,
                         params=cpapr.CpaprParams(k_max=6))
        for A in r.factors:
            assert float(jnp.min(A)) >= 0.0
            np.testing.assert_allclose(np.asarray(jnp.sum(A, axis=0)),
                                       1.0, rtol=1e-3)

    def test_pre_equals_otf(self, count_tensor):
        """ALTO-PRE and ALTO-OTF are the same math (paper §4.3)."""
        a = cpapr.cp_apr(count_tensor, rank=3, seed=3, pi_policy="pre",
                         params=cpapr.CpaprParams(k_max=4))
        b = cpapr.cp_apr(count_tensor, rank=3, seed=3, pi_policy="otf",
                         params=cpapr.CpaprParams(k_max=4))
        for A, B in zip(a.factors, b.factors):
            np.testing.assert_allclose(np.asarray(A), np.asarray(B),
                                       atol=1e-5)

    def test_kkt_violation_decreases(self, count_tensor):
        r = cpapr.cp_apr(count_tensor, rank=3, seed=3,
                         params=cpapr.CpaprParams(k_max=10))
        kkt = r.kkt_violations
        assert kkt[-1] < kkt[0]

    def test_poisson_model_mass(self, count_tensor):
        """After convergence Σλ ≈ ΣX (Poisson total-mass identity)."""
        r = cpapr.cp_apr(count_tensor, rank=3, seed=3,
                         params=cpapr.CpaprParams(k_max=10))
        total = float(jnp.sum(count_tensor.values))
        assert abs(float(jnp.sum(r.lam)) - total) / total < 0.05


class TestHeuristics:
    def test_traversal_choice(self):
        x = synthetic.zipf_tensor((40, 24, 16), 30_000, a=1.1, seed=1)
        at = alto.build(x, n_partitions=4)
        # dense-ish tensor -> high reuse -> recursive everywhere
        for mode in range(3):
            assert heuristics.choose_traversal(at.meta, mode) is \
                heuristics.Traversal.RECURSIVE

        x2 = synthetic.uniform_tensor((2**16, 2**16, 2**16), 5000, seed=1)
        at2 = alto.build(x2, n_partitions=4)
        for mode in range(3):
            assert heuristics.choose_traversal(at2.meta, mode) is \
                heuristics.Traversal.OUTPUT_ORIENTED

    def test_reuse_classes(self):
        assert heuristics.classify_reuse(10.0) == "high"
        assert heuristics.classify_reuse(6.0) == "medium"
        assert heuristics.classify_reuse(2.0) == "limited"

    def test_pi_policy(self):
        x = synthetic.uniform_tensor((2**15, 2**15, 2**15), 4000, seed=2)
        at = alto.build(x, n_partitions=2)
        # hyper-sparse + big factors + tiny fast memory -> PRE
        pol = heuristics.choose_pi_policy(at.meta, rank=64,
                                          fast_mem_bytes=1024)
        assert pol is heuristics.PiPolicy.PRE
        # high reuse -> OTF regardless
        x2 = synthetic.zipf_tensor((64, 64, 64), 40_000, a=1.1, seed=2)
        at2 = alto.build(x2, n_partitions=2)
        assert heuristics.choose_pi_policy(at2.meta, rank=16) is \
            heuristics.PiPolicy.OTF
