"""Incremental-ingest test layer (`core.ingest` + friends).

Pins the PR's acceptance contracts:

* `ingest.append_delta` is BIT-IDENTICAL to the from-scratch host
  rebuild (`alto.merge_reference` — numpy `build` over the merged COO)
  on adversarial layouts and random property cases, under both duplicate
  policies: stream words, values, partition boxes, meta, and every
  oriented view;
* the jitted merge core has zero host callbacks and traces once per
  static merge meta;
* view invalidation is surgical — per (fingerprint, mode), with the
  `invalidated` counter; a no-op append or a re-tile drops nothing and
  keeps hitting, a real append costs at most ONE new view build per
  touched mode;
* `stream.append_stream` updates host/memmap streams in place (atomic
  respill — old maps stay readable);
* warm-start CP-ALS/CP-APR converge in fewer sweeps than cold on a
  perturbed tensor, and extent-growth warm starts match cold fits;
* a 16-thread append/read stress (mirroring `test_outofcore.py`'s cache
  stress) keeps every thread's merge bitwise and every read consistent.

Runs on the hermetic `tests/proptest.py` harness (no hypothesis in the
offline image).
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st

from repro.core import alto, ingest
from repro.core import encoding as E
from repro.core import stream as stream_mod
from repro.core import views as views_mod
from repro.core.cpals import cp_als
from repro.core.cpapr import CpaprParams, cp_apr
from repro.sparse.tensor import SparseTensor

DIMS = (6, 7, 8)


@pytest.fixture(scope="module", autouse=True)
def _release_jit_footprint():
    """This file compiles O(100) small one-off executables (one per
    random merge meta); release them at module teardown so the many
    much larger compiles later in the suite don't inherit the JIT-code
    footprint."""
    yield
    views_mod.cache_clear()
    jax.clear_caches()


def _random_tensor(dims, nnz, seed=0, dup_frac=0.0, lo=0):
    rng = np.random.default_rng(seed)
    coords = np.stack([rng.integers(lo, d, nnz) for d in dims],
                      axis=1).astype(np.int32)
    if dup_frac and nnz > 4:
        k = max(1, int(nnz * dup_frac))
        coords[-k:] = coords[:k]
    values = rng.standard_normal(nnz).astype(np.float32)
    return SparseTensor(tuple(dims), coords, values)


def _delta(dims, D, seed=0, lo=0, hi=None):
    rng = np.random.default_rng(seed)
    hi = list(hi or dims)
    coords = np.stack([rng.integers(lo, h, D) for h in hi],
                      axis=1).astype(np.int32)
    values = rng.standard_normal(D).astype(np.float32)
    return coords, values


def _lowrank_tensor(dims, rank, nnz, seed=0, count_data=False):
    """Low-rank-structured values: warm starts only help when the model
    actually fits, so the regression tests need fittable tensors."""
    rng = np.random.default_rng(seed)
    fac = [rng.uniform(0.1, 1.0, (d, rank)) for d in dims]
    coords = np.stack([rng.integers(0, d, nnz) for d in dims],
                      axis=1).astype(np.int32)
    v = np.ones(nnz)
    for m, A in enumerate(fac):
        v = v * A[coords[:, m]].sum(axis=1)
    if count_data:
        v = np.maximum(1, np.round(v))
    return SparseTensor(tuple(dims), coords, v.astype(np.float32))


def _assert_tensor_bitwise(got: alto.AltoTensor, ref: alto.AltoTensor):
    assert got.meta == ref.meta
    np.testing.assert_array_equal(np.asarray(got.words),
                                  np.asarray(ref.words))
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.part_start),
                                  np.asarray(ref.part_start))
    np.testing.assert_array_equal(np.asarray(got.part_end),
                                  np.asarray(ref.part_end))


def _assert_view_bitwise(got: alto.AltoTensor, ref: alto.AltoTensor):
    for mode in range(len(ref.dims)):
        dv = alto.oriented_view_device(got, mode)
        hv = alto.oriented_view(ref, mode)
        for f in ("rows", "words", "values", "perm"):
            np.testing.assert_array_equal(np.asarray(getattr(dv, f)),
                                          np.asarray(getattr(hv, f)))


# ---------------------------------------------------------------------------
# merge parity: adversarial layouts x both policies
# ---------------------------------------------------------------------------

# Bit-interleaved keys are not lexicographic, but componentwise dominance
# is order-preserving: if every delta coordinate < every resident one per
# mode, every delta key sorts strictly before the resident stream.
ADVERSARIAL = {
    "empty_delta": dict(M=40, D=0),
    "empty_resident": dict(M=0, D=12),
    "both_empty": dict(M=0, D=0),
    "delta_entirely_before": dict(M=40, D=10, res_lo=4, d_hi=(2, 2, 2)),
    "delta_entirely_after": dict(M=40, D=10, res_hi=(2, 2, 2), d_lo=4),
    "cross_duplicates": dict(M=40, D=12, cross=5, dup_frac=0.3),
    "dup_heavy_delta": dict(M=20, D=30, cross=10, dup_frac=0.5),
    "extent_growth": dict(M=40, D=12, grow=(3, 0, 2)),
    "two_word_encoding": dict(M=60, D=20, dims=(300, 300, 300, 300)),
    "single_partition": dict(M=25, D=9, L=1),
    "more_partitions_than_nnz": dict(M=3, D=2, L=16),
}


def _adversarial_case(name, policy):
    c = ADVERSARIAL[name]
    dims = c.get("dims", DIMS)
    res_dims = c.get("res_hi", dims)
    x = _random_tensor(res_dims, c["M"], seed=hash(name) % 1000,
                       dup_frac=c.get("dup_frac", 0.0),
                       lo=c.get("res_lo", 0))
    x = SparseTensor(tuple(dims), x.coords, x.values)   # full extents
    L = c.get("L", 4)
    at = alto.build_device(x, n_partitions=L)
    grow = c.get("grow")
    d_hi = (tuple(d + g for d, g in zip(dims, grow)) if grow
            else c.get("d_hi", dims))
    coords, values = _delta(dims, c["D"], seed=hash(name) % 1000 + 7,
                            lo=c.get("d_lo", 0), hi=d_hi)
    if c.get("cross") and c["M"] and c["D"]:
        k = min(c["cross"], c["D"], c["M"])
        coords[:k] = x.coords[:k]                       # resident dups
    return at, coords, values


@pytest.mark.parametrize("policy", ingest.POLICIES)
@pytest.mark.parametrize("name", sorted(ADVERSARIAL))
def test_merge_parity_adversarial(name, policy):
    at, coords, values = _adversarial_case(name, policy)
    got = ingest.append_delta(at, coords, values, policy=policy)
    ref = alto.merge_reference(at, coords, values, policy=policy)
    _assert_tensor_bitwise(got, ref)
    _assert_view_bitwise(got, ref)


@settings(max_examples=30, deadline=None)
@given(ndim=st.integers(2, 4), side=st.integers(2, 40),
       m=st.integers(0, 60), d=st.integers(0, 25),
       grow=st.integers(0, 5), L=st.integers(1, 6),
       policy=st.sampled_from(ingest.POLICIES),
       seed=st.integers(0, 2**31 - 1))
def test_merge_parity_property(ndim, side, m, d, grow, L, policy, seed):
    rng = np.random.default_rng(seed)
    dims = tuple(int(rng.integers(2, side + 1)) for _ in range(ndim))
    x = _random_tensor(dims, m, seed=seed,
                       dup_frac=float(rng.random() * 0.4))
    at = alto.build_device(x, n_partitions=L)
    hi = tuple(dd + (int(rng.integers(0, grow + 1)) if grow else 0)
               for dd in dims)
    coords, values = _delta(dims, d, seed=seed + 1, hi=hi)
    if m and d:
        k = int(rng.integers(0, min(m, d) + 1))
        coords[:k] = x.coords[:k]
    got = ingest.append_delta(at, coords, values, policy=policy)
    ref = alto.merge_reference(at, coords, values, policy=policy)
    _assert_tensor_bitwise(got, ref)


def test_last_policy_masks_to_last_write():
    """Last-write semantics end to end: re-writing a coordinate leaves
    exactly the new value live (old occurrence masked to 0)."""
    x = _random_tensor(DIMS, 20, seed=5)
    at = alto.build_device(x, n_partitions=4)
    target = x.coords[3]
    got = ingest.append_delta(at, target[None, :], [2.5], policy="last")
    back = alto.to_sparse(got)
    match = np.all(back.coords == target, axis=1)
    vals = np.sort(back.values[match])
    assert vals[-1] == np.float32(2.5) and np.all(vals[:-1] == 0.0)


def test_append_chain_matches_single_rebuild():
    """Three chained appends == one host rebuild of all three batches."""
    x = _random_tensor(DIMS, 30, seed=9)
    at = alto.build_device(x, n_partitions=4)
    ref = at
    for i in range(3):
        coords, values = _delta(DIMS, 6, seed=20 + i)
        at = ingest.append_delta(at, coords, values)
        ref = alto.merge_reference(ref, coords, values)
    _assert_tensor_bitwise(at, ref)


def test_append_linearized_matches_append_delta():
    x = _random_tensor(DIMS, 30, seed=13)
    at = alto.build_device(x, n_partitions=4)
    coords, values = _delta(DIMS, 8, seed=14)
    enc = E.make_encoding(DIMS)
    words = E.linearize_np(enc, coords)
    got = ingest.append_linearized(at, words, values, DIMS)
    ref = ingest.append_delta(at, coords, values)
    _assert_tensor_bitwise(got, ref)


def test_dims_override_validation():
    x = _random_tensor(DIMS, 10, seed=1)
    at = alto.build_device(x, n_partitions=2)
    coords, values = _delta(DIMS, 4, seed=2)
    with pytest.raises(ValueError, match="does not cover"):
        ingest.append_delta(at, coords, values, dims=(2, 2, 2))
    with pytest.raises(ValueError, match="policy"):
        ingest.append_delta(at, coords, values, policy="first")


# ---------------------------------------------------------------------------
# jit contracts: zero host callbacks, once-per-merge-meta tracing
# ---------------------------------------------------------------------------

def test_merge_core_has_zero_host_callbacks():
    x = _random_tensor(DIMS, 40, seed=11)
    at = alto.build_device(x, n_partitions=4)
    coords, values = _delta(DIMS, 12, seed=12)
    grown = tuple(d + 2 for d in DIMS)   # growth path re-encodes in-jit
    for dims in (DIMS, grown):
        enc = E.make_encoding(dims)
        fn = ingest._merge_device_fn(
            at.meta.enc, enc, 4, at.nnz, at.words.shape[0],
            coords.shape[0], "last", True, jnp.float32, "coords")
        jaxpr = jax.make_jaxpr(fn)(at.words, at.values,
                                   jnp.asarray(coords),
                                   jnp.asarray(values))
        assert "callback" not in str(jaxpr)


def test_merge_traces_once_per_static_meta():
    x1 = _random_tensor(DIMS, 40, seed=21)
    x2 = _random_tensor(DIMS, 40, seed=22)
    at1 = alto.build_device(x1, n_partitions=4)
    at2 = alto.build_device(x2, n_partitions=4)
    coords, values = _delta(DIMS, 8, seed=23)
    ingest.append_delta(at1, coords, values)
    before = alto.device_ingest_traces()["merge"]
    ingest.append_delta(at2, coords, values)       # same merge meta
    assert alto.device_ingest_traces()["merge"] == before
    ingest.append_delta(at1, coords[:5], values[:5])   # new D: retrace
    assert alto.device_ingest_traces()["merge"] == before + 1


# ---------------------------------------------------------------------------
# surgical view invalidation
# ---------------------------------------------------------------------------

class TestViewInvalidation:
    def _tensor(self, seed=31, L=4):
        x = _random_tensor((10, 9, 8), 40, seed=seed)
        return alto.build_device(x, n_partitions=L), x

    def test_invalidate_single_mode_counter(self):
        views_mod.cache_clear()
        at, _ = self._tensor()
        for m in range(3):
            views_mod.get_view(at, m)
        b0 = views_mod.cache_stats()["builds"]
        assert views_mod.invalidate(at, modes=(0,)) == 1
        s = views_mod.cache_stats()
        assert s["invalidated"] == 1
        views_mod.get_view(at, 1)                  # untouched mode: hit
        assert views_mod.cache_stats()["builds"] == b0
        views_mod.get_view(at, 0)                  # dropped mode: rebuild
        assert views_mod.cache_stats()["builds"] == b0 + 1
        views_mod.cache_clear()

    def test_invalidate_all_modes_default(self):
        views_mod.cache_clear()
        at, _ = self._tensor()
        for m in range(3):
            views_mod.get_view(at, m)
        assert views_mod.invalidate(at) == 3
        assert views_mod.cache_stats()["invalidated"] == 3
        views_mod.cache_clear()

    def test_retile_keeps_views_and_rebinds_meta(self):
        """Same stream re-tiled (L=4 -> L=2, same padded length): every
        view stays cached — the per-mode fingerprint excludes the
        partitioning fields — and hits carry the new meta."""
        views_mod.cache_clear()
        at4, x = self._tensor(L=4)                 # Mp = 40 both ways
        for m in range(3):
            views_mod.get_view(at4, m)
        b0 = views_mod.cache_stats()["builds"]
        at2 = alto.build_device(x, n_partitions=2)
        assert at2.words.shape == at4.words.shape
        for m in range(3):
            v = views_mod.get_view(at2, m)
            assert v.meta == at2.meta
        assert views_mod.cache_stats()["builds"] == b0
        views_mod.cache_clear()

    def test_noop_append_drops_nothing_and_hits(self):
        views_mod.cache_clear()
        at, _ = self._tensor()
        for m in range(3):
            views_mod.get_view(at, m)
        b0 = views_mod.cache_stats()["builds"]
        new = ingest.append_delta(at, np.empty((0, 3), np.int32), [])
        for m in range(3):
            views_mod.get_view(new, m)
        s = views_mod.cache_stats()
        assert s["builds"] == b0 and s["invalidated"] == 0
        views_mod.cache_clear()

    def test_append_costs_one_build_per_touched_mode(self):
        views_mod.cache_clear()
        at, _ = self._tensor()
        for m in range(3):
            views_mod.get_view(at, m)
        b0 = views_mod.cache_stats()["builds"]
        coords, values = _delta((10, 9, 8), 6, seed=33)
        new = ingest.append_delta(at, coords, values)
        # the stale entries were invalidated eagerly (content changed)
        assert views_mod.cache_stats()["invalidated"] == 3
        for m in range(3):
            views_mod.get_view(new, m)
            views_mod.get_view(new, m)             # second get: hit
        assert views_mod.cache_stats()["builds"] == b0 + 3
        views_mod.cache_clear()


# ---------------------------------------------------------------------------
# host/memmap stream append
# ---------------------------------------------------------------------------

class TestStreamAppend:
    def _pair(self):
        x = _random_tensor(DIMS, 35, seed=41)
        at = alto.build_device(x, n_partitions=4)
        coords, values = _delta(DIMS, 9, seed=42)
        new_at = ingest.append_delta(at, coords, values)
        return at, new_at

    def test_numpy_stream_append(self):
        at, new_at = self._pair()
        hs = stream_mod.host_stream(at, 0)
        got = stream_mod.append_stream(hs, new_at)
        ref = stream_mod.host_stream(new_at, 0)
        assert got.length == ref.length
        np.testing.assert_array_equal(got.words, ref.words)
        np.testing.assert_array_equal(got.values, ref.values)
        np.testing.assert_array_equal(got.rows, ref.rows)

    def test_memmap_stream_appends_in_place(self, tmp_path):
        at, new_at = self._pair()
        mm = stream_mod.to_memmap(stream_mod.host_stream(at, 0), tmp_path)
        old_words = mm.words                       # held across the respill
        old_copy = np.array(old_words)
        got = stream_mod.append_stream(mm, new_at)
        ref = stream_mod.host_stream(new_at, 0)
        assert isinstance(got.words, np.memmap)
        assert str(got.words.filename) == str(tmp_path / "words.npy")
        np.testing.assert_array_equal(np.asarray(got.words), ref.words)
        np.testing.assert_array_equal(np.asarray(got.values), ref.values)
        # atomic replace: the pre-append map still reads the old inode
        np.testing.assert_array_equal(np.asarray(old_words), old_copy)
        # reopening from disk sees the new generation
        re = stream_mod.from_memmap(tmp_path, new_at.meta, 0)
        np.testing.assert_array_equal(np.asarray(re.words), ref.words)

    def test_memmap_backed_merge_parity(self, tmp_path):
        """Adversarial satellite case: the resident tensor's stream lives
        on disk, the append still matches the host rebuild bitwise."""
        x = _random_tensor(DIMS, 30, seed=43)
        at = alto.build_device(x, n_partitions=4)
        mm = stream_mod.to_memmap(stream_mod.host_stream(at, 1), tmp_path)
        coords, values = _delta(DIMS, 7, seed=44)
        new_at = ingest.append_delta(at, coords, values)
        ref = alto.merge_reference(at, coords, values)
        _assert_tensor_bitwise(new_at, ref)
        got = stream_mod.append_stream(mm, new_at)
        ref_hs = stream_mod.host_stream(ref, 1)
        np.testing.assert_array_equal(np.asarray(got.words), ref_hs.words)
        np.testing.assert_array_equal(np.asarray(got.values),
                                      ref_hs.values)


# ---------------------------------------------------------------------------
# warm-start regressions (tier-1)
# ---------------------------------------------------------------------------

class TestWarmStart:
    DIMS = (14, 12, 10)

    def _als_setup(self):
        x = _lowrank_tensor(self.DIMS, 3, 250, seed=0)
        at = alto.build_device(x, n_partitions=4)
        base = cp_als(at, 3, n_iters=80, tol=1e-5, seed=1)
        rng = np.random.default_rng(5)
        coords = np.stack([rng.integers(0, d, 6) for d in self.DIMS],
                          axis=1).astype(np.int32)
        values = (0.02 * rng.standard_normal(6)).astype(np.float32)
        return at, base, coords, values

    def test_cpals_warm_fewer_sweeps_than_cold(self):
        at, base, coords, values = self._als_setup()
        new = ingest.append_delta(at, coords, values)
        warm = cp_als(new, 3, n_iters=80, tol=1e-4, warm_start=base)
        cold = cp_als(new, 3, n_iters=80, tol=1e-4, seed=1)
        assert warm.n_iters < cold.n_iters
        assert warm.fits[-1] >= cold.fits[-1] - 1e-3

    def test_cpals_warm_with_extent_growth_matches_cold_fit(self):
        at, base, _, _ = self._als_setup()
        grown = ingest.append_delta(
            at, np.array([[d for d in self.DIMS]], np.int32), [0.5])
        assert grown.dims == tuple(d + 1 for d in self.DIMS)
        warm = cp_als(grown, 3, n_iters=80, tol=1e-5, warm_start=base)
        cold = cp_als(grown, 3, n_iters=80, tol=1e-5, seed=1)
        assert abs(warm.fits[-1] - cold.fits[-1]) < 0.02

    def test_cpapr_warm_fewer_iterations_than_cold(self):
        x = _lowrank_tensor((12, 10, 9), 3, 220, seed=7, count_data=True)
        at = alto.build_device(x, n_partitions=4)
        p = CpaprParams(k_max=80, tau=1e-4)
        base = cp_apr(at, 3, params=p, seed=1)
        rng = np.random.default_rng(8)
        coords = np.stack([rng.integers(0, d, 5) for d in (12, 10, 9)],
                          axis=1).astype(np.int32)
        new = ingest.append_delta(at, coords, np.ones(5, np.float32))
        warm = cp_apr(new, 3, params=p, warm_start=base)
        cold = cp_apr(new, 3, params=p, seed=1)
        assert warm.n_inner_total < cold.n_inner_total
        assert warm.n_outer <= cold.n_outer

    def test_grow_factors_validation(self):
        lam = jnp.ones((3,))
        factors = [jnp.ones((d, 3)) for d in (4, 5)]
        with pytest.raises(ValueError, match="shrank"):
            ingest.grow_factors((lam, factors), (3, 5), 3)
        with pytest.raises(ValueError, match="expected"):
            ingest.grow_factors((lam, factors), (4, 5), 2)
        with pytest.raises(ValueError, match="factors"):
            ingest.grow_factors((lam, [factors[0]]), (4, 5), 3)
        lam2, grown = ingest.grow_factors((lam, factors), (6, 5), 3,
                                          positive=True)
        assert grown[0].shape == (6, 3)
        np.testing.assert_allclose(np.asarray(grown[0]).sum(axis=0), 1.0,
                                   rtol=1e-5)

    def test_cp_als_rejects_factors_plus_warm_start(self):
        x = _random_tensor(DIMS, 20, seed=51)
        at = alto.build_device(x, n_partitions=2)
        f = [jnp.ones((d, 2)) for d in DIMS]
        with pytest.raises(ValueError, match="not both"):
            cp_als(at, 2, factors=f, warm_start=(None, f))


# ---------------------------------------------------------------------------
# 16-thread append/read stress (mirrors the out-of-core cache stress)
# ---------------------------------------------------------------------------

class TestThreadedAppendStress:
    N_THREADS = 16

    def _run_threads(self, fn, n):
        barrier = threading.Barrier(n)
        errors = []

        def wrap(i):
            try:
                barrier.wait()
                fn(i)
            except BaseException as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        threads = [threading.Thread(target=wrap, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors

    def test_concurrent_appends_and_view_reads(self):
        """Even threads append a private delta to a shared base and check
        bitwise parity vs the host reference; odd threads hammer the view
        cache on the base. Appends are pure (the base tensor is never
        mutated), so every thread must see consistent data throughout."""
        views_mod.cache_clear()
        x = _random_tensor((12, 11, 10), 60, seed=61)
        base = alto.build_device(x, n_partitions=4)
        base_views = [np.asarray(views_mod.get_view(base, m).values)
                      for m in range(3)]

        def work(i):
            if i % 2 == 0:
                coords, values = _delta((12, 11, 10), 5 + (i % 3),
                                        seed=70 + i)
                policy = ingest.POLICIES[i % len(ingest.POLICIES)]
                got = ingest.append_delta(base, coords, values,
                                          policy=policy)
                ref = alto.merge_reference(base, coords, values,
                                           policy=policy)
                _assert_tensor_bitwise(got, ref)
            else:
                m = i % 3
                v = views_mod.get_view(base, m)
                np.testing.assert_array_equal(np.asarray(v.values),
                                              base_views[m])

        self._run_threads(work, self.N_THREADS)
        views_mod.cache_clear()


# ---------------------------------------------------------------------------
# distributed + serving integration
# ---------------------------------------------------------------------------

def test_sharded_append_delta_matches_local():
    from jax.sharding import Mesh
    from repro.dist import cpd as dist_cpd
    devs = np.array(jax.devices()[:1])     # 1-device mesh: same code path
    mesh = Mesh(devs, ("x",))
    x = _random_tensor(DIMS, 30, seed=71)
    at = alto.build_device(x, n_partitions=4)
    coords, values = _delta(DIMS, 7, seed=72)   # 7 % 1 == 0 pad; also odd
    got = dist_cpd.sharded_append_delta(at, coords, values, mesh,
                                        policy="last")
    ref = ingest.append_delta(at, coords, values, policy="last")
    _assert_tensor_bitwise(got, ref)
    empty = dist_cpd.sharded_append_delta(
        at, np.empty((0, 3), np.int32), [], mesh)
    _assert_tensor_bitwise(empty, at)


class TestServingDeltas:
    def _service(self, **kw):
        from repro.launch.serve_cpd import CpdService
        return CpdService(3, "cp_als", capacity=4, n_iters=15, **kw)

    def test_delta_request_roundtrip_and_chaining(self):
        svc = self._service()
        x = _lowrank_tensor((12, 10, 8), 3, 180, seed=81)
        rid = svc.submit(x, seed=0)
        svc.process()
        coords, values = _delta((12, 10, 8), 5, seed=82)
        did = svc.submit_delta(rid, coords, values)
        r1 = svc.process()
        assert len(r1) == 1 and r1[0].bucket_size == 1
        assert r1[0].request_id == did
        coords2, values2 = _delta((12, 10, 8), 4, seed=83)
        did2 = svc.submit_delta(did, coords2, values2)   # chain off delta
        r2 = svc.process()
        assert r2[0].request_id == did2
        s = svc.stats()
        assert s["deltas_done"] == 2
        # the chained result models the twice-appended tensor
        assert r2[0].result.factors[0].shape[0] == 12

    def test_delta_against_unknown_base_raises(self):
        svc = self._service()
        with pytest.raises(KeyError, match="not retained"):
            svc.submit_delta(999, np.empty((0, 3), np.int32), [])

    def test_retention_lru_bound(self):
        svc = self._service(retain_results=2)
        xs = [_random_tensor((6, 5, 4), 12, seed=90 + i) for i in range(3)]
        rids = [svc.submit(x, seed=i) for i, x in enumerate(xs)]
        svc.process()
        with pytest.raises(KeyError):          # oldest aged out of the LRU
            svc.submit_delta(rids[0], np.empty((0, 3), np.int32), [])
        did = svc.submit_delta(rids[2], np.empty((0, 3), np.int32), [])
        assert len(svc.process()) == 1
        assert svc.stats()["deltas_done"] == 1
        assert did > rids[2]
