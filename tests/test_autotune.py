"""Measured plan autotuner + persistent plan store (core/autotune.py).

End-to-end in interpret mode with a tmpdir store, plus the store's
failure-mode contract: corrupted / stale-version cache files are ignored
(never fatal), the ``REPRO_PLAN_CACHE`` override is respected, store hits
cost zero timing runs even from a fresh process, and tuned plans hash and
hit the executable cache exactly like static ones (no retrace).
Serialization round-trips are property-tested on the hermetic
``tests/proptest.py`` harness.
"""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st
from repro.core import alto, autotune, heuristics, plan as plan_mod
from repro.kernels import ops
from repro.sparse import synthetic

RANK = 6


@pytest.fixture
def store(tmp_path, monkeypatch):
    """Point the plan store at a tmpdir (and prove the env override is
    what the tuner actually honors — there is no other path in play)."""
    path = tmp_path / "plans.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    return path


def _tensor(seed=3, dims=(13, 7, 5), nnz=97):
    x = synthetic.uniform_tensor(dims, nnz, seed=seed)
    return alto.build(x, n_partitions=4)


def _tune(at, rank=RANK, **kw):
    kw.setdefault("backend", "pallas")
    kw.setdefault("interpret", True)
    kw.setdefault("max_candidates", 5)
    return autotune.tune_plan(at, rank, **kw)


class TestTunerEndToEnd:
    def test_winner_is_a_feasible_candidate(self, store):
        at = _tensor()
        plan, report = _tune(at)
        assert store.exists()
        for mp in plan.modes:
            assert RANK % mp.r_block == 0
            assert plan_mod.MIN_BLOCK_M <= mp.block_m <= plan_mod.MAX_BLOCK_M
            assert mp.phi_vmem_bytes > 0
        # the winner must reproduce the reference result exactly like any
        # other plan — tuning changes tiles, never math
        from repro.core import mttkrp as cm
        rng = np.random.default_rng(0)
        factors = [jnp.asarray(rng.standard_normal((I, RANK))
                               .astype(np.float32)) for I in at.dims]
        views = plan_mod.build_views(at, plan)
        x = alto.to_sparse(at)
        for mode in range(3):
            got = plan_mod.execute_mttkrp(plan, at, views, factors, mode)
            ref = cm.dense_mttkrp_reference(x.todense(), factors, mode)
            scale = float(jnp.max(jnp.abs(ref))) + 1e-9
            assert float(jnp.max(jnp.abs(got - ref))) / scale < 1e-5

    def test_measured_never_slower_than_static(self, store):
        _, report = _tune(_tensor())
        for mr in report.modes:
            assert mr.best.median_s <= mr.static.median_s
            assert mr.candidates[0].is_static
            assert sum(c.is_static for c in mr.candidates) == 1

    def test_phi_objective_collapses_rank_tiles(self, store):
        at = _tensor(dims=(19, 23, 11), nnz=300)
        plan, report = _tune(at, rank=4, objective="phi")
        for mr in report.modes:
            keys = [(c.traversal, c.block_m) for c in mr.candidates]
            assert len(keys) == len(set(keys))   # r_block duplicates gone

    def test_force_roundtrip_zero_timing_runs(self, store):
        at = _tensor()
        plan, _ = _tune(at)
        runs = ops.timing_runs()
        again = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                   interpret=True, tune="force")
        assert ops.timing_runs() == runs
        assert again == plan and hash(again) == hash(plan)

    def test_force_miss_without_data_raises(self, store):
        at = _tensor(seed=11)
        with pytest.raises(ValueError, match="force"):
            plan_mod.make_plan(at.meta, RANK, backend="pallas",
                               interpret=True, tune="force")

    def test_auto_miss_without_data_falls_back_to_static(self, store):
        at = _tensor(seed=12)
        runs = ops.timing_runs()
        plan = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                  interpret=True, tune="auto")
        static = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                    interpret=True)
        assert plan == static and ops.timing_runs() == runs

    def test_drivers_accept_tune(self, store, monkeypatch):
        monkeypatch.setattr(autotune, "DEFAULT_MAX_CANDIDATES", 4)
        at = _tensor(dims=(12, 10, 8), nnz=120)
        from repro.core import cpals
        res = cpals.cp_als(at, RANK, n_iters=2, seed=1, tune="auto")
        assert res.plan is not None and store.exists()
        # second driver call reuses the stored plan without re-timing
        runs = ops.timing_runs()
        res2 = cpals.cp_als(at, RANK, n_iters=2, seed=1, tune="force")
        assert ops.timing_runs() == runs
        assert res2.plan == res.plan
        assert np.allclose(res2.fits, res.fits)

    def test_cpals_and_cpapr_tune_under_distinct_keys(self, store,
                                                      monkeypatch):
        """cp_als tunes against MTTKRP, cp_apr against Φ — the two
        measurements must land under different store keys, never
        overwriting each other."""
        monkeypatch.setattr(autotune, "DEFAULT_MAX_CANDIDATES", 3)
        x, _ = synthetic.lowrank_count((12, 10, 8), rank=2,
                                       nnz_target=150, seed=5)
        at = alto.build(x, n_partitions=2)
        from repro.core import cpals, cpapr
        cpals.cp_als(at, 4, n_iters=1, tune="auto")
        cpapr.cp_apr(at, 4, params=cpapr.CpaprParams(k_max=1),
                     tune="auto")
        plans = json.loads(store.read_text())["plans"]
        assert len(plans) == 2
        assert {rec["tuned"]["objective"] for rec in plans.values()} \
            == {"mttkrp", "phi"}


class TestSecondProcess:
    def test_identical_plan_across_processes(self, store):
        """The acceptance criterion: tune in process A, then process B's
        ``make_plan(tune="force")`` returns the identical measured plan
        with zero timing runs in that process."""
        script = r"""
import json, sys
from repro.core import alto, autotune, plan as plan_mod
from repro.kernels import ops
from repro.sparse import synthetic

at = alto.build(synthetic.uniform_tensor((13, 7, 5), 97, seed=3),
                n_partitions=4)
if sys.argv[1] == "tune":
    plan, _ = autotune.tune_plan(at, 6, backend="pallas", interpret=True,
                                 max_candidates=5)
else:
    plan = plan_mod.make_plan(at.meta, 6, backend="pallas",
                              interpret=True, tune="force")
    assert ops.timing_runs() == 0, "store hit must not time anything"
print("PLAN_JSON=" + json.dumps(autotune.serialize_plan(plan)))
"""
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env["REPRO_PLAN_CACHE"] = str(store)
        out = {}
        for phase in ("tune", "load"):
            r = subprocess.run([sys.executable, "-c", script, phase],
                               capture_output=True, text=True, env=env,
                               timeout=600)
            assert r.returncode == 0, r.stdout + r.stderr
            line = [ln for ln in r.stdout.splitlines()
                    if ln.startswith("PLAN_JSON=")][0]
            out[phase] = json.loads(line[len("PLAN_JSON="):])
        assert out["tune"] == out["load"]


class TestStoreRobustness:
    def test_corrupted_store_is_ignored_not_fatal(self, store):
        store.write_text("{this is not json")
        at = _tensor()
        assert autotune.load_store() == {}
        plan, _ = _tune(at)        # re-tunes and overwrites
        assert json.loads(store.read_text())["version"] \
            == autotune.PLAN_STORE_VERSION
        assert plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                  interpret=True, tune="force") == plan

    def test_stale_version_is_ignored_not_fatal(self, store):
        at = _tensor()
        plan, report = _tune(at)
        payload = json.loads(store.read_text())
        payload["version"] = autotune.PLAN_STORE_VERSION + 1
        store.write_text(json.dumps(payload))
        assert autotune.load_store() == {}          # stale == empty
        # auto without data: silent static fallback, no crash, no timing
        runs = ops.timing_runs()
        plan_mod.make_plan(at.meta, RANK, backend="pallas",
                           interpret=True, tune="auto")
        assert ops.timing_runs() == runs

    def test_pre_carry_store_loads_as_empty(self, store):
        """A version-1 store predates the ORIENTED_CARRY candidate: its
        winners were measured without the carry traversal in the space
        and must NOT mask it — the v2 bump makes every v1 file load as
        empty, so tune='auto' re-measures over the full space."""
        assert autotune.PLAN_STORE_VERSION >= 2
        at = _tensor()
        plan, _ = _tune(at)
        payload = json.loads(store.read_text())
        payload["version"] = 1                  # a pre-carry store file
        store.write_text(json.dumps(payload))
        assert autotune.load_store() == {}      # pre-carry == empty
        assert autotune.lookup(at.meta, RANK, backend="pallas") is None
        # re-tuning measures again (store miss) and rewrites at v2 with
        # the carry traversal visible in the candidate space
        runs = ops.timing_runs()
        plan2, report = _tune(at)
        assert ops.timing_runs() > runs
        assert json.loads(store.read_text())["version"] \
            == autotune.PLAN_STORE_VERSION
        timed = {c.traversal for mr in report.modes
                 for c in mr.candidates}
        assert "oriented_carry" in timed

    def test_pre_search_v2_store_loads_as_empty_without_clobber(self,
                                                                store):
        """A version-2 store predates the streaming/search records (no
        ``streaming`` block, no ``dev=`` key component, no cost-model
        ``samples``): it must load as EMPTY — and the stale file must
        stay byte-identical on disk through any number of loads and
        lookups, only replaced by the first new write."""
        assert autotune.PLAN_STORE_VERSION >= 3
        at = _tensor()
        _tune(at)
        payload = json.loads(store.read_text())
        payload["version"] = 2                  # a pre-search store file
        store.write_text(json.dumps(payload))
        raw = store.read_bytes()
        assert autotune.load_store() == {}      # pre-search == empty
        assert store.read_bytes() == raw        # load never writes
        assert autotune.lookup(at.meta, RANK, backend="pallas") is None
        runs = ops.timing_runs()
        assert plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                  interpret=True, tune="auto") is not None
        assert ops.timing_runs() == runs        # no data: no measuring
        assert store.read_bytes() == raw        # ...and still no write
        # the first new write (a fresh tune) replaces the stale file
        _tune(at)
        fresh = json.loads(store.read_text())
        assert fresh["version"] == autotune.PLAN_STORE_VERSION
        assert fresh["plans"]                   # re-measured, re-populated

    def test_streaming_record_roundtrips(self, store):
        """v3 records serialize StreamPlan: a searched streaming plan
        must round-trip (chunk_m intact, n_chunks recomputed) under a
        device-budget-keyed lookup, and the in-core record for the same
        tensor must stay distinct."""
        from repro.core import search
        at = _tensor()
        plan, _ = search.search_plan(at, RANK, backend="pallas",
                                     interpret=True, device_bytes=1,
                                     budget_runs=2, seed=0)
        assert plan.streaming is not None
        hit = autotune.lookup(at.meta, RANK, backend="pallas",
                              device_bytes=1)
        assert hit is not None and hit.streaming == plan.streaming
        assert hit.modes == plan.modes
        # the in-core key (device_bytes=None) is a different record
        assert autotune.lookup(at.meta, RANK, backend="pallas") is None

    def test_malformed_entry_is_a_miss(self, store):
        at = _tensor()
        _tune(at)
        payload = json.loads(store.read_text())
        key = next(iter(payload["plans"]))
        payload["plans"][key]["modes"][0]["r_block"] = 5   # !| rank 6
        store.write_text(json.dumps(payload))
        assert autotune.lookup(at.meta, RANK, backend="pallas") is None

    def test_env_override_respected(self, tmp_path, monkeypatch):
        override = tmp_path / "elsewhere" / "cache.json"
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(override))
        assert autotune.store_path() == override
        _tune(_tensor())
        assert override.exists()
        monkeypatch.delenv("REPRO_PLAN_CACHE")
        assert autotune.store_path() == \
            autotune.store_path(autotune.DEFAULT_STORE)

    def test_tuned_plans_cache_without_retrace(self, store):
        at = _tensor()
        plan, _ = _tune(at)
        stored = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                    interpret=True, tune="auto")
        assert stored == plan and hash(stored) == hash(plan)
        rng = np.random.default_rng(0)
        factors = [jnp.asarray(rng.standard_normal((I, RANK))
                               .astype(np.float32)) for I in at.dims]
        views = plan_mod.build_views(at, plan)
        plan_mod.execute_mttkrp(plan, at, views, factors, 0)
        n = ops.cache_size()
        # the deserialized plan is the same cache key: no new executable
        plan_mod.execute_mttkrp(stored, at, views, factors, 0)
        assert ops.cache_size() == n


class TestSerializationProps:
    @settings(max_examples=10, deadline=None)
    @given(dim0=st.integers(4, 40), dim1=st.integers(3, 30),
           dim2=st.integers(2, 20), nnz=st.integers(1, 300),
           rank=st.sampled_from([1, 2, 4, 6, 12]),
           seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_preserves_plan(self, dim0, dim1, dim2, nnz, rank,
                                      seed):
        at = alto.build(synthetic.uniform_tensor((dim0, dim1, dim2), nnz,
                                                 seed=seed % 1000),
                        n_partitions=2)
        plan = plan_mod.make_plan(at.meta, rank, backend="pallas",
                                  interpret=True)
        record = json.loads(json.dumps(autotune.serialize_plan(plan)))
        back = autotune.deserialize_plan(record, at.meta, interpret=True)
        assert back == plan and hash(back) == hash(plan)

    @settings(max_examples=10, deadline=None)
    @given(dims=st.shapes(min_dims=2, max_dims=4, min_side=2, max_side=50),
           nnz=st.integers(1, 200), seed=st.integers(0, 999))
    def test_fingerprint_tracks_meta_identity(self, dims, nnz, seed):
        at = alto.build(synthetic.uniform_tensor(dims, nnz, seed=seed),
                        n_partitions=2)
        fp = autotune.meta_fingerprint(at.meta)
        assert fp == autotune.meta_fingerprint(at.meta)
        import dataclasses
        other = dataclasses.replace(at.meta, nnz=at.meta.nnz + 1)
        assert autotune.meta_fingerprint(other) != fp
        base = autotune.plan_key(at.meta, 4, "pallas")
        assert base != autotune.plan_key(other, 4, "pallas")
        assert base != autotune.plan_key(at.meta, 4, "pallas", n_shards=2)
        # objective and fast-memory budget change the measurement, so
        # they must change the key (phi/mttkrp winners never collide,
        # Π-policy inputs are pinned)
        assert base != autotune.plan_key(at.meta, 4, "pallas",
                                         objective="phi")
        assert base != autotune.plan_key(at.meta, 4, "pallas",
                                         fast_mem_bytes=1)


class TestCandidateSpace:
    def test_static_choice_is_first_and_survives_caps(self):
        at = _tensor()
        static = plan_mod.static_mode_plan(at.meta, 0, RANK)
        for cap in (1, 2, 100):
            cands = plan_mod.candidate_mode_plans(at.meta, 0, RANK,
                                                  max_candidates=cap)
            assert cands[0] == static
            assert len(cands) <= cap

    def test_candidates_respect_budget_and_divisors(self):
        at = _tensor(dims=(64, 48, 32), nnz=2000)
        budget = 256 * 1024
        for mode in range(3):
            cands = plan_mod.candidate_mode_plans(at.meta, mode, 12,
                                                  vmem_limit=budget)
            phi_binding = plan_mod.phi_constraint_active(at.meta, mode, 12,
                                                         vmem_limit=budget)
            for c in cands[1:]:      # static choice may overflow (advisory)
                assert 12 % c.r_block == 0
                assert c.vmem_bytes <= budget
                if (phi_binding and c.traversal
                        is heuristics.Traversal.OUTPUT_ORIENTED):
                    assert c.phi_vmem_bytes <= budget

    def test_forced_oriented_excludes_recursive(self):
        """force_oriented admits both output-oriented variants (one-hot
        merge and scratch carry — `dist.cpd` shards either), never the
        recursive traversal."""
        at = _tensor()
        cands = plan_mod.candidate_mode_plans(at.meta, 0, RANK,
                                              force_oriented=True)
        assert all(heuristics.is_oriented(c.traversal) for c in cands)
        got = {c.traversal for c in cands}
        assert heuristics.Traversal.RECURSIVE not in got
        assert got == {heuristics.Traversal.OUTPUT_ORIENTED,
                       heuristics.Traversal.ORIENTED_CARRY}
