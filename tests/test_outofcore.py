"""Out-of-core chunked execution: chunk-parity property suite.

The tentpole contract — a host-resident stream sliced into block-aligned
chunks flowing through device memory with a cross-chunk carry chain is
**bitwise-identical** to the in-core scratch-carry path at equal tiling —
pinned on the adversarial layouts where chunking can go wrong:

  * one run spanning EVERY chunk (carry threads through all boundaries);
  * chunk capacity of a single block (``chunk_m == block_m``: every
    block boundary is also a chunk boundary);
  * nnz not divisible by the chunk size (short tail chunk);
  * duplicates-heavy streams (many short runs per chunk);
  * empty and single-nonzero tensors;
  * both Π policies for the fused Φ (PRE rebuilds chunk Π rows on
    device; OTF gathers factors per chunk).

Plus the plan layer (byte budget -> StreamPlan -> routing), the modeled
chunk count vs the executed grid, memory-mapped streams, end-to-end
driver parity over-budget, and the threaded one-build/no-use-after-evict
contract of the byte-bounded stream cache.

Runs on the hermetic tests/proptest.py harness (no hypothesis offline).
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st

from repro.core import alto, heuristics, mttkrp as core_mttkrp
from repro.core import plan as plan_mod
from repro.core import stream as stream_mod
from repro.core import views as views_mod
from repro.core.cpals import cp_als
from repro.core.cpapr import CpaprParams, cp_apr
from repro.kernels import ops
from repro.sparse.tensor import SparseTensor

TOL = 1e-5
DIMS = (29, 13, 7)          # non-pow2; mode 0 is the reduction target
MODE = 0
BM = 8                      # smallest legal block: maximizes boundaries


def _stream_tensor(row_counts, seed, count_data=False):
    """SparseTensor whose mode-0 rows appear with given multiplicities."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(len(row_counts), dtype=np.int32),
                     row_counts)
    coords = np.stack(
        [rows] + [rng.integers(0, I, size=rows.shape[0]).astype(np.int32)
                  for I in DIMS[1:]], axis=1)
    if count_data:
        values = rng.integers(1, 5, size=rows.shape[0]).astype(np.float32)
    else:
        values = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return SparseTensor(DIMS, coords, values)


def _factors(seed, R=8):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(np.abs(rng.standard_normal((I, R))
                               ).astype(np.float32) + 0.05) for I in DIMS]


def _layout_counts(layout, rng):
    """Per-row multiplicities realizing the adversarial chunk layouts."""
    I0 = DIMS[0]
    counts = np.zeros(I0, dtype=np.int64)
    if layout == "span_all_chunks":
        # one row owns the whole stream: a single run covering every
        # chunk, so the carry crosses every chunk boundary open
        counts[int(rng.integers(I0))] = 5 * BM + 3
    elif layout == "distinct":
        # every present row once: the carry flushes at every boundary
        n = min(I0, 3 * BM)
        counts[rng.choice(I0, size=n, replace=False)] = 1
    elif layout == "duplicates_heavy":
        # few rows, many repeats: several runs per chunk plus runs that
        # straddle chunk boundaries
        hot = rng.choice(I0, size=3, replace=False)
        counts[hot] = rng.integers(BM, 3 * BM, size=3)
    else:                                   # "mixed"
        counts[:] = rng.integers(0, 2 * BM, size=I0)
        if counts.sum() == 0:
            counts[0] = 1
    return counts


LAYOUTS = ["span_all_chunks", "distinct", "duplicates_heavy", "mixed"]


# ---------------------------------------------------------------------------
# Kernel-level chunk parity (the tentpole bitwise fence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", LAYOUTS)
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       chunk_blocks=st.sampled_from([1, 2, 3]),   # 1 = capacity one block
       r_block=st.sampled_from([4, 8]))
def test_mttkrp_chunked_bitwise(layout, seed, chunk_blocks, r_block):
    rng = np.random.default_rng(seed)
    x = _stream_tensor(_layout_counts(layout, rng), seed)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(seed)

    incore = ops.mttkrp_oriented_carry(view, factors, block_m=BM,
                                       r_block=r_block, interpret=True)
    chunked = ops.mttkrp_oriented_chunked(view, factors,
                                          chunk_m=chunk_blocks * BM,
                                          block_m=BM, r_block=r_block,
                                          interpret=True)
    assert jnp.array_equal(incore, chunked), (
        "chunked MTTKRP not bit-identical to in-core carry path")

    ref = core_mttkrp.mttkrp_oriented(view, factors)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(chunked - ref))) / scale < TOL


@pytest.mark.parametrize("layout", LAYOUTS)
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       chunk_blocks=st.sampled_from([1, 3]),
       pre=st.booleans())
def test_phi_chunked_bitwise_both_policies(layout, seed, chunk_blocks, pre):
    rng = np.random.default_rng(seed)
    x = _stream_tensor(_layout_counts(layout, rng), seed, count_data=True)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(seed)
    B = jnp.abs(factors[MODE]) + 0.1

    if pre:
        coords = alto.delinearize(at.meta.enc, view.words)
        kw = dict(pi=core_mttkrp.krp_rows(coords, factors, MODE))
    else:
        kw = dict(factors=factors)
    incore = ops.cpapr_phi_oriented_carry(view, B, block_m=BM,
                                          interpret=True, **kw)
    chunked = ops.cpapr_phi_oriented_chunked(view, B, factors, pre=pre,
                                             chunk_m=chunk_blocks * BM,
                                             block_m=BM, interpret=True)
    assert jnp.array_equal(incore, chunked), (
        f"chunked Φ (pre={pre}) not bit-identical to in-core carry path")


def test_nnz_not_divisible_by_chunk():
    """Short tail chunk: padded stream not a multiple of chunk_m."""
    x = _stream_tensor(np.full(DIMS[0], 3), seed=5)      # 87 nnz
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(5)
    incore = ops.mttkrp_oriented_carry(view, factors, block_m=BM,
                                       r_block=8, interpret=True)
    hs = stream_mod.host_stream(at, MODE)
    for chunk_m in (2 * BM, 4 * BM, 8 * BM):
        if hs.padded_len(BM) % chunk_m == 0:
            continue
        chunked = ops.mttkrp_oriented_chunked(view, factors,
                                              chunk_m=chunk_m, block_m=BM,
                                              r_block=8, interpret=True)
        assert jnp.array_equal(incore, chunked)


@pytest.mark.parametrize("nnz", [0, 1])
def test_degenerate_streams(nnz):
    """Empty and single-nonzero tensors chunk without special cases."""
    counts = np.zeros(DIMS[0], dtype=np.int64)
    if nnz:
        counts[11] = 1
    x = _stream_tensor(counts, seed=9)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(9)
    incore = ops.mttkrp_oriented_carry(view, factors, block_m=BM,
                                       r_block=8, interpret=True)
    chunked = ops.mttkrp_oriented_chunked(view, factors, chunk_m=BM,
                                          block_m=BM, r_block=8,
                                          interpret=True)
    assert jnp.array_equal(incore, chunked)


def test_memmapped_stream_parity(tmp_path):
    """A spilled (memory-mapped) stream chunks bitwise like the in-core
    path — the executor never distinguishes mmap from RAM numpy."""
    rng = np.random.default_rng(2)
    x = _stream_tensor(_layout_counts("mixed", rng), seed=2)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(2)
    hs = stream_mod.to_memmap(stream_mod.host_stream(at, MODE), tmp_path)
    assert isinstance(hs.words, np.memmap)
    incore = ops.mttkrp_oriented_carry(view, factors, block_m=BM,
                                       r_block=8, interpret=True)
    chunked = ops.mttkrp_oriented_chunked(hs, factors, chunk_m=2 * BM,
                                          block_m=BM, r_block=8,
                                          interpret=True)
    assert jnp.array_equal(incore, chunked)


def test_reference_chunked_tolerance():
    """The reference-backend chunked executors agree with the in-core
    reference traversals to float tolerance (different association)."""
    rng = np.random.default_rng(7)
    x = _stream_tensor(_layout_counts("duplicates_heavy", rng), seed=7,
                       count_data=True)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(7)
    ref = core_mttkrp.mttkrp_oriented(view, factors)
    got = ops.mttkrp_oriented_chunked_reference(view, factors, chunk_m=13)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(got - ref))) / scale < TOL

    B = jnp.abs(factors[MODE]) + 0.1
    coords = alto.delinearize(at.meta.enc, view.words)
    pi = core_mttkrp.krp_rows(coords, factors, MODE)
    ref_phi = ops.cpapr_phi_oriented_carry(view, B, pi=pi, block_m=BM,
                                           interpret=True)
    got_phi = ops.cpapr_phi_oriented_chunked_reference(
        view, B, factors, pre=True, chunk_m=13)
    scale = float(jnp.max(jnp.abs(ref_phi))) + 1e-9
    assert float(jnp.max(jnp.abs(got_phi - ref_phi))) / scale < TOL


def test_chunk_m_must_align_to_block_m():
    x = _stream_tensor(np.full(DIMS[0], 2), seed=0)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    with pytest.raises(ValueError, match="multiple of"):
        ops.mttkrp_oriented_chunked(view, _factors(0), chunk_m=BM + 1,
                                    block_m=BM, interpret=True)


def test_modeled_chunk_count_matches_executed_grid():
    """`plan.chunk_count` (the StreamPlan's n_chunks) equals the number
    of chunk executions the executor actually performs, and each chunk
    beyond the first was prefetched (double buffer)."""
    rng = np.random.default_rng(4)
    x = _stream_tensor(_layout_counts("mixed", rng), seed=4)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(4)
    for chunk_m in (BM, 2 * BM, 4 * BM):
        before = ops.chunk_stats()
        ops.mttkrp_oriented_chunked(view, factors, chunk_m=chunk_m,
                                    block_m=BM, r_block=8, interpret=True)
        after = ops.chunk_stats()
        want = plan_mod.chunk_count(at.meta, chunk_m)
        assert after["chunks"] - before["chunks"] == want
        assert after["prefetches"] - before["prefetches"] == want - 1


# ---------------------------------------------------------------------------
# Plan layer: budget -> StreamPlan -> routing
# ---------------------------------------------------------------------------

def _tensor_and_meta(seed=0, scale=4):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, scale * 2, size=DIMS[0])
    counts[3] = scale * BM
    x = _stream_tensor(counts, seed, count_data=True)
    return alto.build(x, n_partitions=2)


def _streaming_plan(at, R, n_chunks_min=3):
    """A streaming plan with a genuinely multi-chunk grid: vmem_limit=0
    makes every tiling choice advisory-minimal (block_m == MIN == 8), so
    the chunk alignment is 8 and a small budget yields several chunks."""
    meta = at.meta
    resident = plan_mod.streaming_resident_bytes(meta, R)
    elem = plan_mod.stream_elem_bytes(meta)
    budget = resident + 2 * elem * (2 * plan_mod.MIN_BLOCK_M)
    plan = plan_mod.make_plan(meta, R, backend="pallas", interpret=True,
                              vmem_limit=0, device_bytes=budget)
    assert plan.streaming is not None
    assert plan.streaming.n_chunks >= n_chunks_min
    return plan


class TestStreamPlan:
    def test_over_budget_goes_streaming(self):
        at = _tensor_and_meta()
        sp = _streaming_plan(at, R=4).streaming
        assert sp.chunk_m % BM == 0
        assert sp.n_chunks == plan_mod.chunk_count(at.meta, sp.chunk_m)
        assert sp.stream_bytes > sp.device_bytes

    def test_under_budget_stays_incore(self):
        at = _tensor_and_meta()
        plan = plan_mod.make_plan(at.meta, 4, device_bytes=1 << 40)
        assert plan.streaming is None

    def test_no_budget_never_streams(self, monkeypatch):
        monkeypatch.delenv("REPRO_DEVICE_BYTES", raising=False)
        at = _tensor_and_meta()
        assert plan_mod.make_plan(at.meta, 4).streaming is None

    def test_env_budget_is_picked_up(self, monkeypatch):
        at = _tensor_and_meta()
        resident = plan_mod.streaming_resident_bytes(at.meta, 4)
        monkeypatch.setenv("REPRO_DEVICE_BYTES", str(resident + 1))
        assert plan_mod.make_plan(at.meta, 4).streaming is not None

    def test_streaming_forces_carry_traversal(self):
        at = _tensor_and_meta()
        plan = _streaming_plan(at, R=4)
        assert all(m.traversal is heuristics.Traversal.ORIENTED_CARRY
                   for m in plan.modes)

    def test_streaming_rejects_mesh(self):
        at = _tensor_and_meta()
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("x",))
        with pytest.raises(ValueError, match="mesh"):
            plan_mod.make_plan(at.meta, 4, device_bytes=1, mesh=mesh)

    def test_streaming_tune_no_longer_raises(self, tmp_path, monkeypatch):
        # The PR-7 streaming+tune raise is lifted: a store miss with no
        # tensor data falls back to the STATIC streaming plan (same
        # "auto" semantics as in-core), zero timing runs.
        monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "p.json"))
        at = _tensor_and_meta()
        runs = ops.timing_runs()
        plan = plan_mod.make_plan(at.meta, 4, device_bytes=1, tune="auto")
        assert plan.streaming is not None
        assert ops.timing_runs() == runs
        assert plan == plan_mod.make_plan(at.meta, 4, device_bytes=1)

    def test_build_views_yields_host_streams(self):
        at = _tensor_and_meta()
        plan = _streaming_plan(at, R=4)
        views = plan_mod.build_views(at, plan)
        assert views and all(isinstance(v, stream_mod.HostStream)
                             for v in views.values())
        # ...and they carry zero device bytes in the residency accounting
        incore = plan_mod.build_views(
            at, dataclasses.replace(plan, streaming=None))
        assert (plan_mod.resident_bytes(at, views)
                < plan_mod.resident_bytes(at, incore))

    def test_execute_routes_through_chunked(self):
        at = _tensor_and_meta()
        R = 4
        plan = _streaming_plan(at, R)
        views = plan_mod.build_views(at, plan)
        factors = [f[:, :R] for f in _factors(1)]
        before = ops.chunk_stats()["chunks"]
        out = plan_mod.execute_mttkrp(plan, at, views, factors, MODE)
        assert ops.chunk_stats()["chunks"] - before \
            == plan.streaming.n_chunks
        incore = ops.mttkrp_oriented_carry(
            alto.oriented_view(at, MODE), factors,
            block_m=plan.modes[MODE].block_m,
            r_block=plan.modes[MODE].r_block, interpret=True)
        assert jnp.array_equal(out, incore)

    def test_streaming_phi_requires_factors(self):
        at = _tensor_and_meta()
        R = 4
        plan = _streaming_plan(at, R)
        views = plan_mod.build_views(at, plan)
        B = jnp.ones((DIMS[MODE], R), jnp.float32)
        with pytest.raises(ValueError, match="factors"):
            plan_mod.execute_phi(plan, at, views[MODE], B, MODE,
                                 pi=jnp.ones((1, R)))


# ---------------------------------------------------------------------------
# End-to-end: over-budget tensors decompose bitwise-identically
# ---------------------------------------------------------------------------

class TestEndToEndParity:
    """A tensor whose padded stream exceeds the device byte budget runs
    end-to-end through both drivers, multi-chunk, bitwise-identical to
    the in-core scratch-carry path at equal tiling (interpret mode)."""

    def _setup(self, R=4):
        at = _tensor_and_meta(seed=6)
        plan_s = _streaming_plan(at, R)
        plan_i = dataclasses.replace(plan_s, streaming=None)
        views_s = plan_mod.build_views(at, plan_s)
        views_i = plan_mod.build_views(at, plan_i)
        return at, plan_s, plan_i, views_s, views_i

    def test_cp_als_bitwise(self):
        at, plan_s, plan_i, views_s, views_i = self._setup()
        rs = cp_als(at, 4, n_iters=3, plan=plan_s, views=views_s)
        ri = cp_als(at, 4, n_iters=3, plan=plan_i, views=views_i)
        assert rs.fits == ri.fits
        assert jnp.array_equal(rs.lam, ri.lam)
        for a, b in zip(rs.factors, ri.factors):
            assert jnp.array_equal(a, b)

    @pytest.mark.parametrize("policy", ["pre", "otf"])
    def test_cp_apr_bitwise(self, policy):
        at, plan_s, plan_i, views_s, views_i = self._setup()
        p = CpaprParams(k_max=2, l_max=3)
        rs = cp_apr(at, 4, params=p, plan=plan_s, views=views_s,
                    pi_policy=policy)
        ri = cp_apr(at, 4, params=p, plan=plan_i, views=views_i,
                    pi_policy=policy)
        assert rs.kkt_violations == ri.kkt_violations
        assert rs.n_inner_total == ri.n_inner_total
        assert jnp.array_equal(rs.lam, ri.lam)
        for a, b in zip(rs.factors, ri.factors):
            assert jnp.array_equal(a, b)

    def test_runs_genuinely_chunked(self):
        at, plan_s, _, views_s, _ = self._setup()
        before = ops.chunk_stats()["chunks"]
        cp_als(at, 4, n_iters=1, plan=plan_s, views=views_s)
        executed = ops.chunk_stats()["chunks"] - before
        # one sweep = one chunked MTTKRP per mode
        assert executed == len(DIMS) * plan_s.streaming.n_chunks
        assert plan_s.streaming.n_chunks >= 3


# ---------------------------------------------------------------------------
# Threaded stream-cache regression (one build per key, no use-after-evict)
# ---------------------------------------------------------------------------

class TestThreadedStreamCache:
    N_THREADS = 16

    def _tensors(self, n=4):
        return [alto.build(_stream_tensor(
            np.random.default_rng(100 + i).integers(0, 12, size=DIMS[0]),
            seed=100 + i), n_partitions=2) for i in range(n)]

    def _run_threads(self, fn, n):
        barrier = threading.Barrier(n)
        errors = []

        def wrap(i):
            try:
                barrier.wait()
                fn(i)
            except BaseException as e:  # noqa: BLE001 — surfaced below
                errors.append(e)

        threads = [threading.Thread(target=wrap, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors

    def test_exactly_one_build_per_key(self, monkeypatch):
        """16 concurrent requesters over 8 (tensor, mode) keys: the
        per-key latch admits exactly one build each."""
        monkeypatch.delenv("REPRO_VIEW_CACHE_BYTES", raising=False)
        monkeypatch.delenv("REPRO_VIEW_CACHE_SIZE", raising=False)
        tensors = self._tensors(4)
        keys = [(at, m) for at in tensors for m in (0, 1)]   # 8 keys
        views_mod.cache_clear()
        before = views_mod.cache_stats()["builds"]
        got = {}

        def work(i):
            at, m = keys[i % len(keys)]
            hs = views_mod.get_stream(at, m)
            got[i] = hs

        self._run_threads(work, self.N_THREADS)
        assert views_mod.cache_stats()["builds"] - before == len(keys)
        # same key -> identical cached object
        for i in range(len(keys), self.N_THREADS):
            assert got[i] is got[i % len(keys)]
        views_mod.cache_clear()

    def test_no_use_after_evict_under_byte_bound(self, monkeypatch):
        """A byte bound so tight every insert evicts its predecessor:
        threads holding chunk slices of evicted entries must still
        compute bitwise-correct results (numpy slices keep the backing
        buffers alive past eviction)."""
        monkeypatch.setenv("REPRO_VIEW_CACHE_BYTES", "1")
        tensors = self._tensors(4)
        factors = _factors(0)
        want = {}
        for at in tensors:
            view = alto.oriented_view(at, MODE)
            want[id(at)] = ops.mttkrp_oriented_carry(
                view, factors, block_m=BM, r_block=8, interpret=True)
        views_mod.cache_clear()

        def work(i):
            at = tensors[i % len(tensors)]
            hs = views_mod.get_stream(at, MODE)   # may evict a peer's entry
            out = ops.mttkrp_oriented_chunked(hs, factors, chunk_m=2 * BM,
                                              block_m=BM, r_block=8,
                                              interpret=True)
            assert jnp.array_equal(out, want[id(at)])

        self._run_threads(work, self.N_THREADS)
        # the bound held: at most one stream entry survives
        assert views_mod.cache_stats()["size"] <= 1
        views_mod.cache_clear()
