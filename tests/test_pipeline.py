"""GPipe pipeline parallelism: forward + grad equivalence vs the
sequential model (4 emulated pipeline stages in a subprocess)."""
import os
import subprocess
import sys

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import reduced_config
from repro.models import model as M
from repro.models.common import materialize
from repro.dist import pipeline as PP
from repro.train.steps import cross_entropy

cfg = dataclasses.replace(reduced_config("glm4-9b", n_repeats=4),
                          remat=False)
params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32))
batch = {"tokens": toks, "labels": toks}

ref_logits, _ = jax.jit(lambda p, b: M.forward(cfg, p, b))(params, batch)
pp = PP.to_pipeline_params(cfg, params, 4)
pp_logits = jax.jit(lambda p, t: PP.pipeline_forward(
    cfg, p, t, mesh, n_microbatches=4))(pp, toks)
scale = float(jnp.max(jnp.abs(ref_logits)))
assert float(jnp.max(jnp.abs(pp_logits - ref_logits))) / scale < 1e-3

def ref_loss(p):
    lg, _ = M.forward(cfg, p, batch)
    return cross_entropy(lg, batch["labels"])

g_ref = jax.grad(ref_loss)(params)
g_pp = jax.grad(lambda p: PP.pipeline_loss(cfg, p, batch, mesh, 4))(pp)
g_pp_b = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                      g_pp["blocks_0"])
d = max(float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(g_ref["blocks_0"]), jax.tree.leaves(g_pp_b)))
gs = max(float(jnp.max(jnp.abs(a)))
         for a in jax.tree.leaves(g_ref["blocks_0"]))
assert d / gs < 1e-3, (d, gs)
print("PIPE_OK")
"""


def test_pipeline_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _SUB], capture_output=True,
                       text=True, env=env, timeout=600)
    assert "PIPE_OK" in r.stdout, r.stdout + r.stderr
