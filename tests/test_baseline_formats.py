"""HiCOO and CSF baseline formats (the paper's comparison points)."""
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.core import mttkrp as cm
from repro.sparse import baselines, synthetic


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


@pytest.mark.parametrize("gen,dims,nnz", [
    (synthetic.uniform_tensor, (40, 60, 30), 2000),
    (synthetic.blocked_tensor, (64, 64, 64), 3000),
    (synthetic.uniform_tensor, (20, 16, 12, 8), 1500),
])
def test_baselines_vs_dense(gen, dims, nnz):
    x = gen(dims, nnz, seed=3)
    factors = _factors(dims, 16)
    dense = x.todense()
    h = baselines.build_hicoo(x, block_bits=4)
    csf = baselines.CsfAll(x)
    for mode in range(len(dims)):
        ref = cm.dense_mttkrp_reference(dense, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        eh = float(jnp.max(jnp.abs(
            baselines.mttkrp_hicoo(h, factors, mode) - ref))) / scale
        ec = float(jnp.max(jnp.abs(
            csf.mttkrp(factors, mode) - ref))) / scale
        assert eh < 1e-4 and ec < 1e-4, (mode, eh, ec)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([2, 4, 7]))
def test_hicoo_roundtrip_property(seed, bits):
    rng = np.random.default_rng(seed)
    dims = tuple(int(d) for d in rng.integers(8, 200, size=3))
    x = synthetic.uniform_tensor(dims, 500, seed=seed)
    h = baselines.build_hicoo(x, block_bits=bits)
    coords = np.asarray(baselines.hicoo_coords(h))
    a = sorted(map(tuple, coords.tolist()))
    b = sorted(map(tuple, x.coords.tolist()))
    assert a == b


def test_csf_tree_structure():
    x = synthetic.uniform_tensor((10, 12, 8), 300, seed=1)
    t = baselines.build_csf(x, root=1)
    assert t.mode_order == (1, 0, 2)
    # level sizes grow monotonically; leaves == nnz
    sizes = [len(f) for f in t.fids]
    assert sizes == sorted(sizes)
    assert sizes[-1] == x.nnz
    # root ids are the distinct mode-1 indices
    np.testing.assert_array_equal(np.sort(t.fids[0]),
                                  np.unique(x.coords[:, 1]))


def test_storage_orderings():
    """Fig. 12 behaviour: CSF-ALL always biggest (N copies); ALTO always
    <= COO; HiCOO smaller than COO only when blocks are dense."""
    from repro.core import encoding as E
    blocked = synthetic.blocked_tensor((256, 256, 256), 60_000, block=16,
                                       n_blocks=12, seed=0)
    hyper = synthetic.uniform_tensor((2**15, 2**15, 2**15), 20_000, seed=0)
    for x, dense_blocks in ((blocked, True), (hyper, False)):
        enc = E.make_encoding(x.dims)
        coo = x.nnz * (enc.storage_bits_coo(32) // 8 + 4)
        alto_b = x.nnz * (enc.runtime_index_bits() // 8 + 4)
        csf = baselines.CsfAll(x).storage_bytes()
        hic = baselines.build_hicoo(x, block_bits=7).storage_bytes()
        assert alto_b <= coo
        assert csf > coo                      # N tree copies
        if dense_blocks:
            assert hic < coo                  # compression works
        else:
            assert hic > alto_b               # hyper-sparse: HiCOO loses
