"""Distributed substrate: shard_map CPD, checkpoint/restore, compression,
sharding rules. Runs on 1 real device via a subprocess with 8 fake devices
where multi-device semantics matter."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import sharding as shd
from repro.optim import compress


def test_sharding_rule_divisibility():
    """Non-divisible dims must drop mesh axes, never error."""
    import jax.sharding as js
    devs = jax.devices()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = shd.spec_for(mesh, ("vocab", "fsdp"), (49155, 1536))
    assert isinstance(spec, js.PartitionSpec)
    # 8 kv heads over model=1 mesh: fine
    spec = shd.spec_for(mesh, ("batch", None, "kv_heads", None),
                        (8, 1, 8, 64))


def test_bf16_compression_roundtrip():
    g = {"a": jnp.ones((4, 4)) * 0.1, "b": jnp.arange(3.0)}
    out = compress.bf16_compress(g)
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(out))


def test_int8_error_feedback_converges():
    """Error feedback: the accumulated quantization error stays bounded and
    the mean dequantized gradient converges to the true mean."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    err = jnp.zeros_like(g_true, dtype=jnp.bfloat16)
    acc = jnp.zeros_like(g_true)
    n = 50
    for _ in range(n):
        deq, err = compress.int8_compress_decompress(g_true, err)
        acc = acc + deq
    rel = float(jnp.max(jnp.abs(acc / n - g_true))) / float(
        jnp.max(jnp.abs(g_true)))
    assert rel < 2e-2, rel


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import checkpoint as ck
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}
    path = ck.save(str(tmp_path), 7, tree, data_step=42)
    assert os.path.basename(path) == "step_00000007"
    assert ck.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, manifest = ck.restore(str(tmp_path), 7, like)
    assert manifest["data_step"] == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    from repro.checkpoint import checkpoint as ck
    c = ck.AsyncCheckpointer(str(tmp_path))
    for step in (1, 2, 3):
        c.save(step, {"x": jnp.full((2,), step)}, data_step=step * 10)
    c.wait()
    assert ck.latest_step(str(tmp_path)) == 3
    restored, m = ck.restore(str(tmp_path), 3, {"x": jnp.zeros((2,))})
    assert float(restored["x"][0]) == 3.0


def test_checkpoint_structure_mismatch_raises(tmp_path):
    from repro.checkpoint import checkpoint as ck
    ck.save(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), 1, {"x": jnp.zeros((2,)),
                                      "y": jnp.zeros((3,))})


_SUBPROCESS_DIST = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.dist import cpd
from repro.core import alto, cpals
from repro.sparse import synthetic

mesh = jax.make_mesh((8,), ("data",))
x, _ = synthetic.sparse_lowrank((30, 40, 25), rank=4, col_support=0.3,
                                seed=2)
lam, factors, fits = cpd.distributed_cp_als(x, rank=4, mesh=mesh,
                                            n_iters=4, seed=7)
at = alto.build(x, n_partitions=8)
res = cpals.cp_als(at, rank=4, n_iters=4, tol=0, seed=7)
assert abs(fits[-1] - res.fits[-1]) < 1e-3, (fits, res.fits)
print("DIST_OK")
"""


def test_distributed_cpd_equivalence():
    """shard_map CP-ALS on 8 fake devices == single-device result."""
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_DIST],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert "DIST_OK" in r.stdout, r.stdout + r.stderr


_SUBPROCESS_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import checkpoint as ck
import sys

ckdir = sys.argv[1]
mesh8 = jax.make_mesh((8,), ("data",))
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P("data")))
ck.save(ckdir, 1, {"x": x})
# elastic restore onto a DIFFERENT mesh (4 devices x 2 model)
mesh4 = jax.make_mesh((2, 2), ("data", "model"))
tgt = NamedSharding(mesh4, P("model"))
restored, _ = ck.restore(ckdir, 1, {"x": jnp.zeros((8, 8))},
                         shardings={"x": tgt})
np.testing.assert_array_equal(np.asarray(restored["x"]),
                              np.arange(64.0).reshape(8, 8))
assert restored["x"].sharding.spec == P("model")
print("ELASTIC_OK")
"""


def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_ELASTIC,
                        str(tmp_path)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
