"""MTTKRP: all traversal variants vs the dense einsum oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as st

from repro.core import alto, mttkrp
from repro.sparse import synthetic
from repro.sparse.tensor import SparseTensor


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


@pytest.mark.parametrize("gen,dims,nnz", [
    (synthetic.uniform_tensor, (40, 60, 30), 2000),
    (synthetic.zipf_tensor, (40, 60, 30), 2000),
    (synthetic.blocked_tensor, (64, 64, 64), 3000),
    (synthetic.uniform_tensor, (20, 16, 12, 8), 1500),
])
def test_all_variants_vs_dense(gen, dims, nnz):
    x = gen(dims, nnz, seed=3)
    at = alto.build(x, n_partitions=8)
    factors = _factors(dims, 16)
    dense = x.todense()
    for mode in range(len(dims)):
        ref = mttkrp.dense_mttkrp_reference(dense, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        coo = mttkrp.mttkrp_coo(jnp.asarray(x.coords),
                                jnp.asarray(x.values), factors, mode)
        rec = mttkrp.mttkrp_recursive(at, factors, mode)
        ori = mttkrp.mttkrp_oriented(alto.oriented_view(at, mode), factors)
        ada = mttkrp.mttkrp_adaptive(
            at, {mode: alto.oriented_view(at, mode)}, factors, mode)
        for name, out in (("coo", coo), ("recursive", rec),
                          ("oriented", ori), ("adaptive", ada)):
            err = float(jnp.max(jnp.abs(out - ref))) / scale
            assert err < 1e-4, (name, mode, err)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n_part=st.sampled_from([1, 2, 4, 8, 16]),
       rank=st.sampled_from([1, 4, 16, 32]))
def test_partition_invariance_property(seed, n_part, rank):
    """MTTKRP result must not depend on the partition count (the paper's
    partitioning only affects scheduling, never the math)."""
    rng = np.random.default_rng(seed)
    dims = tuple(int(d) for d in rng.integers(8, 40, size=3))
    x = synthetic.uniform_tensor(dims, 600, seed=seed)
    factors = _factors(dims, rank, seed=seed)
    ref = mttkrp.mttkrp_recursive(alto.build(x, n_partitions=1), factors, 0)
    out = mttkrp.mttkrp_recursive(alto.build(x, n_partitions=n_part),
                                  factors, 0)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(out - ref))) / scale < 1e-4


def test_balanced_partitions():
    """Equal-nnz partitioning: every partition holds exactly Mp/L elements
    (the perfect workload balance claim of §4.1)."""
    x = synthetic.zipf_tensor((128, 128, 64), 10_000, seed=5)
    at = alto.build(x, n_partitions=16)
    assert at.words.shape[0] % 16 == 0
    # disjoint & ordered line segments
    w = np.asarray(at.words).reshape(16, -1, at.words.shape[-1])
    for l in range(15):
        last = tuple(w[l, -1][::-1].tolist())
        first = tuple(w[l + 1, 0][::-1].tolist())
        assert last <= first


def test_intervals_bound_nonzeros():
    x = synthetic.uniform_tensor((50, 60, 70), 4000, seed=9)
    L = 8
    at = alto.build(x, n_partitions=L)
    coords = np.asarray(at.coords()).reshape(L, -1, 3)
    ps, pe = np.asarray(at.part_start), np.asarray(at.part_end)
    for l in range(L):
        assert (coords[l] >= ps[l]).all() and (coords[l] <= pe[l]).all()
