"""Pallas kernels (interpret mode) vs pure-jnp ref.py oracles,
swept over shapes / dtypes / partition counts / ranks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alto, mttkrp as core_mttkrp
from repro.kernels import ops, ref
from repro.kernels.delinearize import delinearize_pallas
from repro.kernels.mttkrp import mttkrp_partials_pallas
from repro.kernels.cpapr_phi import phi_partials_pallas
from repro.sparse import synthetic


def _setup(dims, nnz, L, R, seed=0, dtype=jnp.float32, count=True):
    x = synthetic.zipf_tensor(dims, nnz, seed=seed, count_data=count)
    at = alto.build(x, n_partitions=L)
    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(
        np.abs(rng.standard_normal((I, R))).astype(np.float32) + 0.05
    ).astype(dtype) for I in dims]
    return x, at, factors


@pytest.mark.parametrize("dims,nnz,L,R", [
    ((48, 64, 32), 4000, 4, 16),
    ((48, 64, 32), 4000, 8, 32),
    ((16, 16, 16, 16), 3000, 4, 16),
    ((128, 8, 255), 2000, 2, 8),
    ((1000, 999, 17), 1000, 4, 16),
])
def test_mttkrp_kernel_shapes(dims, nnz, L, R):
    x, at, factors = _setup(dims, nnz, L, R)
    for mode in range(len(dims)):
        got = ops.mttkrp(at, factors, mode)
        want = core_mttkrp.mttkrp_recursive(at, factors, mode)
        scale = float(jnp.max(jnp.abs(want))) + 1e-9
        assert float(jnp.max(jnp.abs(got - want))) / scale < 1e-5


@pytest.mark.parametrize("r_block", [8, 16])
def test_mttkrp_kernel_rank_tiling(r_block):
    x, at, factors = _setup((40, 48, 24), 3000, 4, 32)
    got = ops.mttkrp(at, factors, 0, r_block=r_block)
    want = core_mttkrp.mttkrp_recursive(at, factors, 0)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert float(jnp.max(jnp.abs(got - want))) / scale < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mttkrp_kernel_dtypes(dtype):
    x, at, factors = _setup((32, 48, 24), 2000, 4, 16, dtype=dtype)
    vals = at.values.astype(dtype)
    at2 = alto.AltoTensor(at.meta, at.words, vals, at.part_start,
                          at.part_end)
    got = ops.mttkrp(at2, factors, 1)
    want = core_mttkrp.mttkrp_recursive(at2, factors, 1)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-9
    diff = float(jnp.max(jnp.abs((got - want).astype(jnp.float32))))
    assert diff / scale < tol


@pytest.mark.parametrize("dims", [(64, 64), (48, 64, 32), (16, 8, 4, 2),
                                  (3, 5, 7, 11, 13)])
@pytest.mark.parametrize("block_m", [64, 256])
def test_delinearize_kernel_sweep(dims, block_m):
    x = synthetic.uniform_tensor(dims, 2048, seed=1)
    at = alto.build(x, n_partitions=4)
    got = ops.delinearize(at.meta.enc, at.words, block_m=block_m)
    want = ref.ref_delinearize(at.meta.enc, at.words)
    assert jnp.array_equal(got, want)


@pytest.mark.parametrize("mode", [0, 1, 2])
@pytest.mark.parametrize("pre", [True, False])
def test_phi_kernel(mode, pre):
    x, at, factors = _setup((48, 64, 32), 4000, 4, 16)
    B = jnp.abs(factors[mode]) + 0.1
    coords = at.coords()
    pi = core_mttkrp.krp_rows(coords, factors, mode) if pre else None
    got = ops.cpapr_phi(at, B, mode,
                        factors=None if pre else factors, pi=pi)
    want = ref.ref_pull_reduction(
        ref.ref_phi_partials(at.meta.enc, mode, at.meta.temp_rows[mode],
                             1e-10, at.words, at.values, at.part_start, B,
                             factors=factors),
        at.part_start[:, mode], x.dims[mode])
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert float(jnp.max(jnp.abs(got - want))) / scale < 1e-5


def test_partials_match_ref_directly():
    """Kernel partials (pre-reduction) equal the ref oracle partials."""
    x, at, factors = _setup((40, 32, 24), 2000, 4, 16)
    pk = mttkrp_partials_pallas(at.meta.enc, 0, at.meta.temp_rows[0],
                                at.words, at.values, at.part_start,
                                factors)
    pr = ref.ref_mttkrp_partials(at.meta.enc, 0, at.meta.temp_rows[0],
                                 at.words, at.values, at.part_start,
                                 factors)
    scale = float(jnp.max(jnp.abs(pr))) + 1e-9
    assert float(jnp.max(jnp.abs(pk - pr))) / scale < 1e-5
