"""Hermetic unit tests for the distributed seam (`repro.dist.cpd`).

The shard-local reductions are pure functions of a contiguous slice of
the row-sorted stream, so the mesh is simulated in-process: call the
local function per shard and sum on the host — arithmetically the same
combination ``lax.psum`` performs on device. That keeps these tests on
the single-device pytest host (the real 8-fake-device path is covered by
the subprocess tests in ``test_distributed.py``). Property cases run on
the hermetic ``tests/proptest.py`` harness.

Covered: boundary-run carries under adversarial row distributions (every
nonzero in one row → one run spanning all shards; nnz < shards → shards
made entirely of padding; random streams), psum'd Gram equivalence, and
mesh-aware plan resolution / hashing / caching.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st
from repro.core import alto, heuristics, mttkrp as cm, plan as plan_mod
from repro.dist import cpd as dist_cpd
from repro.sparse import synthetic
from repro.sparse.tensor import SparseTensor

TOL = 1e-5


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def _mesh1():
    return jax.make_mesh((1,), ("data",))


def _simulated_sharded_mttkrp(plan, view, factors, mode, n_shards):
    """Shard-local reduce per contiguous slice + host-side sum (≡ psum)."""
    bm = plan.modes[mode].block_m if plan.backend == "pallas" else 1
    rows, words, values, _ = dist_cpd._pad_stream(
        view.rows, view.words, view.values, n_shards * bm)
    per = rows.shape[0] // n_shards
    out = None
    for s in range(n_shards):
        sl = slice(s * per, (s + 1) * per)
        part = dist_cpd.local_mttkrp(plan, mode, rows[sl], words[sl],
                                     values[sl], factors)
        out = part if out is None else out + part
    return out


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("case", ["uniform", "single_row", "tiny_nnz"])
def test_shard_boundary_carries(backend, case):
    """Sum of per-shard local reductions == unsharded oracle, including
    a single row spanning every shard and shards that are pure padding."""
    dims, R, D = (17, 9, 5), 6, 4
    if case == "uniform":
        x = synthetic.uniform_tensor(dims, 300, seed=0)
    elif case == "single_row":
        # every nonzero in mode-0 row 4: one segment run crosses all
        # shard boundaries; every shard contributes a carry to row 4
        rng = np.random.default_rng(1)
        coords = np.stack([np.full(64, 4),
                           rng.integers(0, dims[1], 64),
                           rng.integers(0, dims[2], 64)], axis=1)
        x = SparseTensor(dims, coords.astype(np.int32),
                         rng.standard_normal(64).astype(np.float32)
                         ).deduplicate()
    else:   # tiny_nnz: fewer nonzeros than shards → padding-only shards
        coords = np.array([[0, 0, 0], [16, 8, 4]], np.int32)
        x = SparseTensor(dims, coords, np.array([1.5, -2.0], np.float32))
    at = alto.build(x, n_partitions=2)
    factors = _factors(dims, R)
    plan = plan_mod.make_plan(at.meta, R, mesh=_mesh1(), backend=backend,
                              interpret=True)
    dense = x.todense()
    for mode in range(len(dims)):
        view = alto.oriented_view(at, mode)
        ref = cm.dense_mttkrp_reference(dense, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        out = _simulated_sharded_mttkrp(plan, view, factors, mode, D)
        err = float(jnp.max(jnp.abs(out - ref))) / scale
        assert err < TOL, (case, backend, mode, err)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_shards=st.integers(1, 9),
       zipf=st.booleans())
def test_shard_carries_property(seed, n_shards, zipf):
    """Random streams (skewed included): sharded sum == oracle for every
    mode and any shard count, shards aligned with rows or not."""
    dims, R = (12, 8, 6), 5
    gen = synthetic.zipf_tensor if zipf else synthetic.uniform_tensor
    x = gen(dims, 150, seed=seed)
    at = alto.build(x, n_partitions=2)
    factors = _factors(dims, R, seed=seed % 100)
    plan = plan_mod.make_plan(at.meta, R, mesh=_mesh1())
    dense = x.todense()
    for mode in range(3):
        view = alto.oriented_view(at, mode)
        ref = cm.dense_mttkrp_reference(dense, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        out = _simulated_sharded_mttkrp(plan, view, factors, mode, n_shards)
        assert float(jnp.max(jnp.abs(out - ref))) / scale < TOL


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(1, 50), rank=st.integers(1, 8),
       n_shards=st.integers(1, 7), seed=st.integers(0, 2**31 - 1))
def test_sharded_gram_equivalence(rows, rank, n_shards, seed):
    """Row-sharded AᵀA partials sum to the dense Gram (zero-row padding
    included), the combination `dist_cpd.sharded_gram` psums on device."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.standard_normal((rows, rank)).astype(np.float32))
    ref = A.T @ A
    pad = (-rows) % n_shards
    Ap = jnp.concatenate([A, jnp.zeros((pad, rank), A.dtype)]) if pad else A
    per = Ap.shape[0] // n_shards
    acc = sum(dist_cpd.local_gram(Ap[s * per:(s + 1) * per])
              for s in range(n_shards))
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_sharded_gram_on_device():
    """The shard_map wrapper itself on a 1-device mesh (plumbing check)."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((13, 4)).astype(np.float32))
    out = dist_cpd.sharded_gram(_mesh1(), A)
    np.testing.assert_allclose(np.asarray(out), np.asarray(A.T @ A),
                               rtol=1e-5, atol=1e-5)


def test_sharded_mttkrp_on_device():
    """execute_mttkrp routes mesh-bearing plans through shard_map and
    matches the oracle on a 1-device mesh."""
    x = synthetic.uniform_tensor((11, 7, 5), 120, seed=2)
    at = alto.build(x, n_partitions=2)
    factors = _factors(x.dims, 4)
    plan = plan_mod.make_plan(at.meta, 4, mesh=_mesh1())
    views = plan_mod.build_views(at, plan)
    assert set(views) == {0, 1, 2}        # mesh plans orient every mode
    dense = x.todense()
    for mode in range(3):
        ref = cm.dense_mttkrp_reference(dense, factors, mode)
        out = plan_mod.execute_mttkrp(plan, at, views, factors, mode)
        scale = float(jnp.max(jnp.abs(ref))) + 1e-9
        assert float(jnp.max(jnp.abs(out - ref))) / scale < TOL


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("pre_pi", [True, False])
def test_shard_phi_carries(backend, pre_pi):
    """Sharded CP-APR Φ: per-shard local_phi + host sum == the unsharded
    reference Φ, for both Π policies and backends (carry merge holds for
    the fused kernel too — B rows gather by global ids)."""
    dims, R, D = (14, 9, 6), 5, 4
    x = synthetic.uniform_tensor(dims, 250, seed=4, count_data=True)
    at = alto.build(x, n_partitions=2)
    mode = 0
    view = alto.oriented_view(at, mode)
    rng = np.random.default_rng(0)
    B = jnp.asarray(np.abs(rng.standard_normal((dims[mode], R))
                           ).astype(np.float32))
    factors = [jnp.asarray(np.abs(rng.standard_normal((I, R))
                                  ).astype(np.float32)) for I in dims]
    plan = plan_mod.make_plan(at.meta, R, mesh=_mesh1(), backend=backend,
                              interpret=True)
    # numpy oracle in view (row-sorted) order: Φ = scatter-add of
    # (v / max(<B[row], krp>, ε)) · krp by target row
    coords = np.asarray(alto.delinearize(at.meta.enc, view.words))
    krp_np = np.prod([np.asarray(f)[coords[:, m]]
                      for m, f in enumerate(factors) if m != mode], axis=0)
    rows_np = np.asarray(view.rows)
    denom = np.maximum((np.asarray(B)[rows_np] * krp_np).sum(-1), 1e-10)
    contrib = (np.asarray(view.values) / denom)[:, None] * krp_np
    ref = np.zeros((dims[mode], R), np.float32)
    np.add.at(ref, rows_np, contrib)
    ref = jnp.asarray(ref)
    pi_full = jnp.asarray(krp_np) if pre_pi else None
    bm = plan.modes[mode].block_m if backend == "pallas" else 1
    rows, words, values, pi = dist_cpd._pad_stream(
        view.rows, view.words, view.values, D * bm, pi=pi_full)
    per = rows.shape[0] // D
    out = None
    for s in range(D):
        sl = slice(s * per, (s + 1) * per)
        part = dist_cpd.local_phi(
            plan, mode, 1e-10, rows[sl], words[sl], values[sl], B,
            factors=None if pre_pi else factors,
            pi=pi[sl] if pre_pi else None)
        out = part if out is None else out + part
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(out - ref))) / scale < TOL


def test_sharded_phi_on_device():
    """execute_phi routes mesh-bearing plans through sharded_phi; matches
    the reference Φ on a 1-device mesh (shard_map plumbing + caching)."""
    x = synthetic.uniform_tensor((10, 8, 6), 150, seed=5, count_data=True)
    at = alto.build(x, n_partitions=2)
    R, mode = 4, 1
    view = alto.oriented_view(at, mode)
    rng = np.random.default_rng(1)
    B = jnp.asarray(np.abs(rng.standard_normal((x.dims[mode], R))
                           ).astype(np.float32))
    factors = [jnp.asarray(np.abs(rng.standard_normal((I, R))
                                  ).astype(np.float32)) for I in x.dims]
    mesh_plan = plan_mod.make_plan(at.meta, R, mesh=_mesh1())
    ref_plan = plan_mod.make_plan(at.meta, R, backend="reference")
    ref = plan_mod.execute_phi(ref_plan, at, view, B, mode, factors=factors)
    out = plan_mod.execute_phi(mesh_plan, at, view, B, mode,
                               factors=factors)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(out - ref))) / scale < TOL


def test_pipeline_params_roundtrip():
    """to_pipeline_params is losslessly inverted by from_pipeline_params
    and rejects indivisible stage counts / unsupported families."""
    from repro.configs import reduced_config
    from repro.dist import pipeline as PP
    from repro.models import model as M
    from repro.models.common import materialize

    cfg = reduced_config("glm4-9b", n_repeats=4)
    params = materialize(M.model_def(cfg), jax.random.PRNGKey(0))
    pp = PP.to_pipeline_params(cfg, params, 2)
    leaf = jax.tree.leaves(pp["blocks_0"])[0]
    assert leaf.shape[:2] == (2, 2)
    back = PP.from_pipeline_params(cfg, pp)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        PP.to_pipeline_params(cfg, params, 3)       # 4 repeats % 3 != 0
    enc_cfg = reduced_config("whisper-base")
    with pytest.raises(NotImplementedError):
        PP._forward_with_aux(enc_cfg, {}, jnp.zeros((2, 4), jnp.int32),
                             _mesh1(), 1)


def test_mesh_plan_resolution():
    """Mesh plans force the oriented *family* everywhere (one-hot merge or
    scratch carry, both shardable) and divide the VMEM budget per shard
    (never larger tiles than the single-device plan).
    """
    x = synthetic.blocked_tensor((64, 48, 32), 20_000, seed=0)
    at = alto.build(x, n_partitions=8)
    single = plan_mod.make_plan(at.meta, 16)
    meshed = plan_mod.make_plan(at.meta, 16, mesh=_mesh1())
    from repro.core import heuristics
    assert all(heuristics.is_oriented(mp.traversal) for mp in meshed.modes)
    assert meshed.n_shards == 1 and meshed.mesh_axis == "data"
    assert single.mesh is None and single.n_shards == 1
    for mp_s, mp_m in zip(single.modes, meshed.modes):
        assert mp_m.block_m <= max(mp_s.block_m, plan_mod.MIN_BLOCK_M)


def test_mesh_plan_hashing_and_caching():
    """Mesh-bearing plans stay hashable/static: equal inputs → equal plans
    (same hash, cache hit); mesh presence changes the key."""
    x = synthetic.uniform_tensor((10, 8, 6), 100, seed=1)
    at = alto.build(x, n_partitions=4)
    m1, m2 = _mesh1(), _mesh1()
    p1 = plan_mod.make_plan(at.meta, 4, mesh=m1)
    p2 = plan_mod.make_plan(at.meta, 4, mesh=m2)
    p0 = plan_mod.make_plan(at.meta, 4)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert p1 != p0
    cache = {p1: "sharded", p0: "local"}   # executable-cache key usage
    assert cache[p2] == "sharded" and len(cache) == 2
    # static jit argument: two identical-mesh plans must not retrace
    import functools
    traces = []

    @functools.partial(jax.jit, static_argnames=("plan",))
    def fn(A, *, plan):
        traces.append(1)
        return A * plan.rank

    fn(jnp.ones((2,)), plan=p1)
    fn(jnp.ones((2,)), plan=p2)
    assert len(traces) == 1
