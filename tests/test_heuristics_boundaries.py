"""Boundary behaviour of the §4.2/§4.3 heuristics, exactly at the paper's
thresholds, and proof that the plan layer honors every decision."""
import dataclasses

import numpy as np
import pytest

from repro.core import alto, heuristics, plan as plan_mod
from repro.core.heuristics import (BUFFERED_ACCUM_COST, HIGH_REUSE,
                                   MEDIUM_REUSE, PiPolicy, Traversal)
from repro.sparse import synthetic


def _meta_with_reuse(reuse_per_mode):
    x = synthetic.uniform_tensor((16, 12, 8)[:len(reuse_per_mode)],
                                 200, seed=0)
    at = alto.build(x, n_partitions=2)
    return dataclasses.replace(at.meta,
                               fiber_reuse=tuple(reuse_per_mode))


class TestClassifyReuseBoundaries:
    def test_exactly_high_threshold_is_medium(self):
        # classification is strict-greater at HIGH_REUSE (Table 1)
        assert heuristics.classify_reuse(HIGH_REUSE) == "medium"
        assert heuristics.classify_reuse(np.nextafter(HIGH_REUSE,
                                                      np.inf)) == "high"

    def test_exactly_medium_threshold_is_medium(self):
        # ...but inclusive at MEDIUM_REUSE
        assert heuristics.classify_reuse(MEDIUM_REUSE) == "medium"
        assert heuristics.classify_reuse(np.nextafter(MEDIUM_REUSE,
                                                      -np.inf)) == "limited"

    def test_tensor_class_takes_worst_mode(self):
        meta = _meta_with_reuse((HIGH_REUSE + 1, MEDIUM_REUSE, 100.0))
        assert heuristics.tensor_reuse_class(meta) == "medium"
        meta = _meta_with_reuse((100.0, MEDIUM_REUSE - 1, 100.0))
        assert heuristics.tensor_reuse_class(meta) == "limited"


class TestTraversalBoundary:
    def test_exactly_buffered_cost_goes_oriented(self):
        """Recursive pays off only STRICTLY above the 4-memory-op cost."""
        meta = _meta_with_reuse((BUFFERED_ACCUM_COST,) * 3)
        for mode in range(3):
            assert heuristics.choose_traversal(meta, mode) \
                is Traversal.OUTPUT_ORIENTED

    def test_epsilon_above_goes_recursive(self):
        above = np.nextafter(BUFFERED_ACCUM_COST, np.inf)
        meta = _meta_with_reuse((above,) * 3)
        for mode in range(3):
            assert heuristics.choose_traversal(meta, mode) \
                is Traversal.RECURSIVE

    def test_per_mode_independence(self):
        meta = _meta_with_reuse((BUFFERED_ACCUM_COST + 1,
                                 BUFFERED_ACCUM_COST,
                                 BUFFERED_ACCUM_COST - 1))
        got = [heuristics.choose_traversal(meta, m) for m in range(3)]
        assert got == [Traversal.RECURSIVE, Traversal.OUTPUT_ORIENTED,
                       Traversal.OUTPUT_ORIENTED]


class TestPiPolicyBoundary:
    def test_factor_bytes_exactly_at_budget_stays_otf(self):
        """PRE requires factors STRICTLY over fast memory (§4.3)."""
        meta = _meta_with_reuse((1.0, 1.0, 1.0))        # limited reuse
        rank, vb = 4, 4
        budget = sum(I * rank * vb for I in meta.dims)
        assert heuristics.choose_pi_policy(
            meta, rank, value_bytes=vb, fast_mem_bytes=budget) \
            is PiPolicy.OTF
        assert heuristics.choose_pi_policy(
            meta, rank, value_bytes=vb, fast_mem_bytes=budget - 1) \
            is PiPolicy.PRE

    def test_medium_reuse_never_pre(self):
        meta = _meta_with_reuse((MEDIUM_REUSE,) * 3)    # medium, not limited
        assert heuristics.choose_pi_policy(
            meta, 64, fast_mem_bytes=1) is PiPolicy.OTF


class TestPlanHonorsHeuristics:
    @pytest.mark.parametrize("reuse", [
        (BUFFERED_ACCUM_COST, BUFFERED_ACCUM_COST + 2, 1.0),
        (100.0, 100.0, 100.0),
        (1.0, 1.0, 1.0),
    ])
    def test_traversal_decisions_copied_into_plan(self, reuse):
        meta = _meta_with_reuse(reuse)
        plan = plan_mod.make_plan(meta, 8)
        for mode in range(3):
            assert plan.modes[mode].traversal \
                is heuristics.choose_traversal(meta, mode)

    def test_pi_policy_copied_into_plan(self):
        meta = _meta_with_reuse((1.0, 1.0, 1.0))
        tight = plan_mod.make_plan(meta, 8, fast_mem_bytes=1)
        roomy = plan_mod.make_plan(meta, 8)
        assert tight.pi_policy is heuristics.choose_pi_policy(
            meta, 8, fast_mem_bytes=1)
        assert tight.pi_policy is PiPolicy.PRE
        assert roomy.pi_policy is PiPolicy.OTF

    def test_views_built_only_for_oriented_modes(self):
        meta = _meta_with_reuse((100.0, 1.0, 100.0))
        x = synthetic.uniform_tensor((16, 12, 8), 200, seed=0)
        at = alto.build(x, n_partitions=2)
        at = alto.AltoTensor(meta, at.words, at.values, at.part_start,
                             at.part_end)
        plan = plan_mod.make_plan(meta, 4)
        views = plan_mod.build_views(at, plan)
        assert sorted(views) == [1]

    def test_cpapr_reports_plan_decisions(self):
        x, _ = synthetic.lowrank_count((12, 10, 8), rank=2,
                                       nnz_target=250, seed=5)
        at = alto.build(x, n_partitions=2)
        from repro.core import cpapr
        plan = plan_mod.make_plan(at.meta, 2, backend="reference")
        res = cpapr.cp_apr(at, rank=2, seed=1,
                           params=cpapr.CpaprParams(k_max=1), plan=plan)
        assert res.traversals == list(plan.traversals())
        assert res.pi_policy == plan.pi_policy.value
