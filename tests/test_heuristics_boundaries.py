"""Boundary behaviour of the §4.2/§4.3 heuristics, exactly at the paper's
thresholds, and proof that the plan layer honors every decision."""
import dataclasses

import numpy as np
import pytest

from repro.core import alto, heuristics, plan as plan_mod
from repro.core.heuristics import (BUFFERED_ACCUM_COST, HIGH_REUSE,
                                   MEDIUM_REUSE, PiPolicy, Traversal)
from repro.sparse import synthetic


def _meta_with_reuse(reuse_per_mode):
    x = synthetic.uniform_tensor((16, 12, 8)[:len(reuse_per_mode)],
                                 200, seed=0)
    at = alto.build(x, n_partitions=2)
    return dataclasses.replace(at.meta,
                               fiber_reuse=tuple(reuse_per_mode))


class TestClassifyReuseBoundaries:
    def test_exactly_high_threshold_is_medium(self):
        # classification is strict-greater at HIGH_REUSE (Table 1)
        assert heuristics.classify_reuse(HIGH_REUSE) == "medium"
        assert heuristics.classify_reuse(np.nextafter(HIGH_REUSE,
                                                      np.inf)) == "high"

    def test_exactly_medium_threshold_is_medium(self):
        # ...but inclusive at MEDIUM_REUSE
        assert heuristics.classify_reuse(MEDIUM_REUSE) == "medium"
        assert heuristics.classify_reuse(np.nextafter(MEDIUM_REUSE,
                                                      -np.inf)) == "limited"

    def test_tensor_class_takes_worst_mode(self):
        meta = _meta_with_reuse((HIGH_REUSE + 1, MEDIUM_REUSE, 100.0))
        assert heuristics.tensor_reuse_class(meta) == "medium"
        meta = _meta_with_reuse((100.0, MEDIUM_REUSE - 1, 100.0))
        assert heuristics.tensor_reuse_class(meta) == "limited"


class TestTraversalBoundary:
    def test_exactly_buffered_cost_goes_oriented(self):
        """Recursive pays off only STRICTLY above the 4-memory-op cost."""
        meta = _meta_with_reuse((BUFFERED_ACCUM_COST,) * 3)
        for mode in range(3):
            assert heuristics.choose_traversal(meta, mode) \
                is Traversal.OUTPUT_ORIENTED

    def test_epsilon_above_goes_recursive(self):
        above = np.nextafter(BUFFERED_ACCUM_COST, np.inf)
        meta = _meta_with_reuse((above,) * 3)
        for mode in range(3):
            assert heuristics.choose_traversal(meta, mode) \
                is Traversal.RECURSIVE

    def test_per_mode_independence(self):
        meta = _meta_with_reuse((BUFFERED_ACCUM_COST + 1,
                                 BUFFERED_ACCUM_COST,
                                 BUFFERED_ACCUM_COST - 1))
        got = [heuristics.choose_traversal(meta, m) for m in range(3)]
        assert got == [Traversal.RECURSIVE, Traversal.OUTPUT_ORIENTED,
                       Traversal.OUTPUT_ORIENTED]


class TestPiPolicyBoundary:
    def test_factor_bytes_exactly_at_budget_stays_otf(self):
        """PRE requires factors STRICTLY over fast memory (§4.3)."""
        meta = _meta_with_reuse((1.0, 1.0, 1.0))        # limited reuse
        rank, vb = 4, 4
        budget = sum(I * rank * vb for I in meta.dims)
        assert heuristics.choose_pi_policy(
            meta, rank, value_bytes=vb, fast_mem_bytes=budget) \
            is PiPolicy.OTF
        assert heuristics.choose_pi_policy(
            meta, rank, value_bytes=vb, fast_mem_bytes=budget - 1) \
            is PiPolicy.PRE

    def test_medium_reuse_never_pre(self):
        meta = _meta_with_reuse((MEDIUM_REUSE,) * 3)    # medium, not limited
        assert heuristics.choose_pi_policy(
            meta, 64, fast_mem_bytes=1) is PiPolicy.OTF


class TestPlanHonorsHeuristics:
    @pytest.mark.parametrize("reuse", [
        (BUFFERED_ACCUM_COST, BUFFERED_ACCUM_COST + 2, 1.0),
        (100.0, 100.0, 100.0),
        (1.0, 1.0, 1.0),
    ])
    def test_traversal_decisions_copied_into_plan(self, reuse):
        """The plan honors the family choice; an output-oriented mode is
        then refined to one-hot merge vs scratch carry by the traffic
        model (`choose_oriented_variant`), which the plan must copy."""
        meta = _meta_with_reuse(reuse)
        plan = plan_mod.make_plan(meta, 8)
        for mode in range(3):
            family = heuristics.choose_traversal(meta, mode)
            got = plan.modes[mode].traversal
            if family is Traversal.RECURSIVE:
                assert got is Traversal.RECURSIVE
            else:
                assert heuristics.is_oriented(got)
                assert got is heuristics.choose_oriented_variant(
                    meta, mode, 8,
                    carry_feasible=plan_mod.carry_fits_vmem(meta, mode, 8))

    def test_pi_policy_copied_into_plan(self):
        meta = _meta_with_reuse((1.0, 1.0, 1.0))
        tight = plan_mod.make_plan(meta, 8, fast_mem_bytes=1)
        roomy = plan_mod.make_plan(meta, 8)
        assert tight.pi_policy is heuristics.choose_pi_policy(
            meta, 8, fast_mem_bytes=1)
        assert tight.pi_policy is PiPolicy.PRE
        assert roomy.pi_policy is PiPolicy.OTF

    def test_views_built_only_for_oriented_modes(self):
        meta = _meta_with_reuse((100.0, 1.0, 100.0))
        x = synthetic.uniform_tensor((16, 12, 8), 200, seed=0)
        at = alto.build(x, n_partitions=2)
        at = alto.AltoTensor(meta, at.words, at.values, at.part_start,
                             at.part_end)
        plan = plan_mod.make_plan(meta, 4)
        views = plan_mod.build_views(at, plan)
        assert sorted(views) == [1]

    def test_cpapr_reports_plan_decisions(self):
        x, _ = synthetic.lowrank_count((12, 10, 8), rank=2,
                                       nnz_target=250, seed=5)
        at = alto.build(x, n_partitions=2)
        from repro.core import cpapr
        plan = plan_mod.make_plan(at.meta, 2, backend="reference")
        res = cpapr.cp_apr(at, rank=2, seed=1,
                           params=cpapr.CpaprParams(k_max=1), plan=plan)
        assert res.traversals == list(plan.traversals())
        assert res.pi_policy == plan.pi_policy.value


class TestPhiVmemFootprint:
    """Exact byte accounting of the Φ-specific VMEM model — the
    ROADMAP-flagged gap: the fused Φ kernel keeps the full-rank B
    (I_mode × R) resident per grid step plus the gathered block B rows,
    which the MTTKRP-shaped model never budgeted."""

    def _meta(self, dims=(64, 48, 32), nnz=2000, L=4):
        x = synthetic.uniform_tensor(dims, nnz, seed=0)
        return alto.build(x, n_partitions=L).meta

    def test_phi_oriented_exact_bytes_otf(self):
        meta = self._meta()
        mode, bm, R, db = 1, 64, 8, 4
        W = meta.enc.n_words
        want = (bm * W * 4                      # words tile
                + bm * 4                        # rows tile (int32)
                + bm * db                       # values tile
                + bm * bm * db                  # segment one-hot
                + meta.dims[mode] * R * db      # RESIDENT full-rank B
                + bm * R * db                   # gathered B block rows
                + 2 * bm * R * db               # krp + contrib
                + bm * R * db                   # segment-sum output tile
                + sum(I for m, I in enumerate(meta.dims)
                      if m != mode) * R * db)   # resident other factors
        got = plan_mod.phi_oriented_vmem_bytes(meta, mode, bm, R, db)
        assert got == want

    def test_phi_oriented_pre_streams_pi_instead_of_factors(self):
        meta = self._meta()
        mode, bm, R, db = 0, 128, 16, 4
        otf = plan_mod.phi_oriented_vmem_bytes(meta, mode, bm, R, db,
                                               pre_pi=False)
        pre = plan_mod.phi_oriented_vmem_bytes(meta, mode, bm, R, db,
                                               pre_pi=True)
        others = sum(I for m, I in enumerate(meta.dims) if m != mode)
        # PRE swaps the resident factors for a (block_m, R) Π tile
        assert otf - pre == (others - bm) * R * db

    def test_phi_recursive_exact_bytes_otf(self):
        meta = self._meta(L=4)
        mode, R, db = 2, 8, 4
        L = meta.n_partitions
        chunk = -(-max(meta.nnz, L) // L)
        T = meta.temp_rows[mode]
        W = meta.enc.n_words
        want = (chunk * W * 4                   # words tile
                + chunk * db                    # values tile
                + chunk * T * db                # Temp one-hot
                + meta.dims[mode] * R * db      # RESIDENT full-rank B
                + chunk * R * db                # gathered B rows
                + 2 * chunk * R * db            # krp + contrib
                + T * R * db                    # partition Temp output
                + sum(I for m, I in enumerate(meta.dims)
                      if m != mode) * R * db)   # resident other factors
        got = plan_mod.phi_recursive_vmem_bytes(meta, mode, R, db)
        assert got == want

    def test_resident_b_scales_with_mode_dim_not_block(self):
        """The gap term: growing I_mode must grow the Φ footprint even
        with every blocking knob frozen (B is resident whole)."""
        small = self._meta(dims=(64, 48, 32))
        big = self._meta(dims=(4096, 48, 32))
        R, bm = 16, 64
        delta = (plan_mod.phi_oriented_vmem_bytes(big, 0, bm, R)
                 - plan_mod.phi_oriented_vmem_bytes(small, 0, bm, R))
        assert delta >= (4096 - 64) * R * 4     # at least the B rows

    def test_phi_footprint_constrains_plan_block_m(self):
        """On a big mode with a tight budget the Φ-aware choice must pick
        a smaller block than the MTTKRP-only model would."""
        meta = self._meta(dims=(2048, 16, 12), nnz=3000)
        R = 16
        budget = plan_mod.phi_oriented_vmem_bytes(
            meta, 0, plan_mod.MAX_BLOCK_M, R) - 1
        assert plan_mod.phi_constraint_active(meta, 0, R,
                                              vmem_limit=budget)
        rb = plan_mod.choose_rank_block_oriented(meta, 0, R,
                                                 vmem_limit=budget)
        mttkrp_only = plan_mod.choose_block_m(meta, 0, rb,
                                              vmem_limit=budget)
        phi_aware = plan_mod.choose_block_m(meta, 0, rb, vmem_limit=budget,
                                            rank=R)
        assert phi_aware < mttkrp_only
        assert plan_mod.phi_oriented_vmem_bytes(meta, 0, phi_aware, R) \
            <= budget

    def test_unsatisfiable_phi_budget_does_not_throttle_mttkrp(self):
        """When the resident-B term alone overflows the budget at every
        block size, Φ spills regardless — the vacuous constraint must
        not drag the MTTKRP kernel's block down to the minimum."""
        meta = self._meta(dims=(4096, 24, 16), nnz=3000)
        R = 64
        # budget below Φ's floor but roomy for MTTKRP tiles
        budget = plan_mod.phi_oriented_vmem_bytes(
            meta, 0, plan_mod.MIN_BLOCK_M, R) - 1
        assert not plan_mod.phi_constraint_active(meta, 0, R,
                                                  vmem_limit=budget)
        rb = plan_mod.choose_rank_block_oriented(meta, 0, R,
                                                 vmem_limit=budget)
        mttkrp_only = plan_mod.choose_block_m(meta, 0, rb,
                                              vmem_limit=budget)
        phi_aware = plan_mod.choose_block_m(meta, 0, rb, vmem_limit=budget,
                                            rank=R)
        assert phi_aware == mttkrp_only > plan_mod.MIN_BLOCK_M
        # and the candidate space keeps those larger blocks visible
        # (at the same rank tile; smaller tiles may go larger still)
        cands = plan_mod.candidate_mode_plans(meta, 0, R,
                                              vmem_limit=budget)
        same_rb = [c for c in cands
                   if c.traversal is heuristics.Traversal.OUTPUT_ORIENTED
                   and c.r_block == rb]
        assert max(c.block_m for c in same_rb) == mttkrp_only

    def test_mode_plan_records_phi_footprint(self):
        meta = self._meta()
        plan = plan_mod.make_plan(meta, 8)
        pre = plan.pi_policy is heuristics.PiPolicy.PRE
        assert Traversal.ORIENTED_CARRY in {mp.traversal
                                            for mp in plan.modes}
        for mp in plan.modes:
            if mp.traversal is Traversal.OUTPUT_ORIENTED:
                want = plan_mod.phi_oriented_vmem_bytes(
                    meta, mp.mode, mp.block_m, plan.rank, pre_pi=pre)
            elif mp.traversal is Traversal.ORIENTED_CARRY:
                want = plan_mod.phi_oriented_carry_vmem_bytes(
                    meta, mp.mode, mp.block_m, plan.rank, pre_pi=pre)
            else:
                want = plan_mod.phi_recursive_vmem_bytes(
                    meta, mp.mode, plan.rank, pre_pi=pre)
            assert mp.phi_vmem_bytes == want > 0


class TestCarryVmemFootprint:
    """Exact byte accounting of the scratch-carry kernel's VMEM model:
    no (block_m, block_m) one-hot, but the (I_mode, r_block) output tile
    and the carry scratch are resident across the whole sequential scan."""

    def _meta(self, dims=(64, 48, 32), nnz=2000, L=4):
        x = synthetic.uniform_tensor(dims, nnz, seed=0)
        return alto.build(x, n_partitions=L).meta

    def test_carry_exact_bytes(self):
        meta = self._meta()
        mode, bm, rb, db = 1, 64, 8, 4
        W = meta.enc.n_words
        want = (bm * W * 4                      # words tile
                + bm * 4                        # rows tile (int32)
                + bm * db                       # values tile
                + 3 * bm * rb * db              # krp + contrib + seg sums
                + meta.dims[mode] * rb * db     # RESIDENT output tile
                + rb * db                       # carry scratch row
                + sum(I for m, I in enumerate(meta.dims)
                      if m != mode) * rb * db)  # resident other factors
        got = plan_mod.oriented_carry_vmem_bytes(meta, mode, bm, rb, db)
        assert got == want

    def test_phi_carry_exact_bytes_otf(self):
        meta = self._meta()
        mode, bm, R, db = 0, 32, 8, 4
        W = meta.enc.n_words
        want = (bm * W * 4                      # words tile
                + bm * 4                        # rows tile
                + bm * db                       # values tile
                + meta.dims[mode] * R * db      # RESIDENT full-rank B
                + bm * R * db                   # gathered B block rows
                + 2 * bm * R * db               # krp + contrib
                + bm * R * db                   # segment sums
                + meta.dims[mode] * R * db      # RESIDENT output block
                + R * db                        # carry scratch row
                + sum(I for m, I in enumerate(meta.dims)
                      if m != mode) * R * db)   # resident other factors
        got = plan_mod.phi_oriented_carry_vmem_bytes(meta, mode, bm, R, db)
        assert got == want

    def test_phi_carry_pre_streams_pi_instead_of_factors(self):
        meta = self._meta()
        mode, bm, R, db = 0, 128, 16, 4
        otf = plan_mod.phi_oriented_carry_vmem_bytes(meta, mode, bm, R, db,
                                                     pre_pi=False)
        pre = plan_mod.phi_oriented_carry_vmem_bytes(meta, mode, bm, R, db,
                                                     pre_pi=True)
        others = sum(I for m, I in enumerate(meta.dims) if m != mode)
        assert otf - pre == (others - bm) * R * db

    def test_no_onehot_term(self):
        """Doubling block_m must grow the carry footprint linearly (the
        one-hot kernel grows quadratically) — the whole point of the
        rewrite."""
        meta = self._meta()
        rb = 4
        c = [plan_mod.oriented_carry_vmem_bytes(meta, 0, bm, rb)
             for bm in (128, 256, 512)]
        assert c[2] - c[1] == 2 * (c[1] - c[0])     # linear in block_m
        o = [plan_mod.oriented_vmem_bytes(meta, 0, bm, rb)
             for bm in (128, 256, 512)]
        assert o[2] - o[1] > 2 * (o[1] - o[0])      # quadratic one-hot

    def test_resident_output_scales_with_mode_dim(self):
        small = self._meta(dims=(64, 48, 32))
        big = self._meta(dims=(4096, 48, 32))
        rb, bm = 8, 64
        delta = (plan_mod.oriented_carry_vmem_bytes(big, 0, bm, rb)
                 - plan_mod.oriented_carry_vmem_bytes(small, 0, bm, rb))
        assert delta >= (4096 - 64) * rb * 4

    def test_carry_feasibility_gate(self):
        """carry_fits_vmem is a hard routing gate: below the resident
        output's floor the static plan must route the one-hot merge."""
        meta = self._meta()
        floor = plan_mod.oriented_carry_vmem_bytes(
            meta, 0, plan_mod.MIN_BLOCK_M, 1)
        assert plan_mod.carry_fits_vmem(meta, 0, 8, vmem_limit=floor)
        assert not plan_mod.carry_fits_vmem(meta, 0, 8,
                                            vmem_limit=floor - 1)
        mp = plan_mod.static_mode_plan(meta, 0, 8, vmem_limit=floor - 1)
        assert mp.traversal is Traversal.OUTPUT_ORIENTED
        # and the candidate space hard-gates carry candidates too
        cands = plan_mod.candidate_mode_plans(meta, 0, 8,
                                              vmem_limit=floor - 1)
        assert Traversal.ORIENTED_CARRY not in {c.traversal for c in cands}


class TestOrientedVariantTrafficBoundary:
    """The one-hot-vs-carry refinement is a pure HBM-traffic comparison:
    carry wins iff 2·I_n·R < 2·M·R + M·4/db + I_n·R (in elements)."""

    def _meta_with_dims(self, dims, nnz):
        x = synthetic.uniform_tensor(dims, nnz, seed=0)
        at = alto.build(x, n_partitions=2)
        return dataclasses.replace(at.meta, fiber_reuse=(1.0,) * len(dims))

    def test_traffic_terms_exact(self):
        meta = self._meta_with_dims((40, 30, 20), 500)
        R, db = 16, 4
        M = heuristics.stream_len(meta)
        assert heuristics.oriented_merge_traffic_bytes(meta, 0, R, db) \
            == 2 * M * R * db + M * 4 + meta.dims[0] * R * db
        assert heuristics.carry_traffic_bytes(meta, 0, R, db) \
            == 2 * meta.dims[0] * R * db

    def test_nnz_heavy_mode_goes_carry(self):
        meta = self._meta_with_dims((40, 30, 20), 5000)   # stream >> I_0
        assert heuristics.choose_oriented_variant(meta, 0, 16) \
            is heuristics.Traversal.ORIENTED_CARRY

    def test_hyper_sparse_long_mode_stays_onehot(self):
        # I_0 dwarfs the stream: resident-output traffic loses
        meta = self._meta_with_dims((100_000, 4, 3), 64)
        assert heuristics.choose_oriented_variant(meta, 0, 16) \
            is heuristics.Traversal.OUTPUT_ORIENTED

    def test_infeasible_carry_never_chosen(self):
        meta = self._meta_with_dims((40, 30, 20), 5000)
        assert heuristics.choose_oriented_variant(
            meta, 0, 16, carry_feasible=False) \
            is heuristics.Traversal.OUTPUT_ORIENTED


class TestChunkByteModels:
    """Byte-exact accounting of the out-of-core (HBM) chunk models and
    the chunk-size choice they drive — mirrors TestCarryVmemFootprint:
    every term is re-derived here by hand, so a silent model edit goes
    red, not just a routing flip."""

    def _meta(self, dims=(64, 48, 32), nnz=2000, L=4):
        x = synthetic.uniform_tensor(dims, nnz, seed=0)
        return alto.build(x, n_partitions=L).meta

    def test_stream_elem_exact_bytes(self):
        meta = self._meta()
        for db in (4, 8):
            want = (meta.enc.n_words * 4    # linearized index words
                    + 4                     # row index (int32)
                    + db)                   # value
            assert plan_mod.stream_elem_bytes(meta, db) == want

    def test_resident_exact_bytes(self):
        meta = self._meta()
        R, db = 8, 4
        i_max = max(meta.dims)
        want = (sum(meta.dims) * R * db     # all factors
                + i_max * R * db            # worst-mode output accumulator
                + i_max * R * db            # Φ's resident B operand
                + 4 + R * db)               # carry (row, value) pair
        assert plan_mod.streaming_resident_bytes(meta, R, db) == want

    def test_incore_working_set_exact_bytes(self):
        meta = self._meta()
        R, db = 8, 4
        want = (heuristics.stream_len(meta)
                * plan_mod.stream_elem_bytes(meta, db)
                + plan_mod.streaming_resident_bytes(meta, R, db))
        assert plan_mod.incore_working_set_bytes(meta, R, db) == want

    def test_chunk_hbm_exact_bytes(self):
        """Two in-flight chunks (compute + prefetch) plus the residency."""
        meta = self._meta()
        R, db = 8, 4
        for chunk_m in (64, 256, 1024):
            want = (2 * chunk_m * plan_mod.stream_elem_bytes(meta, db)
                    + plan_mod.streaming_resident_bytes(meta, R, db))
            assert plan_mod.chunk_hbm_bytes(meta, chunk_m, R, db) == want

    def test_needs_streaming_strict_boundary(self):
        """Streaming triggers STRICTLY above the budget: a working set
        exactly equal to device_bytes stays in-core."""
        meta = self._meta()
        ws = plan_mod.incore_working_set_bytes(meta, 8)
        assert not plan_mod.needs_streaming(meta, 8, ws)
        assert plan_mod.needs_streaming(meta, 8, ws - 1)
        assert plan_mod.make_plan(meta, 8, device_bytes=ws).streaming \
            is None
        assert plan_mod.make_plan(meta, 8,
                                  device_bytes=ws - 1).streaming \
            is not None

    def test_chosen_chunk_fits_budget_and_alignment(self):
        """Above the advisory floor the chosen chunk's double-buffered
        footprint fits the budget, sits on the alignment grid, and one
        more alignment step would overflow."""
        meta = self._meta()
        R, align = 8, 64
        resident = plan_mod.streaming_resident_bytes(meta, R)
        elem = plan_mod.stream_elem_bytes(meta)
        for chunks_worth in (2, 5, 11):
            budget = resident + 2 * elem * (chunks_worth * align) + 1
            cm = plan_mod.choose_chunk_m(meta, R, budget, align)
            assert cm == chunks_worth * align
            assert cm % align == 0
            assert plan_mod.chunk_hbm_bytes(meta, cm, R) <= budget
            assert plan_mod.chunk_hbm_bytes(meta, cm + align, R) > budget

    def test_chunk_advisory_floor_and_stream_cap(self):
        """Below the floor one aligned chunk is returned (advisory, like
        the VMEM choosers); a huge budget caps at the aligned stream."""
        meta = self._meta()
        align = 64
        assert plan_mod.choose_chunk_m(meta, 8, 0, align) == align
        padded = -(-heuristics.stream_len(meta) // align) * align
        assert plan_mod.choose_chunk_m(meta, 8, 1 << 50, align) == padded

    def test_chunk_count_block_m_independent(self):
        """n_chunks is a property of (stream, chunk_m), not of the block
        padding: the executor's grid over the block_m-padded stream
        matches the model for every block size dividing chunk_m."""
        from repro.core import stream as stream_mod
        x = synthetic.uniform_tensor((64, 48, 32), 2000, seed=0)
        at = alto.build(x, n_partitions=4)
        hs = stream_mod.host_stream(at, 0)
        for chunk_m in (64, 128, 512):
            want = plan_mod.chunk_count(at.meta, chunk_m)
            for bm in (8, 16, 32, 64):
                padded = hs.padded_len(bm)
                executed = -(-padded // chunk_m)
                assert executed == want, (chunk_m, bm)

    def test_stream_plan_records_model_outputs(self):
        """The StreamPlan on a streaming plan carries exactly the model
        numbers: chunk from choose_chunk_m at the plan's alignment,
        count from chunk_count, working set from the in-core model."""
        meta = self._meta()
        R = 8
        budget = plan_mod.streaming_resident_bytes(meta, R) + 4096
        plan = plan_mod.make_plan(meta, R, device_bytes=budget)
        sp = plan.streaming
        assert sp is not None
        align = max(m.block_m for m in plan.modes)
        assert sp.chunk_m == plan_mod.choose_chunk_m(meta, R, budget,
                                                     align)
        assert sp.n_chunks == plan_mod.chunk_count(meta, sp.chunk_m)
        assert sp.device_bytes == budget
        assert sp.stream_bytes == plan_mod.incore_working_set_bytes(
            meta, R)
