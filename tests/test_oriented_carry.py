"""Scratch-carry oriented kernels vs the one-hot merge path vs jnp.

Adversarial *run layouts* for the sorted-stream reduction — the shapes
where the inter-block carry logic can go wrong:

  * every row identical (a single run covering every block);
  * every row distinct (no run ever crosses a boundary, carry always
    flushes);
  * one run spanning the entire stream including the alto/block padding;
  * a run crossing >= 3 block boundaries with noise on both sides.

The acceptance condition is *bit-identical* MTTKRP/Φ between
`ops.mttkrp_oriented`+`segment_merge` and `ops.mttkrp_oriented_carry`:
within-block segment sums accumulate in the same element order, and the
carry chain only re-associates cross-block partials by IEEE-commutative
swaps (see `kernels/mttkrp_oriented.py`). The jnp oracle reduces in a
different association order (flat segment_sum), so it is held to a tight
relative tolerance instead.

Runs on the hermetic tests/proptest.py harness (no hypothesis offline).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import given, settings, strategies as st

from repro.core import alto, mttkrp as core_mttkrp
from repro.kernels import ops
from repro.sparse.tensor import SparseTensor

TOL = 1e-5
DIMS = (29, 13, 7)          # non-pow2; mode 0 is the reduction target
MODE = 0


def _stream_tensor(row_counts, seed):
    """SparseTensor whose mode-0 rows appear with the given multiplicities
    (the oriented view of mode 0 is then exactly the prescribed run
    layout, up to alto's replicate-last padding)."""
    rng = np.random.default_rng(seed)
    rows = np.repeat(np.arange(len(row_counts), dtype=np.int32),
                     row_counts)
    coords = np.stack(
        [rows] + [rng.integers(0, I, size=rows.shape[0]).astype(np.int32)
                  for I in DIMS[1:]], axis=1)
    values = rng.standard_normal(rows.shape[0]).astype(np.float32)
    return SparseTensor(DIMS, coords, values)


def _factors(seed, R=8):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(np.abs(rng.standard_normal((I, R))
                               ).astype(np.float32) + 0.05) for I in DIMS]


def _layout_counts(layout, block_m, rng):
    """Per-row multiplicities realizing the adversarial layout."""
    I0 = DIMS[0]
    counts = np.zeros(I0, dtype=np.int64)
    if layout == "identical":
        # one row owns the whole stream: a single run covering every
        # block AND >= 3 block boundaries
        counts[int(rng.integers(I0))] = 4 * block_m + 3
    elif layout == "distinct":
        # every present row appears exactly once: blocks of all-distinct
        # rows, the carry flushes at every boundary
        n = min(I0, 3 * block_m)
        counts[rng.choice(I0, size=n, replace=False)] = 1
    elif layout == "boundary_run":
        # noise, then one run crossing >= 3 block boundaries, then noise
        counts[:] = rng.integers(0, 3, size=I0)
        counts[int(rng.integers(I0))] = 3 * block_m + 2
    else:                                   # "mixed"
        counts[:] = rng.integers(0, 2 * block_m, size=I0)
        if counts.sum() == 0:
            counts[0] = 1
    return counts


def _assert_parity(x, block_m, r_block, seed):
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(seed)

    ori = ops.mttkrp_oriented(view, factors, block_m=block_m,
                              r_block=r_block, interpret=True)
    car = ops.mttkrp_oriented_carry(view, factors, block_m=block_m,
                                    r_block=r_block, interpret=True)
    assert jnp.array_equal(ori, car), (
        "carry path not bit-identical to one-hot merge path")

    ref = core_mttkrp.mttkrp_oriented(view, factors)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    assert float(jnp.max(jnp.abs(car - ref))) / scale < TOL


@pytest.mark.parametrize("layout", ["identical", "distinct",
                                    "boundary_run", "mixed"])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       block_m=st.sampled_from([8, 16, 64]),
       r_block=st.sampled_from([2, 4, 8]))
def test_mttkrp_carry_bit_identical(layout, seed, block_m, r_block):
    rng = np.random.default_rng(seed)
    x = _stream_tensor(_layout_counts(layout, block_m, rng), seed)
    _assert_parity(x, block_m, r_block, seed)


@pytest.mark.parametrize("layout", ["identical", "distinct",
                                    "boundary_run", "mixed"])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       block_m=st.sampled_from([8, 32]),
       pre=st.booleans())
def test_phi_carry_bit_identical(layout, seed, block_m, pre):
    rng = np.random.default_rng(seed)
    x = _stream_tensor(_layout_counts(layout, block_m, rng), seed)
    # count data for the Poisson model
    x = SparseTensor(DIMS, x.coords,
                     np.abs(x.values).astype(np.float32) + 0.5)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, MODE)
    factors = _factors(seed)
    B = jnp.abs(factors[MODE]) + 0.1
    if pre:
        coords = alto.delinearize(at.meta.enc, view.words)
        kw = dict(pi=core_mttkrp.krp_rows(coords, factors, MODE))
    else:
        kw = dict(factors=factors)
    ori = ops.cpapr_phi_oriented(view, B, block_m=block_m,
                                 interpret=True, **kw)
    car = ops.cpapr_phi_oriented_carry(view, B, block_m=block_m,
                                       interpret=True, **kw)
    assert jnp.array_equal(ori, car), (
        "Φ carry path not bit-identical to one-hot merge path")


def test_carry_all_modes_of_real_tensor():
    """End-to-end over every mode of a generic tensor (duplicates sum)."""
    from repro.sparse import synthetic
    x = synthetic.zipf_tensor((24, 18, 10), 1500, seed=3, count_data=True)
    at = alto.build(x, n_partitions=4)
    fs = [jnp.asarray(np.random.default_rng(11).standard_normal(
        (I, 8)).astype(np.float32)) for I in x.dims]
    for mode in range(x.ndim):
        view = alto.oriented_view(at, mode)
        ori = ops.mttkrp_oriented(view, fs, block_m=16, r_block=4,
                                  interpret=True)
        car = ops.mttkrp_oriented_carry(view, fs, block_m=16, r_block=4,
                                        interpret=True)
        assert jnp.array_equal(ori, car)


def test_carry_empty_tensor_returns_zeros():
    x = SparseTensor((9, 6, 4), np.zeros((0, 3), np.int32),
                     np.zeros((0,), np.float32))
    at = alto.build(x, n_partitions=4)
    view = alto.oriented_view(at, MODE)
    fs = _factors(0)
    fs = [f[:I] for f, I in zip(fs, (9, 6, 4))]
    out = ops.mttkrp_oriented_carry(view, fs, block_m=8, interpret=True)
    assert out.shape == (9, 8)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_carry_rejects_non_dividing_rank_tile():
    from repro.sparse import synthetic
    x = synthetic.uniform_tensor((12, 8, 6), 200, seed=0)
    at = alto.build(x, n_partitions=2)
    view = alto.oriented_view(at, 0)
    fs = _factors(1, R=7)
    fs = [f[:I] for f, I in zip(fs, (12, 8, 6))]
    with pytest.raises(ValueError, match="r_block"):
        ops.mttkrp_oriented_carry(view, fs, r_block=4, interpret=True)
