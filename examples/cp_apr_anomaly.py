"""CP-APR anomaly detection on count data (the paper's §1 use case).

Plants a rank-3 Poisson model plus a localized anomalous block, runs
CP-APR MU with the adaptive ALTO heuristics, and shows the anomaly
concentrating in one component.

  PYTHONPATH=src python examples/cp_apr_anomaly.py
"""
import numpy as np

from repro.core import alto, cpapr
from repro.sparse import synthetic
from repro.sparse.tensor import SparseTensor

# normal traffic: planted low-rank Poisson counts
x, _ = synthetic.lowrank_count((60, 40, 30), rank=3, nnz_target=8000,
                               seed=0)
# anomaly: a hot block of interactions (e.g. one scanner hitting one port)
rng = np.random.default_rng(1)
n_anom = 300
a_coords = np.stack([rng.integers(50, 55, n_anom),
                     rng.integers(30, 34, n_anom),
                     rng.integers(25, 28, n_anom)], axis=1).astype(np.int32)
a_vals = rng.integers(20, 60, n_anom).astype(np.float32)
x_all = SparseTensor(x.dims, np.concatenate([x.coords, a_coords]),
                     np.concatenate([x.values, a_vals])).deduplicate()

at = alto.build(x_all, n_partitions=8)
res = cpapr.cp_apr(at, rank=4, seed=2, track_ll=True,
                   params=cpapr.CpaprParams(k_max=20))
print(f"CP-APR: {res.n_outer} outer iters, policy={res.pi_policy}, "
      f"traversals={res.traversals}")
print(f"log-likelihood: {res.log_likelihoods[0]:.0f} -> "
      f"{res.log_likelihoods[-1]:.0f}")

# the component whose mode-0 factor concentrates on rows 50-54 is the scan
A0 = np.asarray(res.factors[0])
conc = A0[50:55].sum(axis=0) / (A0.sum(axis=0) + 1e-9)
best = int(np.argmax(conc))
print(f"anomaly concentration per component: {conc.round(3)}")
print(f"-> component {best} captures the injected scanner "
      f"({100 * conc[best]:.0f}% of its mode-0 mass in rows 50-54)")
assert conc[best] > 0.5, "anomaly should dominate one component"
