"""End-to-end driver (the paper's workload kind): decompose a large
synthetic count tensor to convergence with fault-tolerant checkpointing —
restartable at any iteration.

  PYTHONPATH=src python examples/decompose_e2e.py [--iters 30]
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import checkpoint as ck
from repro.core import alto, cpals
from repro.sparse import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--nnz", type=int, default=500_000)
    ap.add_argument("--ckpt-dir",
                    default=os.path.join(tempfile.gettempdir(),
                                         "alto_e2e_ckpt"))
    args = ap.parse_args()

    x = synthetic.zipf_tensor((4096, 2048, 1024, 64), args.nnz, a=1.3,
                              seed=0, count_data=True)
    print(f"tensor: dims={x.dims} nnz={x.nnz}")
    t0 = time.time()
    at = alto.build(x, n_partitions=32)
    print(f"ALTO build: {time.time()-t0:.2f}s "
          f"(index {at.meta.enc.total_bits} bits, "
          f"reuse class per mode "
          f"{[f'{r:.1f}' for r in at.meta.fiber_reuse]})")

    # resume if a checkpoint exists
    import jax.numpy as jnp
    factors = None
    start = 0
    last = ck.latest_step(args.ckpt_dir)
    if last is not None:
        like = cpals.init_factors(x.dims, args.rank, seed=0)
        factors, manifest = ck.restore(args.ckpt_dir, last, like)
        start = manifest["step"]
        print(f"resumed from iteration {start}")

    fits = []
    for it in range(start, args.iters, 5):
        res = cpals.cp_als(at, rank=args.rank, n_iters=5, tol=0, seed=0,
                           factors=factors)
        factors = res.factors
        fits += res.fits
        ck.save(args.ckpt_dir, it + 5, factors)
        print(f"iters {it + 1}-{it + 5}: fit {res.fits[-1]:.4f} "
              f"(checkpointed)")
    print(f"final fit {fits[-1]:.4f} in {time.time()-t0:.1f}s total")


if __name__ == "__main__":
    main()
