"""Train a ~100M-parameter LM for a few hundred steps on CPU with the
production train loop (checkpointing, grad clipping, cosine schedule).

By default uses a width-reduced smollm config sized to ~100M params; pass
--full-360m to use the exact assigned smollm-360m config (slow on CPU).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys

from repro.configs import get_config
from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full-360m", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    argv = ["--arch", "smollm-360m", "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--log-every", "10"]
    if args.ckpt_dir:
        argv += ["--ckpt-dir", args.ckpt_dir]
    if not args.full_360m:
        # ~100M params: half width/depth of smollm-360m
        import repro.configs as C
        base = get_config("smollm-360m")
        cfg = dataclasses.replace(
            base, n_layers=16, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=0, d_ff=2048, remat=False, dtype="float32")
        # register a transient config the launcher can resolve
        C._MODULES["smollm-100m"] = None
        real_get = C.get_config

        def patched(name):
            if name == "smollm-100m":
                return cfg
            return real_get(name)

        C.get_config = patched
        train_mod.get_config = patched
        argv[1] = "smollm-100m"
    sys.argv = ["train"] + argv
    train_mod.main()


if __name__ == "__main__":
    main()
