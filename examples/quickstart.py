"""Quickstart: build an ALTO tensor and decompose it with CP-ALS.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import alto, cpals, encoding as E, heuristics
from repro.sparse import synthetic

# 1. A skewed 4-way count tensor (UBER-like regime from the paper).
x = synthetic.paper_like("uber_like")
print(f"tensor: dims={x.dims} nnz={x.nnz} density={x.density:.2e}")

# 2. ALTO format generation: linearize -> sort -> balanced partitions.
at = alto.build(x, n_partitions=16)
enc = at.meta.enc
print(f"ALTO index: {enc.total_bits} bits in {enc.n_words} u32 word(s); "
      f"COO would need {enc.storage_bits_coo(32)} bits "
      f"(compression {enc.storage_bits_coo(32) / enc.storage_bits_alto(32):.2f}x)")
print(f"fiber reuse per mode: "
      f"{[f'{r:.1f}' for r in at.meta.fiber_reuse]} "
      f"-> class {heuristics.tensor_reuse_class(at.meta)}")
for m in range(x.ndim):
    print(f"  mode {m}: traversal = "
          f"{heuristics.choose_traversal(at.meta, m).value}")

# 3. Decompose.
res = cpals.cp_als(at, rank=8, n_iters=20, seed=0)
print(f"CP-ALS: {res.n_iters} iters, fit {res.fits[-1]:.4f}")
print(f"lambda: {np.asarray(res.lam).round(2)}")
