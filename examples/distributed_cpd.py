"""Distributed CP-ALS across 8 (emulated) devices via shard_map.

The nonzero stream is sharded into equal-nnz device partitions (ALTO's
balanced partitioning lifted to the mesh level); per-device partial
MTTKRPs merge with a psum — the paper's pull-based reduction as an
all-reduce. See src/repro/dist/cpd.py.

  PYTHONPATH=src python examples/distributed_cpd.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.dist.cpd import distributed_cp_als  # noqa: E402
from repro.sparse import synthetic  # noqa: E402

mesh = jax.make_mesh((8,), ("data",))
print(f"mesh: {mesh.shape} over {len(jax.devices())} devices")

x = synthetic.zipf_tensor((512, 256, 128), 200_000, seed=0)
print(f"tensor: dims={x.dims} nnz={x.nnz}")

lam, factors, fits = distributed_cp_als(x, rank=8, mesh=mesh, n_iters=8)
for i, f in enumerate(fits):
    print(f"iter {i}: fit {f:.4f}")
print("distributed decomposition complete;",
      f"factor shapes: {[tuple(f.shape) for f in factors]}")
