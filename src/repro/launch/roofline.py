"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute term    = HLO_FLOPs / peak_FLOPs        (per-device FLOPs)
  memory term     = HLO_bytes / HBM_bw            (per-device bytes)
  collective term = collective_bytes / link_bw    (per-device wire bytes)

`compiled.cost_analysis()` on the SPMD-partitioned module reports
*per-device* numbers, but XLA counts loop (scan) bodies ONCE, not
× trip-count. The dry-run therefore compiles unrolled 1-repeat and
2-repeat calibration variants and extrapolates `total = c1 + (R-1)·(c2-c1)`
— exact for the layer stack since every repeat contributes identical ops.
Collective bytes are parsed from the post-SPMD optimized HLO text
(operand sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute) and extrapolated the same way.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|s8|"
                       r"s16|s32|s64|u4|u8|u16|u32|u64|c64|c128)"
                       r"\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes + op counts from optimized HLO."""
    bytes_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count_by_kind: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in line:      # async pair: count the -start only
            continue
        count_by_kind[kind] += 1
        # shapes on the line: first = result (possibly tuple), rest operands
        shapes = _SHAPE_RE.findall(line)
        if not shapes:
            continue
        args = line[m.end():]
        operand_shapes = _SHAPE_RE.findall(args)
        use = operand_shapes if operand_shapes else shapes[1:] or shapes
        bytes_by_kind[kind] += sum(_shape_bytes(d, s) for d, s in use)
    return {"bytes": bytes_by_kind, "counts": count_by_kind,
            "total_bytes": sum(bytes_by_kind.values())}


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per device
    bytes_hbm: float             # per device
    bytes_collective: float      # per device
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_global: float    # 6·N·D (train) or 2·N·D (serve)
    useful_ratio: float          # model_flops_per_dev / hlo_flops

    def to_dict(self):
        return dataclasses.asdict(self)


def derive_terms(flops: float, bytes_hbm: float, bytes_coll: float,
                 model_flops_global: float, n_chips: int) -> RooflineTerms:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_hbm / HBM_BW
    t_x = bytes_coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = (model_flops_global / n_chips) / max(flops, 1.0)
    return RooflineTerms(flops=flops, bytes_hbm=bytes_hbm,
                         bytes_collective=bytes_coll,
                         t_compute=t_c, t_memory=t_m, t_collective=t_x,
                         bottleneck=bottleneck,
                         model_flops_global=model_flops_global,
                         useful_ratio=useful)


def model_flops(cfg, shape, n_active_params: int) -> float:
    """6·N·D for training, 2·N·D per forward token for serving."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active_params * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active_params * tokens
    # decode: one token per sequence
    return 2.0 * n_active_params * shape.global_batch


def slstm_flops_correction(cfg, shape, n_slstm_layers: int) -> float:
    """sLSTM's per-token scan body is counted once by cost analysis; add
    the remaining (S-1) steps analytically: 4 recurrent PxP matmuls/head."""
    if n_slstm_layers == 0 or shape.kind == "decode":
        return 0.0
    B = shape.global_batch
    S = shape.seq_len
    H = cfg.n_heads
    P = cfg.d_model // H
    per_step = 4 * 2 * B * H * P * P + 40 * B * H * P
    return float(n_slstm_layers * (S - 1) * per_step)
