"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --batch 4 --prompt-len 32 --gen 16

Demonstrates the full request path the decode_* dry-run cells lower:
prefill builds the KV/recurrent cache, then the jitted serve step extends
one token per call with greedy sampling.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import make_batch
from repro.models import model as M
from repro.models.common import materialize


def serve(args):
    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    params = materialize(M.model_def(cfg), jax.random.PRNGKey(args.seed),
                         jnp.float32 if cfg.dtype == "float32"
                         else jnp.bfloat16)
    B, P, G = args.batch, args.prompt_len, args.gen
    s_max = P + G
    batch = make_batch(cfg, B, P, args.seed, 0)
    batch.pop("labels")

    prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b, s_max=s_max))
    decode = jax.jit(
        lambda p, t, c, i: M.decode_step(cfg, p, t, c, i),
        static_argnums=())

    t0 = time.time()
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    print(f"prefill: {time.time()-t0:.2f}s")

    out_tokens = [next_tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = decode(params, next_tok, cache, P + i)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(next_tok)
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    dt = time.time() - t0
    print(f"decode: {G-1} steps in {dt:.2f}s "
          f"({1000*dt/max(1,G-1):.1f} ms/token, batch {B})")
    print("generated (first row):", gen[0].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
