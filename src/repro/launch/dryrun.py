"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
      --shape train_4k [--multi-pod] [--no-calibrate] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this produces:
  * proof of compile on the production mesh (16x16, and 2x16x16 multi-pod);
  * memory_analysis (bytes/device — proves it fits);
  * cost_analysis + trip-count calibration -> per-device HLO FLOPs/bytes;
  * collective census (op counts + operand bytes from optimized HLO);
  * the three roofline terms (launch/roofline.py).
Results land in experiments/dryrun/<arch>_<shape>_<mesh>.json.
"""
# The VERY FIRST lines — before ANY other import, jax locks the device
# count on first init:
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCHS, get_config, get_shape, shapes_for,
                           ALL_SHAPES)  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch import specs as S      # noqa: E402
from repro.launch.mesh import describe, make_production_mesh  # noqa: E402
from repro.models import model as M      # noqa: E402
from repro.models import sharding as shd  # noqa: E402
from repro.models.common import (abstract, bytes_per_device,  # noqa: E402
                                 shardings, shardings_inference)
from repro.optim import get_optimizer    # noqa: E402
from repro.train.steps import (make_decode_step, make_prefill_step,  # noqa
                               make_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _params_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def build_lowering(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Lower the cell's step function against ShapeDtypeStructs."""
    defs = M.model_def(cfg)
    p_abs = abstract(defs, _params_dtype(cfg))
    if shape.kind == "train":
        p_shd = shardings(defs, mesh)
    else:
        # inference: drop FSDP unless TP-only sharding cannot fit (12 GiB
        # param budget per v5e chip) — kills per-step param all-gathers
        keep_fsdp = bytes_per_device(defs, mesh, keep_fsdp=False) \
            > 12 * 2**30
        p_shd = shardings_inference(defs, mesh, keep_fsdp=keep_fsdp)

    with shd.use_mesh(mesh):
        if shape.kind == "train":
            opt = get_optimizer(cfg.optimizer, lr=1e-4)
            sdefs = opt.state_defs(defs)
            o_abs = abstract(sdefs)
            o_shd = shardings(sdefs, mesh)
            bspec = S.train_batch_specs(cfg, shape.global_batch,
                                        shape.seq_len)
            b_shd = S.batch_shardings(cfg, mesh, bspec)
            step = make_train_step(cfg, opt)
            jitted = jax.jit(step, in_shardings=(p_shd, o_shd, b_shd),
                             out_shardings=(p_shd, o_shd, None),
                             donate_argnums=(0, 1))
            return jitted.lower(p_abs, o_abs, bspec)

        if shape.kind == "prefill":
            bspec = S.train_batch_specs(cfg, shape.global_batch,
                                        shape.seq_len)
            bspec.pop("labels")
            b_shd = S.batch_shardings(cfg, mesh, bspec)
            step = make_prefill_step(cfg, s_max=shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_shd, b_shd))
            return jitted.lower(p_abs, bspec)

        # decode: one new token against a seq_len cache
        tokens, cache_abs, extras = S.decode_input_specs(cfg, shape)
        c_shd = S.cache_shardings(cfg, mesh, cache_abs, shape.global_batch)
        t_shd = S.batch_shardings(cfg, mesh, {"tokens": tokens})["tokens"]
        step = make_decode_step(cfg)
        index = shape.seq_len - 1
        if cfg.family == "vlm":
            pos3 = extras["positions3"]
            jitted = jax.jit(
                lambda p, t, c, q: step(p, t, c, index, positions3=q),
                in_shardings=(p_shd, t_shd, c_shd, None),
                donate_argnums=(2,))
            return jitted.lower(p_abs, tokens, cache_abs, pos3)
        jitted = jax.jit(lambda p, t, c: step(p, t, c, index),
                         in_shardings=(p_shd, t_shd, c_shd),
                         donate_argnums=(2,))
        return jitted.lower(p_abs, tokens, cache_abs)


def _cost_dict(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _calibration_cfg(cfg: ModelConfig, repeats: int) -> ModelConfig:
    plen = len(cfg.block_pattern)
    over = dict(n_layers=plen * repeats, scan_unroll=True, grad_accum=1)
    if cfg.is_encdec:
        over["encoder_layers"] = repeats
    return dataclasses.replace(cfg, **over)


def calibrate_costs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """Extrapolate per-device FLOPs/bytes/collective-bytes to full depth:
    total = c1 + (R-1)·(c2-c1), with unrolled 1- and 2-repeat variants."""
    out = {}
    for r in (1, 2):
        ccfg = _calibration_cfg(cfg, r)
        lowered = build_lowering(ccfg, shape, mesh)
        compiled = lowered.compile()
        cd = _cost_dict(compiled)
        cs = RL.collective_stats(compiled.as_text())
        out[r] = {"flops": cd["flops"], "bytes": cd["bytes"],
                  "coll": float(cs["total_bytes"]),
                  "coll_counts": cs["counts"]}
    R = cfg.n_repeats
    extr = {}
    for key in ("flops", "bytes", "coll"):
        c1, c2 = out[1][key], out[2][key]
        extr[key] = c1 + (R - 1) * (c2 - c1)
    extr["per_repeat"] = {k: out[2][k] - out[1][k]
                          for k in ("flops", "bytes", "coll")}
    extr["calib_counts"] = out[2]["coll_counts"]
    # grad-accum: calibration ran accum=1 at full global batch == same
    # total tokens, so no further scaling is needed.
    n_slstm = sum(1 for b in cfg.layer_types() if b == "slstm")
    extr["flops"] += RL.slstm_flops_correction(cfg, shape, n_slstm) / \
        _mesh_chips(mesh)
    return extr


def _mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def _parse_overrides(pairs: list[str] | None) -> dict:
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             calibrate: bool = True, out_dir: str = OUT_DIR,
             overrides: dict | None = None, tag: str = "") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = _mesh_chips(mesh)
    mesh_name = ("multipod" if multi_pod else "pod") + (f"_{tag}" if tag else "")
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": describe(mesh),
                 "chips": n_chips, "status": "ok",
                 "overrides": overrides or {}}

    if shape_name not in [s.name for s in shapes_for(cfg)]:
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch skips long_500k"
                         if shape_name == "long_500k" else "n/a")
        _write(rec, arch, shape_name, mesh_name, out_dir)
        return rec

    t0 = time.time()
    lowered = build_lowering(cfg, shape, mesh)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_est_bytes": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
    }
    rec["cost_raw"] = _cost_dict(compiled)
    cs = RL.collective_stats(compiled.as_text())
    rec["collectives_raw"] = cs

    if calibrate:
        extr = calibrate_costs(cfg, shape, mesh)
        rec["cost_calibrated"] = {k: extr[k]
                                  for k in ("flops", "bytes", "coll")}
        rec["per_repeat"] = extr["per_repeat"]
        n_active = M.count_active_params(cfg)
        mf = RL.model_flops(cfg, shape, n_active)
        terms = RL.derive_terms(extr["flops"], extr["bytes"], extr["coll"],
                                mf, n_chips)
        rec["n_active_params"] = n_active
        rec["n_params"] = M.count_params(cfg)
        rec["roofline"] = terms.to_dict()
    _write(rec, arch, shape_name, mesh_name, out_dir)
    return rec


def _write(rec, arch, shape_name, mesh_name, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="config overrides for perf experiments, e.g. "
                         "--override remat_policy=dots --override "
                         "grad_accum=4")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json filename")
    args = ap.parse_args()
    overrides = _parse_overrides(args.override)

    cells = []
    if args.all:
        for a in ARCHS:
            for s in ALL_SHAPES:
                cells.append((a, s.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape required without --all")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
            try:
                t0 = time.time()
                rec = run_cell(arch, shape_name, mp,
                               calibrate=not args.no_calibrate,
                               out_dir=args.out, overrides=overrides,
                               tag=args.tag)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    peak = rec["memory"]["peak_est_bytes"] / 2**30
                    extra = (f" compile={rec['compile_s']}s "
                             f"peak/dev={peak:.2f}GiB")
                    if "roofline" in rec:
                        extra += (" bottleneck="
                                  f"{rec['roofline']['bottleneck']}")
                print(f"[{time.time()-t0:7.1f}s] {tag}: {status}{extra}",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[ FAIL ] {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
