"""Multi-tenant decomposition serving: COO submissions → bucketed CPD.

  PYTHONPATH=src python -m repro.launch.serve_cpd --tenants 12 --rank 4

The request path the ROADMAP's production workload needs — thousands of
tenant tensors decomposed concurrently without thousands of compiles:

  submit(COO)                   thread-safe admission, classified into a
    │                           shape class (`core.shapeclass.classify`)
    ▼
  per-class queue               tenants accumulate until a bucket fills
    │                           (or `process()` flushes a partial bucket,
    ▼                           padded with inactive slots)
  pad → ingest → views          `shapeclass.pad_to_class` then the PR 5
    │                           device ingest (`alto.build_device`,
    │                           compute_reuse off — the canonical meta
    ▼                           overrides reuse anyway) and the unified
  batched sweep                 view cache (`core.views` via
    │                           `plan.build_views`); one vmapped
    ▼                           executable per class (`core.batched`)
  per-tenant result             factors sliced back to real dims, fit /
                                KKT trajectory, wall-clock latency

Zero-warmup dispatch: the class plan comes from `plan.make_class_plan`
with ``tune="auto"`` — the autotuner's persistent store is keyed on the
canonical class meta (`autotune.class_plan_key`), so a class ever tuned
by ANY process on this machine dispatches measurement-free, and the
first bucket of a class warms every later bucket, tenant, and restart.

Degenerate tenants (empty or single-nonzero COO) are first-class: they
admit, bucket, and return well-defined results (an empty tensor yields
zero factors and fit 1.0) instead of raising mid-queue.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time
from typing import Sequence

import numpy as np

from repro.core import alto, batched, shapeclass
from repro.core import cpals as cpals_mod
from repro.core import cpapr as cpapr_mod
from repro.core import ingest as ingest_mod
from repro.core import plan as plan_mod
from repro.sparse.tensor import SparseTensor


@dataclasses.dataclass
class CpdRequest:
    """One tenant's admitted submission."""
    request_id: int
    x: SparseTensor
    sc: shapeclass.ShapeClass
    seed: int
    submitted_at: float


@dataclasses.dataclass
class DeltaRequest:
    """An incremental update against a previously served result."""
    request_id: int
    base_id: int                   # request id of the retained base result
    coords: np.ndarray
    values: np.ndarray
    policy: str
    submitted_at: float


@dataclasses.dataclass
class CpdResponse:
    request_id: int
    sc: shapeclass.ShapeClass
    result: object                 # CpalsResult | CpaprResult (real dims)
    latency_s: float               # submit → result wall clock
    bucket_size: int               # real tenants in the bucket served with


class CpdService:
    """Request-queue front end over the shape-class batched layer.

    ``submit`` is thread-safe and cheap (classify + enqueue); the heavy
    path is ``process()``, which drains every class queue bucket-by-
    bucket. ``capacity`` fixes each bucket's stacked width — partial
    buckets are padded with inactive slots so a class compiles exactly
    once no matter how its tenants arrive (`core.batched` docstring).
    """

    def __init__(self, rank: int, algorithm: str = "cp_als", *,
                 capacity: int = 8, n_partitions: int | None = None,
                 n_iters: int = 25, tol: float = 1e-4,
                 tune: str = "auto", backend: str | None = None,
                 retain_results: int = 128):
        if algorithm not in ("cp_als", "cp_apr"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        self.rank = int(rank)
        self.algorithm = algorithm
        self.capacity = int(capacity)
        self.n_partitions = (shapeclass.DEFAULT_PARTITIONS
                             if n_partitions is None else int(n_partitions))
        self.n_iters = int(n_iters)
        self.tol = float(tol)
        self.tune = tune
        self.backend = backend
        self._lock = threading.Lock()
        self._queues: dict[shapeclass.ShapeClass, collections.deque] = {}
        self._plans: dict[shapeclass.ShapeClass,
                          plan_mod.ExecutionPlan] = {}
        self._next_id = 0
        self._latencies: list[float] = []
        self._tenants_done = 0
        self._buckets_run = 0
        self._busy_s = 0.0
        # rid -> (x | None, AltoTensor | None, result, sc): every served
        # result is retained (LRU-bounded) so `submit_delta` can append
        # against it and warm-start from its factors. The AltoTensor slot
        # starts None (the bucketed path pads to the class shape, which
        # the delta path does NOT want) and is filled lazily on the first
        # delta; delta responses retain their merged tensor directly, so
        # delta CHAINS run the jitted merge with no rebuild anywhere.
        self.retain_results = int(retain_results)
        self._retained: "collections.OrderedDict[int, tuple]" = \
            collections.OrderedDict()
        self._delta_queue: collections.deque = collections.deque()
        self._deltas_done = 0

    # -- admission --------------------------------------------------------

    def submit(self, x: SparseTensor, seed: int = 0) -> int:
        """Admit one COO submission; returns its request id.

        Classification is pure metadata (dims/nnz rounding) — no device
        work happens under the lock, so admission never blocks on a
        bucket in flight.
        """
        sc = shapeclass.classify(x, self.rank,
                                 n_partitions=self.n_partitions)
        req = CpdRequest(request_id=-1, x=x, sc=sc, seed=int(seed),
                         submitted_at=time.perf_counter())
        with self._lock:
            req.request_id = self._next_id
            self._next_id += 1
            self._queues.setdefault(sc, collections.deque()).append(req)
        return req.request_id

    def submit_delta(self, base_id: int, coords, values,
                     policy: str = "sum") -> int:
        """Admit a COO delta against a previously served result; returns
        the new request id. The base must still be retained (see
        ``retain_results``). Deltas skip class bucketing entirely: they
        are latency-sensitive singletons whose jit cache is already warm
        (the merge core keys on the static merge meta, the sweep on the
        tensor meta), so `process()` serves them solo with
        ``warm_start=`` from the base's factors.
        """
        if policy not in ingest_mod.POLICIES:
            raise ValueError(f"policy {policy!r}: expected one of "
                             f"{ingest_mod.POLICIES}")
        coords = np.asarray(coords, dtype=np.int32)
        values = np.asarray(values)
        req = DeltaRequest(request_id=-1, base_id=int(base_id),
                           coords=coords, values=values, policy=policy,
                           submitted_at=time.perf_counter())
        with self._lock:
            if int(base_id) not in self._retained:
                raise KeyError(f"request {base_id} is not retained "
                               f"(never served, or aged out of the "
                               f"{self.retain_results}-entry LRU)")
            req.request_id = self._next_id
            self._next_id += 1
            self._delta_queue.append(req)
        return req.request_id

    def pending(self) -> int:
        with self._lock:
            return (sum(len(q) for q in self._queues.values())
                    + len(self._delta_queue))

    def shape_classes(self) -> list[shapeclass.ShapeClass]:
        with self._lock:
            return list(self._queues)

    # -- class plan (store-backed, shared by every bucket of the class) ---

    def _class_plan(self, sc, at_canonical=None):
        with self._lock:
            plan = self._plans.get(sc)
        if plan is not None:
            return plan
        plan = plan_mod.make_class_plan(
            sc, backend=self.backend, tune=self.tune,
            tune_objective=("phi" if self.algorithm == "cp_apr"
                            else "mttkrp"),
            at=at_canonical)
        with self._lock:
            return self._plans.setdefault(sc, plan)

    # -- the heavy path ---------------------------------------------------

    def _prepare(self, req: CpdRequest, plan):
        """pad → device ingest → canonical meta → cached views."""
        xp = shapeclass.pad_to_class(req.x, req.sc)
        # Reuse stats are data-dependent (they would fork the meta per
        # tenant) and the canonical meta pins reuse to 1.0 regardless —
        # skip the fiber count entirely.
        at = alto.build_device(xp, n_partitions=req.sc.n_partitions,
                               compute_reuse=False)
        at = shapeclass.canonicalize_tensor(at, req.sc)
        views = plan_mod.build_views(at, plan)
        return at, views

    def _run_bucket(self, sc, reqs: Sequence[CpdRequest]) -> list[CpdResponse]:
        t0 = time.perf_counter()
        # The first bucket of a never-seen class may tune (store miss
        # with tune="auto"); give the tuner a canonical representative.
        at0, views0 = None, None
        plan = self._plans.get(sc)
        if plan is None:
            xp0 = shapeclass.pad_to_class(reqs[0].x, sc)
            at0 = shapeclass.canonicalize_tensor(
                alto.build_device(xp0, n_partitions=sc.n_partitions,
                                  compute_reuse=False), sc)
            plan = self._class_plan(sc, at_canonical=at0)
            views0 = plan_mod.build_views(at0, plan)
        ats, views, rdims, seeds = [], [], [], []
        for j, req in enumerate(reqs):
            if j == 0 and at0 is not None:
                at, vs = at0, views0
            else:
                at, vs = self._prepare(req, plan)
            ats.append(at)
            views.append(vs)
            rdims.append(req.x.dims)
            seeds.append(req.seed)
        if self.algorithm == "cp_als":
            out = batched.batched_cp_als(
                ats, views, rdims, self.rank, plan=plan,
                n_iters=self.n_iters, tol=self.tol, seeds=seeds,
                capacity=self.capacity)
        else:
            out = batched.batched_cp_apr(
                ats, views, rdims, self.rank, plan=plan,
                params=cpapr_mod.CpaprParams(k_max=self.n_iters,
                                             tau=self.tol),
                seeds=seeds, capacity=self.capacity)
        done = time.perf_counter()
        responses = []
        for req, result in zip(reqs, out.results):
            lat = done - req.submitted_at
            responses.append(CpdResponse(
                request_id=req.request_id, sc=sc, result=result,
                latency_s=lat, bucket_size=len(reqs)))
        with self._lock:
            self._latencies.extend(r.latency_s for r in responses)
            self._tenants_done += len(responses)
            self._buckets_run += 1
            self._busy_s += done - t0
            for req, result in zip(reqs, out.results):
                self._retain_locked(req.request_id,
                                    (req.x, None, result, sc))
        return responses

    def _retain_locked(self, rid: int, entry: tuple) -> None:
        self._retained[rid] = entry
        while len(self._retained) > max(1, self.retain_results):
            self._retained.popitem(last=False)

    def _run_delta(self, req: DeltaRequest) -> CpdResponse:
        t0 = time.perf_counter()
        with self._lock:
            x, at, result, sc = self._retained[req.base_id]
        if at is None:
            # First delta against a bucket-served base: materialize the
            # REAL-dims tensor once (the bucketed solve ran on the
            # class-padded shape, which deltas must not inherit).
            at = alto.build_device(x, n_partitions=self.n_partitions,
                                   compute_reuse=False)
            with self._lock:
                if req.base_id in self._retained:
                    self._retained[req.base_id] = (x, at, result, sc)
        new_at = ingest_mod.append_delta(at, req.coords, req.values,
                                         policy=req.policy)
        if self.algorithm == "cp_als":
            res = cpals_mod.cp_als(new_at, self.rank, n_iters=self.n_iters,
                                   tol=self.tol, warm_start=result)
        else:
            res = cpapr_mod.cp_apr(
                new_at, self.rank,
                params=cpapr_mod.CpaprParams(k_max=self.n_iters,
                                             tau=self.tol),
                warm_start=result)
        done = time.perf_counter()
        resp = CpdResponse(request_id=req.request_id, sc=sc, result=res,
                           latency_s=done - req.submitted_at,
                           bucket_size=1)
        with self._lock:
            self._latencies.append(resp.latency_s)
            self._deltas_done += 1
            self._busy_s += done - t0
            self._retain_locked(req.request_id, (None, new_at, res, sc))
        return resp

    def process(self, flush: bool = True) -> list[CpdResponse]:
        """Drain the queues: deltas first (latency-sensitive, already
        warm — solo solves seeded from the retained base), then full
        buckets always, partial ones if ``flush`` (padded with inactive
        slots — same executable)."""
        responses: list[CpdResponse] = []
        while True:
            with self._lock:
                dreq = (self._delta_queue.popleft()
                        if self._delta_queue else None)
            if dreq is None:
                break
            responses.append(self._run_delta(dreq))
        while True:
            with self._lock:
                batch_ = None
                for sc, q in self._queues.items():
                    if len(q) >= self.capacity or (flush and q):
                        n = min(len(q), self.capacity)
                        batch_ = (sc, [q.popleft() for _ in range(n)])
                        break
                empties = [sc for sc, q in self._queues.items() if not q]
                for sc in empties:
                    del self._queues[sc]
            if batch_ is None:
                return responses
            responses.extend(self._run_bucket(*batch_))

    # -- observability ----------------------------------------------------

    def stats(self) -> dict:
        """Serving counters + the trace counters the tests pin."""
        with self._lock:
            lats = sorted(self._latencies)
            n = len(lats)
            done, buckets, busy = (self._tenants_done, self._buckets_run,
                                   self._busy_s)
            classes = len(self._plans)
            deltas = self._deltas_done

        def pct(p):
            return lats[min(n - 1, int(p * n))] if n else 0.0

        return {
            "tenants_done": done,
            "deltas_done": deltas,
            "buckets_run": buckets,
            "shape_classes": classes,
            "tenants_per_s": (done / busy) if busy > 0 else 0.0,
            "latency_p50_s": pct(0.50),
            "latency_p99_s": pct(0.99),
            "ingest_traces": alto.device_ingest_traces(),
            "sweep_traces": batched.sweep_traces(),
        }


# ---------------------------------------------------------------------------
# CLI demo: synthetic tenants with deliberately scattered shapes
# ---------------------------------------------------------------------------

def main(argv=None):
    from repro.sparse.synthetic import uniform_tensor

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--algorithm", default="cp_als",
                    choices=["cp_als", "cp_apr"])
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    svc = CpdService(args.rank, args.algorithm, capacity=args.capacity,
                     n_iters=args.iters)
    rng = np.random.default_rng(args.seed)
    shapes = [(9, 7, 5), (12, 6, 8), (16, 8, 8), (30, 20, 10)]
    for t in range(args.tenants):
        dims = shapes[t % len(shapes)]
        nnz = int(rng.integers(60, 128))
        x = uniform_tensor(dims, nnz, seed=args.seed + t,
                           count_data=(args.algorithm == "cp_apr"))
        svc.submit(x, seed=t)
    print(f"admitted {svc.pending()} tenants across "
          f"{len(svc.shape_classes())} shape classes")
    t0 = time.perf_counter()
    responses = svc.process()
    dt = time.perf_counter() - t0
    s = svc.stats()
    print(f"served {len(responses)} tenants in {dt:.2f}s "
          f"({s['tenants_per_s']:.1f} tenants/s busy-rate), "
          f"{s['buckets_run']} buckets, {s['shape_classes']} classes")
    print(f"latency p50 {s['latency_p50_s']*1e3:.0f} ms, "
          f"p99 {s['latency_p99_s']*1e3:.0f} ms")
    print(f"jit traces: ingest {s['ingest_traces']}, "
          f"sweeps {s['sweep_traces']}")
    return responses


if __name__ == "__main__":
    main()
