"""Multi-tenant decomposition serving: COO submissions → bucketed CPD.

  PYTHONPATH=src python -m repro.launch.serve_cpd --tenants 12 --rank 4

The request path the ROADMAP's production workload needs — thousands of
tenant tensors decomposed concurrently without thousands of compiles:

  submit(COO)                   thread-safe admission, classified into a
    │                           shape class (`core.shapeclass.classify`)
    ▼
  per-class queue               tenants accumulate until a bucket fills
    │                           (or `process()` flushes a partial bucket,
    ▼                           padded with inactive slots)
  pad → ingest → views          `shapeclass.pad_to_class` then the PR 5
    │                           device ingest (`alto.build_device`,
    │                           compute_reuse off — the canonical meta
    ▼                           overrides reuse anyway) and the unified
  batched sweep                 view cache (`core.views` via
    │                           `plan.build_views`); one vmapped
    ▼                           executable per class (`core.batched`)
  per-tenant result             factors sliced back to real dims, fit /
                                KKT trajectory, wall-clock latency

Zero-warmup dispatch: the class plan comes from `plan.make_class_plan`
with ``tune="auto"`` — the autotuner's persistent store is keyed on the
canonical class meta (`autotune.class_plan_key`), so a class ever tuned
by ANY process on this machine dispatches measurement-free, and the
first bucket of a class warms every later bucket, tenant, and restart.

Degenerate tenants (empty or single-nonzero COO) are first-class: they
admit, bucket, and return well-defined results (an empty tensor yields
zero factors and fit 1.0) instead of raising mid-queue.

Resilience (PR 9, `docs/resilience.md`): the service is a *runtime*,
not just a queue. A background worker loop (:meth:`CpdService.serve` /
:meth:`CpdService.shutdown`) drains the queues continuously and
survives any request's failure; every failure mode maps to a structured
:class:`CpdResponse` — never a crash, never a poisoned bucket-mate:

* transient faults (I/O blips, allocator RESOURCE_EXHAUSTED —
  `faults.is_transient`) are retried with exponential backoff;
* plan failures walk the degradation ladder (`health.degrade_plan`):
  streaming OOM halves ``chunk_m``, a Pallas kernel failure drops to
  the reference backend, and a stored plan that fails at dispatch is
  evicted from the autotune store and replaced by the heuristic plan;
* a bucket that still fails is *bisected*: each member re-runs solo,
  and an offender that fails alone too is quarantined with a
  structured error while its bucket-mates' results are unaffected;
* ``guard=True`` (default) runs the per-sweep health guards
  (`core.health`) — a tenant whose iterates go non-finite is rolled
  back to its last good state and marked quarantined in-place;
* per-request deadlines (``deadline_s``) and a deadline-aware partial-
  bucket flush (``max_wait_s``) bound tail latency.
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import alto, batched, faults, shapeclass
from repro.core import autotune as autotune_mod
from repro.core import cpals as cpals_mod
from repro.core import cpapr as cpapr_mod
from repro.core import health as health_mod
from repro.core import ingest as ingest_mod
from repro.core import plan as plan_mod
from repro.core import stream as stream_mod
from repro.sparse.tensor import SparseTensor


@dataclasses.dataclass
class CpdRequest:
    """One tenant's admitted submission."""
    request_id: int
    x: SparseTensor
    sc: shapeclass.ShapeClass
    seed: int
    submitted_at: float
    deadline_s: float | None = None


@dataclasses.dataclass
class DeltaRequest:
    """An incremental update against a previously served result."""
    request_id: int
    base_id: int                   # request id of the retained base result
    coords: np.ndarray
    values: np.ndarray
    policy: str
    submitted_at: float
    deadline_s: float | None = None


@dataclasses.dataclass
class CpdResponse:
    request_id: int
    sc: shapeclass.ShapeClass
    result: object                 # CpalsResult | CpaprResult | None
    latency_s: float               # submit → result wall clock
    bucket_size: int               # real tenants in the bucket served with
    # Resilience outcome. ``error`` is None on success; a quarantined or
    # deadline-expired request gets the reason here (its ``result`` may
    # still carry the last good, rolled-back iterate — degraded but
    # finite — or be None when nothing was computed). ``degraded`` marks
    # results served through a ladder rung (reference backend, halved
    # chunks, evicted store plan); ``retries`` counts transient-fault
    # re-attempts absorbed on this request's behalf.
    error: str | None = None
    degraded: bool = False
    retries: int = 0

    @property
    def ok(self) -> bool:
        return self.error is None


class CpdService:
    """Request-queue front end over the shape-class batched layer.

    ``submit`` is thread-safe and cheap (classify + enqueue); the heavy
    path is ``process()``, which drains every class queue bucket-by-
    bucket. ``capacity`` fixes each bucket's stacked width — partial
    buckets are padded with inactive slots so a class compiles exactly
    once no matter how its tenants arrive (`core.batched` docstring).

    Run it caller-driven (call ``process()`` yourself) or as a runtime:
    ``serve()`` starts a daemon worker that drains continuously, and
    ``wait(request_id)`` blocks until that request's response lands.
    """

    def __init__(self, rank: int, algorithm: str = "cp_als", *,
                 capacity: int = 8, n_partitions: int | None = None,
                 n_iters: int = 25, tol: float = 1e-4,
                 tune: str = "auto", backend: str | None = None,
                 retain_results: int = 128, guard: bool = True,
                 max_wait_s: float | None = None, max_retries: int = 2,
                 retry_base_s: float = 0.02,
                 search_budget: int | None = None,
                 search_budgets: dict | None = None):
        if algorithm not in ("cp_als", "cp_apr"):
            raise ValueError(f"unknown algorithm {algorithm!r}")
        self.rank = int(rank)
        self.algorithm = algorithm
        self.capacity = int(capacity)
        self.n_partitions = (shapeclass.DEFAULT_PARTITIONS
                             if n_partitions is None else int(n_partitions))
        self.n_iters = int(n_iters)
        self.tol = float(tol)
        self.tune = tune
        self.backend = backend
        # Budgeted-search warm start (tune="search"): a class-keyed run
        # budget per ShapeClass, falling back to the flat default. High
        # -traffic classes deserve more measurements than one-off shapes;
        # None everywhere = the search engine's own default (25% of the
        # feasible space). Ignored under the other tune modes.
        self.search_budget = (None if search_budget is None
                              else int(search_budget))
        self.search_budgets = dict(search_budgets or {})
        self.guard = bool(guard)
        # Deadline-aware flush: a partial bucket whose oldest request
        # has waited this long is flushed without waiting for capacity.
        self.max_wait_s = None if max_wait_s is None else float(max_wait_s)
        self.max_retries = int(max_retries)
        self.retry_base_s = float(retry_base_s)
        self._lock = threading.Lock()
        self._queues: dict[shapeclass.ShapeClass, collections.deque] = {}
        self._plans: dict[shapeclass.ShapeClass,
                          plan_mod.ExecutionPlan] = {}
        self._next_id = 0
        self._latencies: list[float] = []
        self._tenants_done = 0
        self._buckets_run = 0
        self._busy_s = 0.0
        # rid -> (x | None, AltoTensor | None, result, sc): every served
        # result is retained (LRU-bounded) so `submit_delta` can append
        # against it and warm-start from its factors. The AltoTensor slot
        # starts None (the bucketed path pads to the class shape, which
        # the delta path does NOT want) and is filled lazily on the first
        # delta; delta responses retain their merged tensor directly, so
        # delta CHAINS run the jitted merge with no rebuild anywhere.
        self.retain_results = int(retain_results)
        self._retained: "collections.OrderedDict[int, tuple]" = \
            collections.OrderedDict()
        self._delta_queue: collections.deque = collections.deque()
        self._deltas_done = 0
        # Resilience counters (all under self._lock; see stats()).
        self._retries = 0
        self._backoff_s = 0.0
        self._quarantined_tenants = 0
        self._degraded_dispatches = 0
        self._plan_evictions = 0
        self._deadline_expired = 0
        self._errors = 0
        # Completed responses for wait(): bounded mailbox, popped on
        # delivery; notified under the service lock.
        self._responses: "collections.OrderedDict[int, CpdResponse]" = \
            collections.OrderedDict()
        self._resp_cond = threading.Condition(self._lock)
        # Worker-loop state.
        self._worker: threading.Thread | None = None
        self._stop_evt = threading.Event()
        self._worker_recoveries = 0

    # -- admission --------------------------------------------------------

    def submit(self, x: SparseTensor, seed: int = 0, *,
               deadline_s: float | None = None) -> int:
        """Admit one COO submission; returns its request id.

        Classification is pure metadata (dims/nnz rounding) — no device
        work happens under the lock, so admission never blocks on a
        bucket in flight. ``deadline_s`` bounds submit→serve wall clock:
        a request still queued past its deadline is answered with a
        structured error instead of being served late.
        """
        sc = shapeclass.classify(x, self.rank,
                                 n_partitions=self.n_partitions)
        req = CpdRequest(request_id=-1, x=x, sc=sc, seed=int(seed),
                         submitted_at=time.perf_counter(),
                         deadline_s=deadline_s)
        with self._lock:
            req.request_id = self._next_id
            self._next_id += 1
            self._queues.setdefault(sc, collections.deque()).append(req)
        return req.request_id

    def submit_delta(self, base_id: int, coords, values,
                     policy: str = "sum", *,
                     deadline_s: float | None = None) -> int:
        """Admit a COO delta against a previously served result; returns
        the new request id. The base must still be retained (see
        ``retain_results``). Deltas skip class bucketing entirely: they
        are latency-sensitive singletons whose jit cache is already warm
        (the merge core keys on the static merge meta, the sweep on the
        tensor meta), so `process()` serves them solo with
        ``warm_start=`` from the base's factors.
        """
        if policy not in ingest_mod.POLICIES:
            raise ValueError(f"policy {policy!r}: expected one of "
                             f"{ingest_mod.POLICIES}")
        coords = np.asarray(coords, dtype=np.int32)
        values = np.asarray(values)
        req = DeltaRequest(request_id=-1, base_id=int(base_id),
                           coords=coords, values=values, policy=policy,
                           submitted_at=time.perf_counter(),
                           deadline_s=deadline_s)
        with self._lock:
            if int(base_id) not in self._retained:
                raise KeyError(f"request {base_id} is not retained "
                               f"(never served, or aged out of the "
                               f"{self.retain_results}-entry LRU)")
            req.request_id = self._next_id
            self._next_id += 1
            self._delta_queue.append(req)
        return req.request_id

    def pending(self) -> int:
        with self._lock:
            return (sum(len(q) for q in self._queues.values())
                    + len(self._delta_queue))

    def shape_classes(self) -> list[shapeclass.ShapeClass]:
        with self._lock:
            return list(self._queues)

    # -- worker loop (the runtime half) -----------------------------------

    def serve(self, poll_s: float = 0.005) -> None:
        """Start the background worker: a daemon thread that drains the
        queues continuously (full buckets immediately, partial ones once
        ``max_wait_s`` is exceeded). Idempotent — a live worker is left
        alone. The loop is self-healing: an exception that escapes a
        request path is counted (``worker_recoveries``) and the loop
        keeps serving everyone else."""
        with self._lock:
            if self._worker is not None and self._worker.is_alive():
                return
            self._stop_evt = threading.Event()
            self._worker = threading.Thread(
                target=self._worker_loop, args=(float(poll_s),),
                name="cpd-serve-worker", daemon=True)
            self._worker.start()

    def _worker_loop(self, poll_s: float) -> None:
        stop = self._stop_evt
        while not stop.is_set():
            try:
                served = self.process(flush=False)
            except Exception:
                # Every request path converts failures into structured
                # responses, so anything landing here is a runtime bug —
                # survive it, count it, keep serving other tenants.
                with self._lock:
                    self._worker_recoveries += 1
                served = []
            if not served:
                stop.wait(poll_s)
        # Final drain: shutdown(wait=True) must leave no admitted
        # request unanswered, including partial buckets.
        try:
            self.process(flush=True)
        except Exception:
            with self._lock:
                self._worker_recoveries += 1

    def shutdown(self, wait: bool = True, timeout: float = 60.0) -> None:
        """Stop the worker. ``wait=True`` joins it — the worker drains
        everything still queued (flush) before exiting, so a clean
        shutdown never drops an admitted request."""
        with self._lock:
            worker = self._worker
        if worker is None:
            return
        self._stop_evt.set()
        if wait:
            worker.join(timeout)
        with self._lock:
            if self._worker is worker:
                self._worker = None

    @property
    def serving(self) -> bool:
        with self._lock:
            return self._worker is not None and self._worker.is_alive()

    def wait(self, request_id: int,
             timeout: float | None = None) -> CpdResponse:
        """Block until ``request_id``'s response lands (worker mode) and
        return it. Raises TimeoutError past ``timeout`` seconds."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._resp_cond:
            while request_id not in self._responses:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"request {request_id} not served "
                                       f"within {timeout}s")
                self._resp_cond.wait(remaining)
            return self._responses.pop(request_id)

    def _deliver(self, responses: Sequence[CpdResponse]) -> None:
        if not responses:
            return
        with self._resp_cond:
            for r in responses:
                self._responses[r.request_id] = r
            # Bound the mailbox: nobody waiting on very old responses.
            cap = max(64, 4 * self.retain_results)
            while len(self._responses) > cap:
                self._responses.popitem(last=False)
            self._resp_cond.notify_all()

    # -- class plan (store-backed, shared by every bucket of the class) ---

    def _class_plan(self, sc, at_canonical=None):
        with self._lock:
            plan = self._plans.get(sc)
        if plan is not None:
            return plan
        plan = plan_mod.make_class_plan(
            sc, backend=self.backend, tune=self.tune,
            tune_objective=self._objective(),
            at=at_canonical,
            search_budget=self.search_budgets.get(sc, self.search_budget))
        with self._lock:
            return self._plans.setdefault(sc, plan)

    def _objective(self) -> str:
        return "phi" if self.algorithm == "cp_apr" else "mttkrp"

    # -- the resilience ladder --------------------------------------------

    def _with_ladder(self, sc, run: Callable[[], object]):
        """Run ``run()`` under the recovery ladder; returns
        ``(out, retries, degraded)`` or raises when out of rungs.

        Rungs, in order, per failure: (1) transient fault
        (`faults.is_transient`) → retry with exponential backoff, up to
        ``max_retries``; (2) `health.degrade_plan` → swap the class plan
        (halved ``chunk_m`` on streaming OOM, reference backend on a
        Pallas failure) and re-run; (3) a stored plan failing at
        dispatch → evict it from the autotune store, rebuild the
        heuristic plan (``tune="off"``), re-run once. ``run`` must read
        the current class plan each attempt so rung swaps take effect.
        """
        retries = 0
        degraded = False
        evicted = False
        while True:
            try:
                return run(), retries, degraded
            except Exception as exc:  # noqa: BLE001 — ladder sorts them
                if faults.is_transient(exc) and retries < self.max_retries:
                    retries += 1
                    delay = self.retry_base_s * (2 ** (retries - 1))
                    with self._lock:
                        self._retries += 1
                        self._backoff_s += delay
                    time.sleep(delay)
                    continue
                with self._lock:
                    plan = self._plans.get(sc) if sc is not None else None
                if plan is not None:
                    new_plan, why = health_mod.degrade_plan(plan, exc)
                    if new_plan is not None:
                        with self._lock:
                            self._plans[sc] = new_plan
                            self._degraded_dispatches += 1
                        degraded = True
                        continue
                    if not evicted and self.tune != "off":
                        self._evict_class_plan(sc, plan)
                        evicted = True
                        degraded = True
                        continue
                raise

    def _evict_class_plan(self, sc, failed_plan) -> None:
        """Evict-and-retune rung: the stored (measured) plan failed at
        dispatch — drop its store entry so no later process trusts it,
        and fall back to the heuristic plan for this class."""
        key = autotune_mod.class_plan_key(sc, failed_plan.backend,
                                          objective=self._objective())
        autotune_mod.evict(key)
        fresh = plan_mod.make_class_plan(sc, backend=self.backend,
                                         tune="off")
        with self._lock:
            self._plans[sc] = fresh
            self._plan_evictions += 1

    def _error_response(self, req, sc, message: str,
                        result=None) -> CpdResponse:
        with self._lock:
            self._errors += 1
        return CpdResponse(request_id=req.request_id, sc=sc,
                           result=result,
                           latency_s=time.perf_counter() - req.submitted_at,
                           bucket_size=0, error=message)

    def _expired(self, req) -> bool:
        return (req.deadline_s is not None
                and time.perf_counter() - req.submitted_at > req.deadline_s)

    # -- the heavy path ---------------------------------------------------

    def _prepare(self, req: CpdRequest, plan):
        """pad → device ingest → canonical meta → cached views."""
        xp = shapeclass.pad_to_class(req.x, req.sc)
        # Reuse stats are data-dependent (they would fork the meta per
        # tenant) and the canonical meta pins reuse to 1.0 regardless —
        # skip the fiber count entirely.
        at = alto.build_device(xp, n_partitions=req.sc.n_partitions,
                               compute_reuse=False)
        at = shapeclass.canonicalize_tensor(at, req.sc)
        views = plan_mod.build_views(at, plan)
        return at, views

    def _run_bucket(self, sc, reqs: Sequence[CpdRequest]) -> list[CpdResponse]:
        t0 = time.perf_counter()
        # The first bucket of a never-seen class may tune (store miss
        # with tune="auto"); give the tuner a canonical representative.
        at0, views0 = None, None
        with self._lock:
            plan = self._plans.get(sc)
        if plan is None:
            xp0 = shapeclass.pad_to_class(reqs[0].x, sc)
            at0 = shapeclass.canonicalize_tensor(
                alto.build_device(xp0, n_partitions=sc.n_partitions,
                                  compute_reuse=False), sc)
            plan = self._class_plan(sc, at_canonical=at0)
            views0 = plan_mod.build_views(at0, plan)
        ats, views, rdims, seeds = [], [], [], []
        for j, req in enumerate(reqs):
            if j == 0 and at0 is not None:
                at, vs = at0, views0
            else:
                at, vs = self._prepare(req, plan)
            ats.append(at)
            views.append(vs)
            rdims.append(req.x.dims)
            seeds.append(req.seed)
        if self.algorithm == "cp_als":
            out = batched.batched_cp_als(
                ats, views, rdims, self.rank, plan=plan,
                n_iters=self.n_iters, tol=self.tol, seeds=seeds,
                capacity=self.capacity, guard=self.guard)
        else:
            out = batched.batched_cp_apr(
                ats, views, rdims, self.rank, plan=plan,
                params=cpapr_mod.CpaprParams(k_max=self.n_iters,
                                             tau=self.tol),
                seeds=seeds, capacity=self.capacity, guard=self.guard)
        done = time.perf_counter()
        quarantined = (out.quarantined if out.quarantined
                       else [False] * len(reqs))
        responses = []
        for req, result, quar in zip(reqs, out.results, quarantined):
            lat = done - req.submitted_at
            err = None
            if quar:
                # Guard quarantine: the slot went non-finite mid-solve
                # and was rolled back to its last good iterate — the
                # result is degraded but finite, and ONLY this tenant is
                # affected (vmap lanes are independent).
                err = ("quarantined: non-finite update detected; "
                       "result is the last good iterate")
            responses.append(CpdResponse(
                request_id=req.request_id, sc=sc, result=result,
                latency_s=lat, bucket_size=len(reqs), error=err,
                degraded=bool(quar)))
        with self._lock:
            self._latencies.extend(r.latency_s for r in responses)
            self._tenants_done += len(responses)
            self._buckets_run += 1
            self._busy_s += done - t0
            self._quarantined_tenants += sum(bool(q) for q in quarantined)
            self._errors += sum(bool(q) for q in quarantined)
            for req, result in zip(reqs, out.results):
                self._retain_locked(req.request_id,
                                    (req.x, None, result, sc))
        return responses

    def _serve_bucket(self, sc,
                      reqs: Sequence[CpdRequest]) -> list[CpdResponse]:
        """The resilient bucket path: deadline triage → ladder-wrapped
        bucket run → bisection to solo re-runs on bucket failure."""
        live, responses = [], []
        for req in reqs:
            if self._expired(req):
                with self._lock:
                    self._deadline_expired += 1
                responses.append(self._error_response(
                    req, sc, f"deadline expired: waited "
                             f"{time.perf_counter() - req.submitted_at:.3f}s "
                             f"of {req.deadline_s:.3f}s budget"))
            else:
                live.append(req)
        if not live:
            return responses
        try:
            served, retries, degraded = self._with_ladder(
                sc, lambda: self._run_bucket(sc, live))
            for r in served:
                r.retries += retries
                r.degraded = r.degraded or degraded
            responses.extend(served)
        except Exception as exc:  # noqa: BLE001 — bisect, don't crash
            # The whole bucket failed beyond the ladder. Bisect: each
            # member re-runs solo so one poisoned tenant cannot take
            # down its bucket-mates' answers.
            for req in live:
                responses.append(self._serve_solo(sc, req, cause=exc))
        return responses

    def _serve_solo(self, sc, req: CpdRequest,
                    cause: BaseException) -> CpdResponse:
        """Bisection rung: re-run one member of a failed bucket alone
        (through the ladder again — the failure may have been a bucket-
        mate's). A request that fails solo too is quarantined with a
        structured error carrying both failures."""
        try:
            served, retries, degraded = self._with_ladder(
                sc, lambda: self._run_bucket(sc, [req]))
        except Exception as solo_exc:  # noqa: BLE001 — quarantine
            with self._lock:
                self._quarantined_tenants += 1
            return self._error_response(
                req, sc, f"quarantined after repeated failures "
                         f"(bucket: {cause}; solo: {solo_exc})")
        resp = served[0]
        resp.retries += retries
        resp.degraded = resp.degraded or degraded
        return resp

    def _retain_locked(self, rid: int, entry: tuple) -> None:
        self._retained[rid] = entry
        while len(self._retained) > max(1, self.retain_results):
            self._retained.popitem(last=False)

    def _run_delta(self, req: DeltaRequest) -> CpdResponse:
        t0 = time.perf_counter()
        with self._lock:
            x, at, result, sc = self._retained[req.base_id]
        if at is None:
            # First delta against a bucket-served base: materialize the
            # REAL-dims tensor once (the bucketed solve ran on the
            # class-padded shape, which deltas must not inherit).
            at = alto.build_device(x, n_partitions=self.n_partitions,
                                   compute_reuse=False)
            with self._lock:
                if req.base_id in self._retained:
                    self._retained[req.base_id] = (x, at, result, sc)
        new_at = ingest_mod.append_delta(at, req.coords, req.values,
                                         policy=req.policy)
        if self.algorithm == "cp_als":
            res = cpals_mod.cp_als(new_at, self.rank, n_iters=self.n_iters,
                                   tol=self.tol, warm_start=result,
                                   guard=self.guard)
        else:
            res = cpapr_mod.cp_apr(
                new_at, self.rank,
                params=cpapr_mod.CpaprParams(k_max=self.n_iters,
                                             tau=self.tol),
                warm_start=result, guard=self.guard)
        done = time.perf_counter()
        resp = CpdResponse(request_id=req.request_id, sc=sc, result=res,
                           latency_s=done - req.submitted_at,
                           bucket_size=1)
        if res.health is not None and res.health.rolled_back:
            resp.error = f"quarantined: {res.health.reason}"
            resp.degraded = True
            with self._lock:
                self._quarantined_tenants += 1
                self._errors += 1
        with self._lock:
            self._latencies.append(resp.latency_s)
            self._deltas_done += 1
            self._busy_s += done - t0
            self._retain_locked(req.request_id, (None, new_at, res, sc))
        return resp

    def _serve_delta(self, req: DeltaRequest) -> CpdResponse:
        """Resilient delta path: deadline triage, transient retry. The
        jitted merge is functional (`ingest._append`), so a failure mid-
        delta leaves the retained base tensor fully serviceable — the
        structured error invites a clean resubmit, never torn state."""
        if self._expired(req):
            with self._lock:
                self._deadline_expired += 1
            return self._error_response(
                req, self._delta_sc(req),
                f"deadline expired: waited "
                f"{time.perf_counter() - req.submitted_at:.3f}s "
                f"of {req.deadline_s:.3f}s budget")
        try:
            resp, retries, degraded = self._with_ladder(
                None, lambda: self._run_delta(req))
        except KeyError as exc:
            return self._error_response(req, None,
                                        f"base result gone: {exc}")
        except Exception as exc:  # noqa: BLE001 — structured error
            return self._error_response(
                req, self._delta_sc(req),
                f"delta failed (base retained, resubmit is safe): {exc}")
        resp.retries += retries
        resp.degraded = resp.degraded or degraded
        return resp

    def _delta_sc(self, req: DeltaRequest):
        """Best-effort shape class for a delta's error response (the
        base may have aged out of the LRU by then)."""
        with self._lock:
            entry = self._retained.get(req.base_id)
        return entry[3] if entry is not None else None

    def process(self, flush: bool = True) -> list[CpdResponse]:
        """Drain the queues: deltas first (latency-sensitive, already
        warm — solo solves seeded from the retained base), then full
        buckets always, partial ones if ``flush`` — or, under
        ``max_wait_s``, once the bucket's oldest request has aged past
        the wait budget (the deadline-aware flush the worker loop runs
        on). Every admitted request yields exactly one response; failure
        modes come back as structured errors, not exceptions."""
        responses: list[CpdResponse] = []
        while True:
            with self._lock:
                dreq = (self._delta_queue.popleft()
                        if self._delta_queue else None)
            if dreq is None:
                break
            responses.append(self._serve_delta(dreq))
        while True:
            now = time.perf_counter()
            with self._lock:
                batch_ = None
                for sc, q in self._queues.items():
                    ready = len(q) >= self.capacity or (flush and bool(q))
                    if (not ready and q and self.max_wait_s is not None
                            and now - q[0].submitted_at >= self.max_wait_s):
                        ready = True          # deadline-aware flush
                    if ready:
                        n = min(len(q), self.capacity)
                        batch_ = (sc, [q.popleft() for _ in range(n)])
                        break
                empties = [sc for sc, q in self._queues.items() if not q]
                for sc in empties:
                    del self._queues[sc]
            if batch_ is None:
                break
            responses.extend(self._serve_bucket(*batch_))
        self._deliver(responses)
        return responses

    # -- observability ----------------------------------------------------

    def stats(self) -> dict:
        """Serving counters + the trace counters the tests pin."""
        integ = stream_mod.integrity_stats()
        with self._lock:
            lats = sorted(self._latencies)
            n = len(lats)
            done, buckets, busy = (self._tenants_done, self._buckets_run,
                                   self._busy_s)
            classes = len(self._plans)
            deltas = self._deltas_done
            resilience = {
                "retries": self._retries,
                "backoff_s": self._backoff_s,
                "quarantined_tenants": self._quarantined_tenants,
                "degraded_dispatches": self._degraded_dispatches,
                "plan_evictions": self._plan_evictions,
                "deadline_expired": self._deadline_expired,
                "errors": self._errors,
                "worker_alive": (self._worker is not None
                                 and self._worker.is_alive()),
                "worker_recoveries": self._worker_recoveries,
            }

        def pct(p):
            return lats[min(n - 1, int(p * n))] if n else 0.0

        return {
            "tenants_done": done,
            "deltas_done": deltas,
            "buckets_run": buckets,
            "shape_classes": classes,
            "tenants_per_s": (done / busy) if busy > 0 else 0.0,
            "latency_p50_s": pct(0.50),
            "latency_p99_s": pct(0.99),
            "ingest_traces": alto.device_ingest_traces(),
            "sweep_traces": batched.sweep_traces(),
            "checksum_failures": integ["checksum_failures"],
            "stream_rebuilds": integ["rebuilds"],
            **resilience,
        }


# ---------------------------------------------------------------------------
# CLI demo: synthetic tenants with deliberately scattered shapes
# ---------------------------------------------------------------------------

def main(argv=None):
    from repro.sparse.synthetic import uniform_tensor

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=12)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--algorithm", default="cp_als",
                    choices=["cp_als", "cp_apr"])
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--worker", action="store_true",
                    help="serve through the background worker loop "
                         "instead of a caller-driven process()")
    ap.add_argument("--max-wait-s", type=float, default=0.05,
                    help="deadline-aware partial-bucket flush budget "
                         "(worker mode)")
    ap.add_argument("--tune", default="auto",
                    choices=["off", "auto", "force", "search"],
                    help="plan selection: analytic, store-backed "
                         "exhaustive, or budgeted search")
    ap.add_argument("--search-budget", type=int, default=None,
                    help="timing-run budget per class under "
                         "--tune search (default: the engine's 25%% "
                         "of the feasible space)")
    args = ap.parse_args(argv)

    svc = CpdService(args.rank, args.algorithm, capacity=args.capacity,
                     n_iters=args.iters, tune=args.tune,
                     search_budget=args.search_budget,
                     max_wait_s=(args.max_wait_s if args.worker else None))
    rng = np.random.default_rng(args.seed)
    shapes = [(9, 7, 5), (12, 6, 8), (16, 8, 8), (30, 20, 10)]
    rids = []
    if args.worker:
        svc.serve()
    for t in range(args.tenants):
        dims = shapes[t % len(shapes)]
        nnz = int(rng.integers(60, 128))
        x = uniform_tensor(dims, nnz, seed=args.seed + t,
                           count_data=(args.algorithm == "cp_apr"))
        rids.append(svc.submit(x, seed=t))
    print(f"admitted {args.tenants} tenants")
    t0 = time.perf_counter()
    if args.worker:
        responses = [svc.wait(rid, timeout=300.0) for rid in rids]
        svc.shutdown()
    else:
        responses = svc.process()
    dt = time.perf_counter() - t0
    s = svc.stats()
    print(f"served {len(responses)} tenants in {dt:.2f}s "
          f"({s['tenants_per_s']:.1f} tenants/s busy-rate), "
          f"{s['buckets_run']} buckets, {s['shape_classes']} classes")
    print(f"latency p50 {s['latency_p50_s']*1e3:.0f} ms, "
          f"p99 {s['latency_p99_s']*1e3:.0f} ms")
    print(f"jit traces: ingest {s['ingest_traces']}, "
          f"sweeps {s['sweep_traces']}")
    print(f"resilience: retries {s['retries']}, quarantined "
          f"{s['quarantined_tenants']}, degraded {s['degraded_dispatches']}, "
          f"errors {s['errors']}")
    return responses


if __name__ == "__main__":
    main()
