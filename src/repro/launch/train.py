"""Training launcher (LM workloads and the CPD workload).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 100 --batch 8 --seq 128 [--ckpt-dir DIR]
  PYTHONPATH=src python -m repro.launch.train --workload cpd \
      --dims 64,64,48 --rank 8 --iters 10

Fault tolerance: step-addressable checkpoints every --ckpt-every steps
(async), automatic resume from the newest checkpoint in --ckpt-dir,
data-pipeline cursor restored exactly. The same launcher works on the
production mesh by passing --mesh pod|multipod under the dry-run XLA flag.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore)
from repro.configs import get_config, reduced_config
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.models import sharding as shd
from repro.models.common import materialize, shardings
from repro.optim import get_optimizer, warmup_cosine
from repro.train.steps import make_train_step


def train_lm(args):
    cfg = (reduced_config(args.arch, n_repeats=args.reduced_repeats)
           if args.reduced else get_config(args.arch))
    if args.grad_accum:
        cfg = dataclasses.replace(cfg, grad_accum=args.grad_accum)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))

    defs = M.model_def(cfg)
    params = materialize(defs, jax.random.PRNGKey(args.seed),
                         jnp.float32 if cfg.dtype == "float32"
                         else jnp.bfloat16)
    opt = get_optimizer(cfg.optimizer,
                        lr=warmup_cosine(args.lr, warmup=args.warmup,
                                         total=args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt,
                                      compression=args.compression or None))

    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), manifest = restore(
                args.ckpt_dir, last, (params, opt_state))
            start = manifest["step"]
            pipe.skip_to(manifest["data_step"])
            print(f"resumed from step {start}")

    with shd.use_mesh(mesh if args.mesh != "host" else None):
        t0 = time.time()
        for step in range(start, args.steps):
            batch = next(pipe)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"({dt / max(1, step - start + 1):.3f}s/step)",
                      flush=True)
            if ckpt and step > start and step % args.ckpt_every == 0:
                ckpt.save(step, (params, opt_state),
                          data_step=pipe.state.step)
    if ckpt:
        ckpt.save(args.steps, (params, opt_state),
                  data_step=pipe.state.step)
        ckpt.wait()
    return params, metrics


def train_cpd(args):
    """The paper's own workload: CP decomposition, distributed."""
    from repro.dist.cpd import distributed_cp_als
    from repro.sparse import synthetic
    dims = tuple(int(d) for d in args.dims.split(","))
    x = synthetic.zipf_tensor(dims, args.nnz, seed=args.seed)
    mesh = make_host_mesh()
    lam, factors, fits = distributed_cp_als(x, rank=args.rank, mesh=mesh,
                                            n_iters=args.iters,
                                            seed=args.seed)
    for i, f in enumerate(fits):
        print(f"iter {i}: fit {f:.4f}")
    return lam, factors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "cpd"])
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--reduced-repeats", type=int, default=2)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--compression", default="",
                    choices=["", "bf16", "int8_ef"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    # cpd workload
    ap.add_argument("--dims", default="64,64,48")
    ap.add_argument("--nnz", type=int, default=20000)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()
    if args.workload == "cpd":
        train_cpd(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
