"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, and never allocated — the dry-run lowers
against these. Modality frontends are stubs per the assignment: audio
supplies precomputed frame embeddings, vlm supplies patch embeddings +
3-D M-RoPE positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib
from repro.models import sharding as shd

I32 = jnp.int32
BF16 = jnp.bfloat16
F32 = jnp.float32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, B: int, S: int) -> dict:
    specs = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
    if cfg.family == "audio":
        specs["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), BF16)
    if cfg.family == "vlm":
        vis = cfg.vision_prefix
        specs["tokens"] = _sds((B, S - vis), I32)
        specs["patch_embeds"] = _sds((B, vis, cfg.d_model), BF16)
        specs["positions3"] = _sds((3, B, S), I32)
    return specs


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: dict) -> dict:
    """Batch dim over (pod, data); everything else replicated."""
    out = {}
    for k, s in specs.items():
        if k == "positions3":
            log = (None, "batch") + (None,) * (len(s.shape) - 2)
        else:
            log = ("batch",) + (None,) * (len(s.shape) - 1)
        out[k] = shd.sharding_for(mesh, log, s.shape)
    return out


def decode_cache_logical(cfg: ModelConfig, mesh: Mesh, B: int):
    """Pick cache sharding: batch over (pod,data) when divisible; KV heads
    over model when divisible, else the cache sequence axis (SP)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            dp *= mesh.shape[a]
    batch_ok = B % dp == 0
    kv_ok = cfg.n_kv_heads % mesh.shape.get("model", 1) == 0
    return batch_ok, kv_ok


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree, B: int):
    """Shardings for the stacked decode-cache pytree.

    KV caches (path contains 'kv'): shard KV heads over model when
    divisible, else sequence-parallel (SP) over the cache length; batch
    over (pod,data) when divisible, else cache length over data too
    (the B=1 long_500k cells). Recurrent states: heads over model.
    """
    batch_ok, kv_ok = decode_cache_logical(cfg, mesh, B)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_n = mesh.shape.get("model", 1)

    def one(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        is_kv = any("kv" in str(n) for n in names)
        shape = leaf.shape                      # (n_repeats, B, ...)
        spec: list = [None] * len(shape)
        if batch_ok and len(shape) >= 2 and shape[1] == B:
            spec[1] = dp_axes[0] if len(dp_axes) == 1 else dp_axes
        if is_kv and len(shape) == 5:           # (R, B, S, KV, hd)
            if kv_ok:
                spec[3] = "model"
                if not batch_ok and "data" in mesh.shape \
                        and shape[2] % mesh.shape["data"] == 0:
                    spec[2] = "data"            # B=1: SP over data too
            elif shape[2] % model_n == 0:
                spec[2] = "model"               # SP over cache length
        elif not is_kv and len(shape) >= 3:     # recurrent state (R,B,H,..)
            if shape[2] % model_n == 0 and shape[2] >= model_n:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Serve-step inputs: one new token + a seq_len KV cache."""
    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, 1), I32)
    cache = model_lib.abstract_cache(cfg, B, S, BF16)
    extras = {}
    if cfg.family == "vlm":
        extras["positions3"] = _sds((3, B, 1), I32)
    return tokens, cache, extras
