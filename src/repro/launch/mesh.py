"""Production mesh builders.

`make_production_mesh` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax import and only then asks for the mesh.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def describe(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
