"""Sharded checkpointing with elastic restore.

Layout: <dir>/step_<N>/
  manifest.json        — step, data cursor, tree structure, leaf shapes
  arrays.npz           — flat {index: ndarray} (host-gathered shards)

Design points for 1000+ node deployments (documented trade-offs for the
single-host container):
  * save is ASYNC (background thread) — the train loop donates nothing and
    keeps stepping while serialization runs off the critical path;
  * restore is ELASTIC: arrays are saved in their global logical shape and
    re-placed under whatever mesh/sharding the restoring job supplies —
    a job restarted at a different scale (e.g. 256 -> 128 chips) reshards
    transparently via jax.device_put;
  * manifests carry the data-pipeline cursor so restarts resume the exact
    batch stream (with data/pipeline.py's step-addressable batches);
  * integrity: manifest is written LAST (atomic rename), so a partially
    written checkpoint is never eligible for restore.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

Tree = Any

# npz cannot serialize ml_dtypes custom dtypes; store raw bit views
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_savable(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _EXOTIC:
        return a.view(_EXOTIC[name][1])
    return a


def _from_savable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return a.view(_EXOTIC[dtype_name][0])
    return a


def _flatten(tree: Tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Tree, data_step: int = 0,
         extra: dict | None = None) -> str:
    """Synchronous save. Returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    arrays = {str(i): _to_savable(np.asarray(x))
              for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "data_step": data_step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto(
        ).hex() if hasattr(jax.tree_util.tree_structure(tree),
                           "serialize_using_proto") else None,
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    return final


class AsyncCheckpointer:
    """Fire-and-forget saver; at most one outstanding save (back-pressure
    drops intermediate requests, keeping the newest)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self._last_path: str | None = None

    def save(self, step: int, tree: Tree, data_step: int = 0,
             extra: dict | None = None):
        # materialize to host BEFORE backgrounding (donation safety)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            self._last_path = save(self.ckpt_dir, step, host_tree,
                                   data_step, extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def last_path(self):
        self.wait()
        return self._last_path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Tree,
            shardings: Tree | None = None) -> tuple[Tree, dict]:
    """Restore into the structure of `like`; reshard onto `shardings`
    (elastic: any mesh shape works — device_put re-places global arrays)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — structure mismatch")
    out = []
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    for i, (ref, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = _from_savable(data[str(i)], manifest["dtypes"][i])
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != "
                             f"{np.shape(ref)}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest
