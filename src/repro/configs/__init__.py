"""Architecture registry + reduced smoke-test configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, TRAIN_4K, ModelConfig,
                                ShapeConfig, shapes_for)

_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "glm4-9b": "glm4_9b",
    "smollm-360m": "smollm_360m",
    "minitron-8b": "minitron_8b",
    "whisper-base": "whisper_base",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "zamba2-7b": "zamba2_7b",
}

ARCHS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def reduced_config(name: str, n_repeats: int = 2) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (small width/depth/vocab,
    few experts) — the full configs are exercised only via the dry-run."""
    cfg = get_config(name)
    plen = len(cfg.block_pattern)
    over = dict(
        n_layers=plen * n_repeats,
        d_model=64,
        n_heads=4,
        n_kv_heads=4 if cfg.n_kv_heads == cfg.n_heads else 2,
        head_dim=0,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=128,
        encoder_seq=24 if cfg.is_encdec else cfg.encoder_seq,
        ssm_head_dim=16 if cfg.ssm_state or "mamba" in cfg.block_pattern
        else cfg.ssm_head_dim,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_chunk=8,
        attn_chunk=16,
        vision_prefix=8 if cfg.family == "vlm" else cfg.vision_prefix,
        mrope_sections=(2, 3, 3) if cfg.mrope else cfg.mrope_sections,
        grad_accum=1,
        remat=False,
        dtype="float32",
    )
    if cfg.n_experts:
        over.update(n_experts=8, experts_per_token=2, d_expert=32)
    return dataclasses.replace(cfg, **over)


__all__ = ["ARCHS", "get_config", "get_shape", "reduced_config",
           "ModelConfig", "ShapeConfig", "shapes_for", "ALL_SHAPES",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K"]
