"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 ratio), d_ff=0 (blocks
carry their own projections). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2.0, ssm_chunk=256,
    # mLSTM chunk states are the dominant activation; accum=4 brings
    # train_4k to 15.5 GiB/dev on the single pod (§Perf iteration 9)
    grad_accum=4,
)
