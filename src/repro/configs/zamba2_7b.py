"""zamba2-7b [hybrid] — Mamba2 blocks with a shared full-MHA attention
block every 9th layer (81 = 9 x (8 mamba + 1 attn)), ssm_state=64.
[arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14_336, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_chunk=256,
    block_pattern=("mamba",) * 8 + ("attn",),
    grad_accum=4,
)
