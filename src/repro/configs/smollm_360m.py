"""smollm-360m [dense] — llama-arch small, GQA (kv=5).
[hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab_size=49_152,
    rope_theta=10_000.0,
    block_pattern=("attn",), tie_embeddings=True,
    grad_accum=1,
)
