"""minitron-8b [dense] — pruned nemotron, GQA (kv=8).
[arXiv:2407.14679; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16_384, vocab_size=256_000,
    rope_theta=10_000.0,
    block_pattern=("attn",),
    grad_accum=2,
)
