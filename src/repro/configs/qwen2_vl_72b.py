"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. Backbone only: the
vision tower is a STUB (input_specs provides patch embeddings + 3-D
positions). [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29_568, vocab_size=152_064,
    qkv_bias=True, rope_theta=1_000_000.0,
    mrope=True, mrope_sections=(16, 24, 24), vision_prefix=256,
    block_pattern=("attn",),
    grad_accum=8,
)
