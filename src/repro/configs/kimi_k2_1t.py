"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE: 384 experts top-8,
d_expert=2048, 61 layers (prime → pattern length 1). Adafactor optimizer +
bf16 moments + grad_accum=8 keep per-device HBM under the v5e budget at
512 chips (DESIGN.md §6). [arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab_size=163_840,
    n_experts=384, experts_per_token=8, d_expert=2048,
    block_pattern=("moe",),
    optimizer="adafactor", grad_accum=8,
    opt_update_chunks=4,    # sequence optimizer-update temporaries (§Perf)
)
