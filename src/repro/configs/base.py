"""Model/config system: one frozen dataclass per architecture.

Every assigned architecture is expressed as a repeating ``block_pattern``
(e.g. 8×mamba + 1×attn for zamba2) so the model stack can scan over stacked
per-pattern-position parameters — HLO size stays independent of depth, which
is what makes 61-80 layer dry-runs compile quickly.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "audio", "ssm", "vlm", "moe", "hybrid"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    use_rope: bool = True              # False -> absolute sinusoidal (whisper)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # block structure: repeating pattern, cycled to n_layers
    block_pattern: tuple[str, ...] = ("attn",)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_alto_dispatch: bool = True     # ALTO-linearized sorted dispatch
    moe_ep_axis: str = "model"         # model | data (see models/moe.py)

    # SSM / recurrent
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    mlstm_proj_factor: float = 2.0

    # encoder-decoder (audio family)
    encoder_layers: int = 0
    encoder_seq: int = 1500            # whisper 30 s of 10 ms frames / 2

    # vlm
    mrope: bool = False
    mrope_sections: tuple[int, ...] = (16, 24, 24)  # t/h/w head_dim halves
    vision_prefix: int = 256           # stubbed patch-embedding positions

    # numerics / training
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"      # nothing | dots (save matmul outs;
                                       # trades scan-carried memory for
                                       # less recompute — per-cell choice)
    opt_update_chunks: int = 1         # >1: sequence optimizer leaf updates
    loss_seq_chunk: int = 0            # >0: CE over seq chunks (never
                                       # materializes full (B,S,V) logits)
    scan_unroll: bool = False          # unroll scans (cost-calibration runs)
    attn_chunk: int = 1024             # query-chunked attention block
    optimizer: str = "adamw"           # adamw | adafactor
    grad_accum: int = 1                # microbatch accumulation steps

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))
        if self.n_layers % len(self.block_pattern):
            raise ValueError(
                f"{self.name}: n_layers {self.n_layers} not a multiple of "
                f"pattern {self.block_pattern}")

    # vocab padding: embedding/unembed tables round up so the vocab axis
    # shards over the model axis (granite's 49155 / whisper's 51865 would
    # otherwise replicate the logits across all TP ranks)
    vocab_pad_to: int = 128

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab_size // p) * p

    @property
    def n_repeats(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (SSM/hybrid state recurrence)."""
        return any(b in ("mamba", "mlstm", "slstm")
                   for b in self.block_pattern)

    def layer_types(self) -> list[str]:
        return [self.block_pattern[i % len(self.block_pattern)]
                for i in range(self.n_layers)]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (the assigned shapes)."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells an architecture actually runs (skips per DESIGN.md)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out
