"""whisper-base [audio] — encoder-decoder; the conv/mel frontend is a STUB
per the assignment (input_specs provides precomputed frame embeddings).
Absolute sinusoidal positions (no RoPE). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51_865,
    use_rope=False,
    block_pattern=("attn",),              # decoder blocks become xattn
    # 1536 (not whisper's 1500): divisible by the 16-way model axis so the
    # stub encoder frames can sequence-shard; the frontend is a stub anyway
    encoder_layers=6, encoder_seq=1536,
    grad_accum=1,
)
