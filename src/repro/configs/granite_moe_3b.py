"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_expert=512; the MoE
dispatch runs through the ALTO-linearized sorted path (DESIGN.md §4).
[hf:ibm-granite/granite-3.0-*-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab_size=49_155,
    n_experts=40, experts_per_token=8, d_expert=512,
    block_pattern=("moe",), tie_embeddings=True,
    grad_accum=1,
)
