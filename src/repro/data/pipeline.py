"""Deterministic, restart-safe synthetic token pipeline.

Every batch is a pure function of (seed, step), so:
  * skip-to-step restart is exact (fault tolerance: after restore, the
    pipeline resumes at `state.step` with identical data);
  * elastic re-sharding is trivial (batches are generated globally and
    sharded by the same rule as the train step's in_shardings);
  * no host state needs checkpointing beyond the integer cursor.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataState:
    seed: int
    step: int


def make_batch(cfg: ModelConfig, B: int, S: int, seed: int,
               step: int) -> dict:
    """Global batch for (seed, step) — identical on every host."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # zipf-ish unigram stream: realistic token frequency skew
    z = rng.zipf(1.3, size=(B, S + 1))
    tokens_full = ((z - 1) % cfg.vocab_size).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens_full[:, :S]),
             "labels": jnp.asarray(tokens_full[:, 1:])}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        vis = cfg.vision_prefix
        batch["tokens"] = batch["tokens"][:, :S - vis]
        batch["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (B, vis, cfg.d_model)).astype(np.float32))
        pos = np.broadcast_to(np.arange(S, dtype=np.int32), (3, B, S))
        batch["positions3"] = jnp.asarray(pos.copy())
        batch["labels"] = jnp.concatenate(
            [jnp.full((B, vis), -1, jnp.int32),
             batch["labels"][:, :S - vis]], axis=1)
    return batch


class TokenPipeline:
    """Iterator with an explicit, checkpointable cursor."""

    def __init__(self, cfg: ModelConfig, B: int, S: int, seed: int = 0,
                 start_step: int = 0):
        self.cfg, self.B, self.S = cfg, B, S
        self.state = DataState(seed=seed, step=start_step)

    def __next__(self):
        batch = make_batch(self.cfg, self.B, self.S, self.state.seed,
                           self.state.step)
        self.state.step += 1
        return batch

    def __iter__(self):
        return self

    def skip_to(self, step: int):
        self.state.step = step
