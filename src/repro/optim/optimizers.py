"""Optimizers with shardable state trees.

Each optimizer exposes `init / update / state_defs`; `state_defs` mirrors
the parameter `ParamDef` tree so the launcher can derive NamedShardings for
optimizer state exactly like for params (ZeRO: states inherit the param's
FSDP+TP sharding). Adafactor offers a factored second moment + bf16 first
moment for the 1T-parameter configs where full f32 Adam state would not fit
the per-device HBM budget (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamDef, is_def

Tree = Any


class Optimizer(NamedTuple):
    init: Callable[[Tree], Tree]
    update: Callable[[Tree, Tree, Tree, jnp.ndarray], tuple[Tree, Tree]]
    state_defs: Callable[[Tree], Tree]


def _tmap(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


# -----------------------------------------------------------------------
# AdamW
# -----------------------------------------------------------------------

def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, moment_dtype), params),
            "v": _tmap(lambda p: jnp.zeros(p.shape, moment_dtype), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, step=None):
        count = state["count"] + 1
        stepf = count.astype(jnp.float32)
        lr_t = lr_fn(stepf)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            return (p_new.astype(p.dtype), m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = _tmap(upd, grads, state["m"], state["v"], params)
        new_params = _tmap(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_m = _tmap(lambda o: o[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
        new_v = _tmap(lambda o: o[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}

    def state_defs(param_defs):
        mom = _tmap(lambda d: ParamDef(d.shape, d.logical, init="zeros"),
                    param_defs, is_leaf=is_def)
        return {"m": mom, "v": mom,
                "count": ParamDef((), (), init="zeros")}

    return Optimizer(init, update, state_defs)


# -----------------------------------------------------------------------
# Adafactor (factored second moment, bf16 first moment)
# -----------------------------------------------------------------------

def adafactor(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
              b1: float = 0.9, decay: float = 0.99, eps: float = 1e-30,
              weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1] if _factored(p.shape) else p.shape,
                             jnp.float32)

        def vc(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p.shape) else jnp.zeros((), jnp.float32))

        return {
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            "vr": _tmap(vr, params),
            "vc": _tmap(vc, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, step=None):
        count = state["count"] + 1
        lr_t = lr_fn(count.astype(jnp.float32))

        def upd(g, m, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr_new = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
                vc_new = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr_new[..., None] * vc_new[..., None, :]
                         / jnp.maximum(
                             jnp.mean(vr_new, axis=-1,
                                      keepdims=True)[..., None], eps))
                pre = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            else:
                vr_new = decay * vr + (1 - decay) * g2
                vc_new = vc
                pre = g * jax.lax.rsqrt(jnp.maximum(vr_new, eps))
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * pre
            delta = m_new
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            return (p_new.astype(p.dtype), m_new.astype(jnp.bfloat16),
                    vr_new, vc_new)

        out = _tmap(upd, grads, state["m"], state["vr"], state["vc"],
                    params)
        pick = lambda i: _tmap(lambda o: o[i], out,  # noqa: E731
                               is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "vr": pick(2), "vc": pick(3),
                         "count": count}

    def state_defs(param_defs):
        def vr(d):
            if len(d.shape) >= 2:
                return ParamDef(d.shape[:-1], d.logical[:-1], init="zeros")
            return ParamDef(d.shape, d.logical, init="zeros")

        def vc(d):
            if len(d.shape) >= 2:
                return ParamDef(d.shape[:-2] + d.shape[-1:],
                                d.logical[:-2] + d.logical[-1:],
                                init="zeros")
            return ParamDef((), (), init="zeros")

        mom = _tmap(lambda d: ParamDef(d.shape, d.logical, init="zeros"),
                    param_defs, is_leaf=is_def)
        return {"m": mom,
                "vr": _tmap(vr, param_defs, is_leaf=is_def),
                "vc": _tmap(vc, param_defs, is_leaf=is_def),
                "count": ParamDef((), (), init="zeros")}

    return Optimizer(init, update, state_defs)


def warmup_cosine(peak_lr: float, warmup: int = 1000,
                  total: int = 100_000, floor: float = 0.1):
    def lr(step):
        warm = peak_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def get_optimizer(name: str, lr=3e-4, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name}")
