from repro.optim.optimizers import (Optimizer, adamw, adafactor,
                                    get_optimizer, warmup_cosine)
from repro.optim import compress

__all__ = ["Optimizer", "adamw", "adafactor", "get_optimizer",
           "warmup_cosine", "compress"]
