"""Gradient compression for cross-pod reduction.

Two schemes, applied to the gradient tree *before* the optimizer:
  * bf16: cast gradients to bf16 for the all-reduce (2x wire bytes).
  * int8 + error feedback: per-tensor symmetric int8 quantization; the
    quantization residual is carried in an error-feedback buffer so the
    compression bias vanishes over steps (Seide et al. / 1-bit SGD lineage).

Under jit + GSPMD the cast happens before the reduce-scatter/all-reduce
that grad averaging lowers to, so the collective moves the compressed
payload. Error-feedback state shards like the gradient itself.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


def bf16_compress(grads: Tree) -> Tree:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def init_error_feedback(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def int8_compress_decompress(g: jnp.ndarray, err: jnp.ndarray
                             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize g+err to int8, return (dequantized, new error)."""
    x = g.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, (x - deq).astype(jnp.bfloat16)


def int8_with_error_feedback(grads: Tree, err_state: Tree
                             ) -> tuple[Tree, Tree]:
    out = jax.tree.map(int8_compress_decompress, grads, err_state)
    deq = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err
