"""Train / serve step builders.

`make_train_step` produces a pure function (params, opt_state, batch) ->
(params, opt_state, metrics) with:
  * optional microbatch gradient accumulation via lax.scan (the standard
    memory lever for deep configs — it also lets XLA overlap the
    reduce-scatter of one microbatch's grads with the next's backward);
  * optional gradient compression (bf16 / int8+error-feedback) applied
    before grad averaging so cross-pod collectives move compressed bytes;
  * global-norm clipping.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.optim.optimizers import Optimizer
from repro.optim import compress as compress_lib

Tree = Any


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token CE with -1 = ignore. logits (B,S,V) f32, labels (B,S) i32."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    loss = (lse - ll) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1.0)


def _chunked_ce(cfg: ModelConfig, params, hidden, labels, chunk: int):
    """CE via a remat'd scan over sequence chunks: the (B,C,V) logits of
    one chunk are the only vocab-sized live buffer (vs (B,S,V) f32 —
    for a 152k vocab at 4k seq that's the largest activation in the
    whole step)."""
    from repro.models.model import unembed_params
    from repro.models.common import unembed
    B, S, D = hidden.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    nC = S // C
    emb = unembed_params(cfg, params)
    hc = jnp.moveaxis(hidden.reshape(B, nC, C, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nC, C), 1, 0)

    def body(carry, args):
        h, lab = args
        logits = unembed(emb, h)
        mask = (lab >= 0).astype(jnp.float32)
        safe = jnp.maximum(lab, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        s, n = carry
        return (s + jnp.sum((lse - ll) * mask), n + jnp.sum(mask)), None

    body = jax.checkpoint(body)
    (s, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc), unroll=nC if cfg.scan_unroll else 1)
    return s / jnp.maximum(n, 1.0)


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch):
        if cfg.loss_seq_chunk > 0:
            hidden, aux = model_lib.forward_hidden(cfg, params, batch)
            ce = _chunked_ce(cfg, params, hidden, batch["labels"],
                             cfg.loss_seq_chunk)
        else:
            logits, aux = model_lib.forward(cfg, params, batch)
            ce = cross_entropy(logits, batch["labels"])
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}
    return loss_fn


def global_norm(tree: Tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Tree, max_norm: float) -> Tree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    clip_norm: float = 1.0,
                    compression: str | None = None):
    """compression: None | 'bf16' | 'int8_ef'."""
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    accum = max(1, cfg.grad_accum)

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        def _split(key, x):
            ax = 1 if key == "positions3" else 0   # (3, B, S) batches dim 1
            n = x.shape[ax] // accum
            parts = jnp.moveaxis(
                x.reshape(x.shape[:ax] + (accum, n) + x.shape[ax + 1:]),
                ax, 0)
            return parts

        micro = {k: _split(k, v) for k, v in batch.items()}

        def body(carry, mb):
            g_acc, m_acc = carry
            (loss, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        m0 = {"loss": 0.0, "ce": 0.0, "aux": 0.0}
        m0 = jax.tree.map(jnp.float32, m0)
        (grads, metrics), _ = jax.lax.scan(
            body, (g0, m0), micro, unroll=accum if cfg.scan_unroll else 1)
        grads = jax.tree.map(lambda g: g / accum, grads)
        metrics = jax.tree.map(lambda m: m / accum, metrics)
        return grads, metrics

    def apply_update(grads, opt_state, params):
        """Optimizer update; opt_update_chunks > 1 sequences leaf GROUPS:
        each group's gradient inputs are barrier-gated on the previous
        group's outputs, so only one group's f32 update temporaries are
        live at a time (the 1T-param configs would otherwise hold f32
        copies of every leaf simultaneously)."""
        chunks = max(1, cfg.opt_update_chunks)
        if chunks == 1:
            return optimizer.update(grads, opt_state, params)
        gl, tdef = jax.tree.flatten(grads)
        pl = jax.tree.flatten(params)[0]
        state_keys = [k for k in opt_state if k != "count"]
        sl = {k: jax.tree.flatten(opt_state[k])[0] for k in state_keys}
        n = len(gl)
        per = -(-n // chunks)
        new_p = [None] * n
        new_s: dict = {k: [None] * n for k in state_keys}
        count0 = opt_state["count"]
        count_new = None
        token = None
        for i in range(0, n, per):
            idx = list(range(i, min(n, i + per)))
            sub_g = [gl[j] for j in idx]
            if token is not None:
                sub_g = [jax.lax.optimization_barrier((g, token))[0]
                         for g in sub_g]
            sub_state = {k: [sl[k][j] for j in idx] for k in state_keys}
            sub_state["count"] = count0
            p2, s2 = optimizer.update(sub_g, sub_state,
                                      [pl[j] for j in idx])
            count_new = s2["count"]
            token = p2[-1].ravel()[:1]
            for o, j in enumerate(idx):
                new_p[j] = p2[o]
                for k in state_keys:
                    new_s[k][j] = s2[k][o]
        out_state = {k: jax.tree.unflatten(
            jax.tree.structure(opt_state[k]), new_s[k])
            for k in state_keys}
        out_state["count"] = count_new
        return jax.tree.unflatten(tdef, new_p), out_state

    def train_step(params, opt_state, batch, compress_state=None):
        grads, metrics = compute_grads(params, batch)
        if compression == "bf16":
            grads = compress_lib.bf16_compress(grads)
        elif compression == "int8_ef":
            grads, compress_state = compress_lib.int8_with_error_feedback(
                grads, compress_state)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = apply_update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        if compression == "int8_ef":
            return params, opt_state, metrics, compress_state
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, s_max: int):
    def prefill_step(params, batch):
        return model_lib.prefill(cfg, params, batch, s_max)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache, index, positions3=None):
        return model_lib.decode_step(cfg, params, tokens, cache, index,
                                     positions3=positions3)
    return decode_step
