"""State-space / linear-recurrence substrate.

`ssd_chunked` is the shared chunked-scan core (Mamba2's SSD algorithm):
within a chunk the recurrence is computed in a parallel attention-like
form; across chunks a lax.scan carries the (H, N, P) state. Both Mamba2
blocks (zamba2) and mLSTM cells (xlstm) lower onto this core — an mLSTM is
the same recurrence with a = log f, B = k, X = i·v, C = q.

Decode is the O(1) per-token state update, which is what makes the
long_500k cell runnable for the ssm/hybrid architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.common import ParamDef, rmsnorm


def ssd_chunked(a, Bm, X, Cm, chunk: int, unroll: bool = False):
    """Chunked linear recurrence  h_t = exp(a_t)·h_{t-1} + B_t ⊗ X_t,
    y_t = C_t · h_t.

    a:  (B, S, H)      log-decay per step
    Bm: (B, S, H, N)   input maps (broadcast H=1 allowed)
    X:  (B, S, H, P)   inputs
    Cm: (B, S, H, N)   output maps (broadcast H=1 allowed)
    Returns y (B, S, H, P), final state (B, H, N, P).
    """
    Bsz, S, H = a.shape
    N = Bm.shape[-1]
    P = X.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q

    G = Bm.shape[2]
    hpg = H // G                                        # heads per group
    af = a.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    # expand group maps to per-head (a broadcast XLA fuses, G==H is a no-op)
    Bh = jnp.repeat(Bm.reshape(Bsz, nc, Q, G, N), hpg,
                    axis=3).astype(jnp.float32)          # (B,nc,Q,H,N)
    Ch = jnp.repeat(Cm.reshape(Bsz, nc, Q, G, N), hpg,
                    axis=3).astype(jnp.float32)
    Xc = X.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)

    cum = jnp.cumsum(af, axis=2)                       # (B,nc,Q,H)
    total = cum[:, :, -1:, :]                          # (B,nc,1,H)

    # --- intra-chunk (parallel attention-like form) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)    # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * L, Xc)

    # --- chunk states ---
    decay_state = jnp.exp(total - cum)                  # (B,nc,Q,H)
    BX = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bh, decay_state, Xc)

    chunk_decay = jnp.exp(total[:, :, 0, :])            # (B,nc,H)

    def scan_fn(h, args):
        bx, dec = args
        h_prev = h
        h = h * dec[:, :, None, None] + bx
        return h, h_prev

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(BX, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=nc if unroll else 1)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,H,N,P)

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Ch, h_prevs)
    y_inter = y_inter * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y.astype(X.dtype), hT


def ssd_step(h, a, Bm, X, Cm):
    """Single-token recurrence step. h: (B,H,N,P); a: (B,H);
    Bm/Cm: (B,G,N); X: (B,H,P). Returns y (B,H,P), new h."""
    G = Bm.shape[1]
    hpg = h.shape[1] // G
    Bfull = jnp.repeat(Bm, hpg, axis=1)                 # (B,H,N)
    Cfull = jnp.repeat(Cm, hpg, axis=1)
    h = h * jnp.exp(a.astype(jnp.float32))[:, :, None, None] \
        + Bfull[..., None].astype(jnp.float32) * X[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cfull.astype(jnp.float32), h)
    return y.astype(X.dtype), h


# -----------------------------------------------------------------------
# Mamba2 block
# -----------------------------------------------------------------------

class MambaCache(NamedTuple):
    conv: jnp.ndarray    # (B, W-1, H, P + 2N/H… flattened conv channels)
    h: jnp.ndarray       # (B, H, N, P)


def mamba_def(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    P = cfg.ssm_head_dim
    H = (2 * D) // P                   # expand factor 2
    N = cfg.ssm_state
    W = cfg.conv_width
    return {
        "wz": ParamDef((D, H, P), ("fsdp", "heads", None)),
        "wx": ParamDef((D, H, P), ("fsdp", "heads", None)),
        "wB": ParamDef((D, N), ("fsdp", None)),
        "wC": ParamDef((D, N), ("fsdp", None)),
        "wdt": ParamDef((D, H), ("fsdp", "heads")),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "a_log": ParamDef((H,), ("heads",), init="zeros"),
        "skip": ParamDef((H,), ("heads",), init="ones"),
        "conv_x": ParamDef((W, H, P), (None, "heads", None), init="normal"),
        "conv_B": ParamDef((W, N), (None, None), init="normal"),
        "conv_C": ParamDef((W, N), (None, None), init="normal"),
        "norm": ParamDef((H, P), ("heads", None), init="ones"),
        "wo": ParamDef((H, P, D), ("heads", None, "fsdp"), axis=-3),
    }


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv along seq. x: (B,S,...C), w: (W,...C)."""
    W = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], W - 1) + x.shape[2:], x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(W))
    new_cache = xp[:, -(W - 1):] if W > 1 else pad
    return jax.nn.silu(out), new_cache


def mamba_apply(cfg: ModelConfig, p, x, return_cache: bool = False):
    """x: (B, S, D) -> (B, S, D). Training / prefill path."""
    B_, S, D = x.shape
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.conv_width
    H = (2 * D) // P
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"].astype(x.dtype))
    xs0 = jnp.einsum("bsd,dhp->bshp", x, p["wx"].astype(x.dtype))
    Bm0 = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))
    Cm0 = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))
    xs, _ = _causal_conv(xs0, p["conv_x"])
    Bm, _ = _causal_conv(Bm0, p["conv_B"])
    Cm, _ = _causal_conv(Cm0, p["conv_C"])
    xs = shd.act(xs, ("batch", None, "heads", None))

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))        # (H,) negative
    a = dt * A[None, None, :]                            # (B,S,H) log decay
    X = xs.astype(jnp.float32) * dt[..., None]
    y, hT = ssd_chunked(a, Bm[:, :, None, :], X, Cm[:, :, None, :],
                        cfg.ssm_chunk, unroll=cfg.scan_unroll)
    y = y + xs * p["skip"].astype(x.dtype)[None, None, :, None]
    y = rmsnorm({"scale": p["norm"].reshape(-1)},
                y.reshape(B_, S, H * P)).reshape(B_, S, H, P)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(x.dtype))
    if not return_cache:
        return out
    # conv cache: last W-1 *pre-conv* channel values, matching decode layout
    tail = jnp.concatenate(
        [xs0.reshape(B_, S, H * P), Bm0, Cm0], axis=-1)[:, -(W - 1):]
    return out, MambaCache(conv=tail.astype(jnp.bfloat16), h=hT)


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    D = cfg.d_model
    P, N, W = cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_width
    H = (2 * D) // P
    return MambaCache(
        conv=jnp.zeros((batch, W - 1, H * P + 2 * N), dtype),
        h=jnp.zeros((batch, H, N, P), jnp.float32))


def mamba_decode(cfg: ModelConfig, p, x, cache: MambaCache):
    """x: (B, 1, D) one token. Returns y (B,1,D), new cache."""
    B_, _, D = x.shape
    P, N, W = cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_width
    H = (2 * D) // P
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"].astype(x.dtype))
    xs = jnp.einsum("bsd,dhp->bshp", x, p["wx"].astype(x.dtype))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))

    conv_in = jnp.concatenate(
        [xs.reshape(B_, 1, H * P), Bm, Cm], axis=-1)     # (B,1,HP+2N)
    xp = jnp.concatenate([cache.conv.astype(x.dtype), conv_in], axis=1)
    w_full = jnp.concatenate(
        [p["conv_x"].reshape(W, H * P), p["conv_B"], p["conv_C"]], axis=-1)
    conv_out = jnp.einsum("bwc,wc->bc", xp, w_full.astype(x.dtype))
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[:, :H * P].reshape(B_, H, P)
    Bm = conv_out[:, H * P:H * P + N].reshape(B_, 1, N)
    Cm = conv_out[:, H * P + N:].reshape(B_, 1, N)
    new_conv = xp[:, 1:]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = dt * A[None, :]
    X = xs.astype(jnp.float32) * dt[..., None]
    y, h = ssd_step(cache.h, a, Bm, X, Cm)               # (B,H,P)
    y = y + xs * p["skip"].astype(x.dtype)[None, :, None]
    y = rmsnorm({"scale": p["norm"].reshape(-1)},
                y.reshape(B_, 1, H * P)).reshape(B_, H, P)
    y = y * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bhp,hpd->bd", y, p["wo"].astype(x.dtype))
    return out[:, None, :], MambaCache(conv=new_conv.astype(cache.conv.dtype),
                                       h=h)
