"""Dense SwiGLU MLP (TP-sharded on the hidden axis)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.common import ParamDef, swiglu


def mlp_def(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((D, F), ("fsdp", "mlp")),
        "w_up": ParamDef((D, F), ("fsdp", "mlp")),
        "w_down": ParamDef((F, D), ("mlp", "fsdp")),
    }


def mlp(p, x):
    h = swiglu(jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype)),
               jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)))
    h = shd.act(h, ("batch", None, "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
