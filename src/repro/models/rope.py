"""Rotary position embeddings: standard RoPE and multi-axis M-RoPE.

M-RoPE (qwen2-vl): the head_dim/2 frequency slots are split into sections
(temporal, height, width); each section rotates with its own position
stream. Text tokens carry identical t/h/w positions, so M-RoPE degenerates
to RoPE on text — the stub vision frontend supplies 3-D positions for the
patch-embedding prefix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: tuple[int, ...]) -> jnp.ndarray:
    """x: (B, S, H, hd); positions3: (3, B, S) int32 (t, h, w streams).

    sections sum to hd/2; frequency slot j uses the position stream of the
    section containing j (Qwen2-VL §2.1).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    # stream id per frequency slot
    stream = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=hd // 2)
    pos = positions3.astype(jnp.float32)                 # (3, B, S)
    pos_per_slot = jnp.take(pos, stream, axis=0)         # (hd/2, B, S)
    ang = jnp.transpose(pos_per_slot, (1, 2, 0)) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)
