"""Parameter definition machinery + shared layers (norms, embeddings).

Params are nested dicts of arrays. Each module first builds a matching tree
of `ParamDef` (shape + logical sharding axes + init law); `materialize`
turns defs into arrays, `abstract` into ShapeDtypeStructs (dry-run path —
no host allocation for 1T-parameter configs), `shardings` into
NamedShardings via the logical rule table.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import sharding as shd

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "fan_in"      # fan_in | zeros | ones | normal | embed
    axis: int = -2            # fan-in axis for fan_in init

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"{self.shape} vs {self.logical}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(key, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape) * 0.02).astype(dtype)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * 1.0).astype(dtype)
    fan_in = d.shape[d.axis] if len(d.shape) > 1 else d.shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, d.shape) * scale).astype(dtype)


def materialize(defs: Tree, key, dtype=jnp.float32) -> Tree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, d, dtype) for k, d in zip(keys, leaves)])


def abstract(defs: Tree, dtype=jnp.float32) -> Tree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def shardings(defs: Tree, mesh) -> Tree:
    return jax.tree.map(
        lambda d: shd.sharding_for(mesh, d.logical, d.shape), defs,
        is_leaf=is_def)


def shardings_inference(defs: Tree, mesh, keep_fsdp: bool = False) -> Tree:
    """Param shardings for serving: TP/EP axes only. FSDP sharding is a
    *training* trade (it turns every step into a param all-gather); for
    decode it makes the collective term the bottleneck, so unless the
    model cannot fit per-device without it (keep_fsdp=True for the
    1T-class configs) params replicate across data/pod."""
    if keep_fsdp:
        return shardings(defs, mesh)

    def one(d):
        logical = tuple(None if ax == "fsdp" else ax for ax in d.logical)
        return shd.sharding_for(mesh, logical, d.shape)

    return jax.tree.map(one, defs, is_leaf=is_def)


def bytes_per_device(defs: Tree, mesh, dtype_bytes: int = 2,
                     keep_fsdp: bool = False) -> int:
    """Exact per-device param bytes under the given sharding policy."""
    total = 0
    shds = (shardings(defs, mesh) if keep_fsdp
            else shardings_inference(defs, mesh, False))
    for d, s in zip(jax.tree.leaves(defs, is_leaf=is_def),
                    jax.tree.leaves(shds,
                                    is_leaf=lambda x: hasattr(x, "spec"))):
        shard = 1
        for ax in jax.tree.leaves(tuple(s.spec)):
            if ax is not None:
                shard *= mesh.shape[ax]
        total += int(np.prod(d.shape)) * dtype_bytes // max(1, shard)
    return total


def specs(defs: Tree, mesh) -> Tree:
    return jax.tree.map(
        lambda d: shd.spec_for(mesh, d.logical, d.shape), defs,
        is_leaf=is_def)


def n_params(defs: Tree) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=is_def))


# -----------------------------------------------------------------------
# layers
# -----------------------------------------------------------------------

def rmsnorm_def(dim: int) -> Tree:
    return {"scale": ParamDef((dim,), ("embed",), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_def(dim: int) -> Tree:
    return {"scale": ParamDef((dim,), ("embed",), init="ones"),
            "bias": ParamDef((dim,), ("embed",), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def embed_def(vocab: int, dim: int) -> Tree:
    return {"tokens": ParamDef((vocab, dim), ("vocab", "fsdp"),
                               init="embed")}


def embed(p, ids):
    return jnp.take(p["tokens"], ids, axis=0)


def unembed(p, x):
    """Logits in f32 (vocab sharded over model)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["tokens"].astype(jnp.float32))


def swiglu(x_gate, x_up):
    return jax.nn.silu(x_gate) * x_up
