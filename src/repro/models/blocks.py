"""Block registry: every architecture is a repeating pattern of these.

Types: attn (attention+MLP), moe (attention+MoE), xattn (self+cross+MLP,
whisper decoder), mamba, mlstm, slstm. Each type provides def/apply/decode/
cache-init with a uniform signature so the model can scan over
heterogeneous patterns.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.models.common import rmsnorm, rmsnorm_def
from repro.models.mlp import mlp, mlp_def


def block_def(cfg: ModelConfig, btype: str) -> dict:
    if btype == "attn":
        return {"ln1": rmsnorm_def(cfg.d_model), "attn": attn.attn_def(cfg),
                "ln2": rmsnorm_def(cfg.d_model), "mlp": mlp_def(cfg)}
    if btype == "moe":
        return {"ln1": rmsnorm_def(cfg.d_model), "attn": attn.attn_def(cfg),
                "ln2": rmsnorm_def(cfg.d_model), "moe": moe_mod.moe_def(cfg)}
    if btype == "xattn":
        return {"ln1": rmsnorm_def(cfg.d_model), "attn": attn.attn_def(cfg),
                "lnx": rmsnorm_def(cfg.d_model),
                "xattn": attn.attn_def(cfg),
                "ln2": rmsnorm_def(cfg.d_model), "mlp": mlp_def(cfg)}
    if btype == "mamba":
        return {"ln1": rmsnorm_def(cfg.d_model), "mamba": ssm.mamba_def(cfg)}
    if btype == "mlstm":
        return {"ln1": rmsnorm_def(cfg.d_model),
                "mlstm": xlstm.mlstm_def(cfg)}
    if btype == "slstm":
        return {"ln1": rmsnorm_def(cfg.d_model),
                "slstm": xlstm.slstm_def(cfg)}
    raise ValueError(f"unknown block type {btype}")


def block_apply(cfg: ModelConfig, btype: str, p, x, *, positions=None,
                positions3=None, enc_out=None, causal=True):
    """Full-sequence apply. Returns (x, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if btype in ("attn", "moe", "xattn"):
        h = attn.attention_full(cfg, p["attn"], rmsnorm(p["ln1"], x, eps),
                                positions, causal=causal,
                                positions3=positions3)
        x = x + h.astype(x.dtype)
        if btype == "xattn":
            h = attn.attention_full(cfg, p["xattn"],
                                    rmsnorm(p["lnx"], x, eps),
                                    positions, causal=False, kv_x=enc_out)
            x = x + h.astype(x.dtype)
        if btype == "moe":
            h, aux = moe_mod.moe_ffn(cfg, p["moe"], rmsnorm(p["ln2"], x,
                                                            eps))
        else:
            h = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps))
        return x + h.astype(x.dtype), aux
    if btype == "mamba":
        return x + ssm.mamba_apply(cfg, p["mamba"],
                                   rmsnorm(p["ln1"], x, eps)
                                   ).astype(x.dtype), aux
    if btype == "mlstm":
        return x + xlstm.mlstm_apply(cfg, p["mlstm"],
                                     rmsnorm(p["ln1"], x, eps)
                                     ).astype(x.dtype), aux
    if btype == "slstm":
        return x + xlstm.slstm_apply(cfg, p["slstm"],
                                     rmsnorm(p["ln1"], x, eps)
                                     ).astype(x.dtype), aux
    raise ValueError(btype)


def block_cache_init(cfg: ModelConfig, btype: str, batch: int, s_max: int,
                     dtype=jnp.bfloat16) -> Any:
    if btype in ("attn", "moe"):
        return {"kv": attn.init_kv_cache(cfg, batch, s_max, dtype)}
    if btype == "xattn":
        return {"kv": attn.init_kv_cache(cfg, batch, s_max, dtype),
                "xkv": attn.init_kv_cache(cfg, batch, cfg.encoder_seq,
                                          dtype)}
    if btype == "mamba":
        return {"state": ssm.mamba_init_cache(cfg, batch, dtype)}
    if btype == "mlstm":
        return {"state": xlstm.mlstm_init_cache(cfg, batch, dtype)}
    if btype == "slstm":
        return {"state": xlstm.slstm_init_cache(cfg, batch, dtype)}
    raise ValueError(btype)


def block_prefill(cfg: ModelConfig, btype: str, p, x, *, positions=None,
                  positions3=None, enc_out=None, s_max: int = 0,
                  cache_dtype=jnp.bfloat16):
    """Full-sequence apply that also emits the decode cache.

    For attention the (k, v) of the S prefilled positions are padded to
    s_max; recurrent blocks emit their final state.
    """
    eps = cfg.norm_eps
    S = x.shape[1]

    def pad_kv(k, v):
        pad = s_max - S
        if pad > 0:
            zeros = jnp.zeros((k.shape[0], pad) + k.shape[2:], cache_dtype)
            k = jnp.concatenate([k.astype(cache_dtype), zeros], axis=1)
            v = jnp.concatenate([v.astype(cache_dtype), zeros], axis=1)
        return attn.KVCache(k.astype(cache_dtype), v.astype(cache_dtype))

    if btype in ("attn", "moe", "xattn"):
        h, (k, v) = attn.attention_full(cfg, p["attn"],
                                        rmsnorm(p["ln1"], x, eps),
                                        positions, causal=True,
                                        positions3=positions3,
                                        return_kv=True)
        x = x + h.astype(x.dtype)
        cache = {"kv": pad_kv(k, v)}
        if btype == "xattn":
            h, (xk, xv) = attn.attention_full(cfg, p["xattn"],
                                              rmsnorm(p["lnx"], x, eps),
                                              positions, causal=False,
                                              kv_x=enc_out, return_kv=True)
            x = x + h.astype(x.dtype)
            cache["xkv"] = attn.KVCache(xk.astype(cache_dtype),
                                        xv.astype(cache_dtype))
        if btype == "moe":
            h, _ = moe_mod.moe_ffn(cfg, p["moe"], rmsnorm(p["ln2"], x, eps))
        else:
            h = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps))
        return x + h.astype(x.dtype), cache
    if btype == "mamba":
        h, st = ssm.mamba_apply(cfg, p["mamba"], rmsnorm(p["ln1"], x, eps),
                                return_cache=True)
        return x + h.astype(x.dtype), {"state": st}
    if btype == "mlstm":
        h, st = xlstm.mlstm_apply(cfg, p["mlstm"],
                                  rmsnorm(p["ln1"], x, eps),
                                  return_cache=True)
        return x + h.astype(x.dtype), {"state": st}
    if btype == "slstm":
        h, st = xlstm.slstm_apply(cfg, p["slstm"],
                                  rmsnorm(p["ln1"], x, eps),
                                  return_cache=True)
        return x + h.astype(x.dtype), {"state": st}
    raise ValueError(btype)


def block_decode(cfg: ModelConfig, btype: str, p, x, cache, index, *,
                 positions3=None):
    """One-token decode. Returns (x, new_cache)."""
    eps = cfg.norm_eps
    if btype in ("attn", "moe", "xattn"):
        h, kv = attn.attention_decode(cfg, p["attn"],
                                      rmsnorm(p["ln1"], x, eps),
                                      cache["kv"], index,
                                      positions3=positions3)
        x = x + h.astype(x.dtype)
        new_cache = dict(cache)
        new_cache["kv"] = kv
        if btype == "xattn":
            h, _ = attn.attention_decode(cfg, p["xattn"],
                                         rmsnorm(p["lnx"], x, eps),
                                         cache["xkv"], index, cross=True)
            x = x + h.astype(x.dtype)
        if btype == "moe":
            h, _ = moe_mod.moe_ffn(cfg, p["moe"], rmsnorm(p["ln2"], x, eps))
        else:
            h = mlp(p["mlp"], rmsnorm(p["ln2"], x, eps))
        return x + h.astype(x.dtype), new_cache
    if btype == "mamba":
        h, st = ssm.mamba_decode(cfg, p["mamba"], rmsnorm(p["ln1"], x, eps),
                                 cache["state"])
        return x + h.astype(x.dtype), {"state": st}
    if btype == "mlstm":
        h, st = xlstm.mlstm_decode(cfg, p["mlstm"],
                                   rmsnorm(p["ln1"], x, eps),
                                   cache["state"])
        return x + h.astype(x.dtype), {"state": st}
    if btype == "slstm":
        h, st = xlstm.slstm_decode(cfg, p["slstm"],
                                   rmsnorm(p["ln1"], x, eps),
                                   cache["state"])
        return x + h.astype(x.dtype), {"state": st}
    raise ValueError(btype)
