"""Model assembly: embedding → scanned block stack → norm → logits.

The depth dimension is a lax.scan over `n_repeats` copies of the block
pattern (params stacked per pattern position), optionally rematerialized —
HLO size is independent of depth, which keeps 61-80 layer × 512-device
dry-run compiles tractable. Forward (train), prefill (build cache), and
decode (one token) all share the same scan skeleton.

Families: dense/moe/ssm/hybrid decoder-only LMs; vlm (stub patch-embedding
prefix + M-RoPE positions); audio (whisper-style encoder-decoder with stub
frame embeddings).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import sharding as shd
from repro.models.common import (ParamDef, embed, embed_def, is_def,
                                 rmsnorm, rmsnorm_def, unembed)

Tree = Any


def _stack_defs(defs: Tree, n: int) -> Tree:
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.logical,
                           init=d.init, axis=d.axis),
        defs, is_leaf=is_def)


def model_def(cfg: ModelConfig) -> Tree:
    d: dict = {"embed": embed_def(cfg.padded_vocab, cfg.d_model),
               "final_norm": rmsnorm_def(cfg.d_model)}
    if not cfg.tie_embeddings:
        d["unembed"] = {"tokens": ParamDef(
            (cfg.padded_vocab, cfg.d_model), ("vocab", "fsdp"),
            init="normal")}
    for pos, btype in enumerate(cfg.block_pattern):
        bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
        d[f"blocks_{pos}"] = _stack_defs(blk.block_def(cfg, bt),
                                         cfg.n_repeats)
    if cfg.is_encdec:
        d["enc_blocks"] = _stack_defs(blk.block_def(cfg, "attn"),
                                      cfg.encoder_layers)
        d["enc_norm"] = rmsnorm_def(cfg.d_model)
    return d


def _sinusoidal(S: int, D: int, dtype) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    i = np.arange(D // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / D)
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, dtype)


def _repeat_params(params, pattern):
    return {pos: params[f"blocks_{pos}"] for pos in range(len(pattern))}


def _scan_stack(cfg: ModelConfig, params, x, step_fn, cache=None):
    """Scan `step_fn(x, rep_params[, rep_cache])` over n_repeats."""
    rep_params = _repeat_params(params, cfg.block_pattern)
    body = step_fn
    if cfg.remat and cache is None:      # decode carries caches; no remat
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "nothing" else
                  jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        body = jax.checkpoint(body, policy=policy)
    unroll = cfg.n_repeats if cfg.scan_unroll else 1
    if cache is None:
        (x, aux), ys = jax.lax.scan(
            lambda carry, p: body(carry, p),
            (x, jnp.zeros((), jnp.float32)), rep_params, unroll=unroll)
        return x, aux, ys
    (x, aux), new_cache = jax.lax.scan(
        lambda carry, pc: body(carry, pc),
        (x, jnp.zeros((), jnp.float32)), (rep_params, cache),
        unroll=unroll)
    return x, aux, new_cache


def _encoder(cfg: ModelConfig, params, frames):
    """Whisper-style encoder over stub frame embeddings (B, S_enc, D)."""
    B, S, D = frames.shape
    x = frames + _sinusoidal(S, D, frames.dtype)[None]
    x = shd.act(x, ("batch", None, None))
    positions = jnp.arange(S)[None, :]

    def step(carry, p):
        x, aux = carry
        x, a = blk.block_apply(cfg, "attn", p, x, positions=positions,
                               causal=False)
        return (x, aux + a), 0.0

    rep = params["enc_blocks"]
    body = jax.checkpoint(step) if cfg.remat else step
    (x, _), _ = jax.lax.scan(
        lambda c, p: body(c, p), (x, jnp.zeros((), jnp.float32)), rep,
        unroll=cfg.encoder_layers if cfg.scan_unroll else 1)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _embed_inputs(cfg: ModelConfig, params, batch):
    """Token / multimodal embedding. Returns x, positions, positions3."""
    tokens = batch["tokens"]
    x = embed(params["embed"], tokens).astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    positions3 = batch.get("positions3")
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x],
                            axis=1)
    if not cfg.use_rope:
        x = x + _sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]
    positions = jnp.arange(x.shape[1])[None, :]
    x = shd.act(x, ("batch", None, None))
    return x, positions, positions3


def forward_hidden(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray,
                                                             jnp.ndarray]:
    """Forward up to the final norm: returns (hidden (B,S,D), aux_loss)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encoder(cfg, params, batch["frames"])
    x, positions, positions3 = _embed_inputs(cfg, params, batch)

    def step(carry, p):
        x, aux = carry
        for pos, btype in enumerate(cfg.block_pattern):
            bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
            x, a = blk.block_apply(cfg, bt, p[pos], x, positions=positions,
                                   positions3=positions3, enc_out=enc_out)
            aux = aux + a
        return (x, aux), 0.0

    x, aux, _ = _scan_stack(cfg, params, x, step)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def unembed_params(cfg: ModelConfig, params):
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def forward(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """Training/scoring forward: returns (logits f32, aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch)
    logits = unembed(unembed_params(cfg, params), x)
    logits = shd.act(logits, ("batch", None, "vocab"))
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> Tree:
    """Stacked (n_repeats leading axis) decode caches per pattern pos."""
    caches = {}
    for pos, btype in enumerate(cfg.block_pattern):
        bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
        one = blk.block_cache_init(cfg, bt, batch, s_max, dtype)
        caches[pos] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_repeats,) + a.shape),
            one)
    return caches


def abstract_cache(cfg: ModelConfig, batch: int, s_max: int,
                   dtype=jnp.bfloat16) -> Tree:
    """ShapeDtypeStruct cache (dry-run input spec — no allocation)."""
    caches = {}
    for pos, btype in enumerate(cfg.block_pattern):
        bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
        one = blk.block_cache_init(cfg, bt, 1, s_max, dtype)
        caches[pos] = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                (cfg.n_repeats, batch) + a.shape[1:], a.dtype), one)
    return caches


def prefill(cfg: ModelConfig, params, batch, s_max: int,
            cache_dtype=jnp.bfloat16):
    """Run the full prompt; returns (last-position logits, cache)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encoder(cfg, params, batch["frames"])
    x, positions, positions3 = _embed_inputs(cfg, params, batch)

    def step(carry, p):
        x, aux = carry
        caches = {}
        for pos, btype in enumerate(cfg.block_pattern):
            bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
            x, c = blk.block_prefill(cfg, bt, p[pos], x,
                                     positions=positions,
                                     positions3=positions3,
                                     enc_out=enc_out, s_max=s_max,
                                     cache_dtype=cache_dtype)
            caches[pos] = c
        return (x, aux), caches

    x, _, caches = _scan_stack(cfg, params, x, step)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(emb, x[:, -1:])
    return logits[:, 0, :cfg.vocab_size], caches


def decode_step(cfg: ModelConfig, params, tokens, cache, index,
                positions3=None):
    """One-token serve step. tokens: (B, 1). Returns (logits, new cache)."""
    x = embed(params["embed"], tokens).astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    if not cfg.use_rope:                  # absolute position at `index`
        D = cfg.d_model
        i = jnp.arange(D // 2, dtype=jnp.float32)
        ang = jnp.asarray(index, jnp.float32) / jnp.power(
            10000.0, 2 * i / D)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
        x = x + pe[None, None, :].astype(x.dtype)
    x = shd.act(x, ("batch", None, None))

    def step(carry, pc):
        x, aux = carry
        p, cache_r = pc
        new_caches = {}
        for pos, btype in enumerate(cfg.block_pattern):
            bt = "xattn" if (cfg.is_encdec and btype == "attn") else btype
            x, c = blk.block_decode(cfg, bt, p[pos], x, cache_r[pos], index,
                                    positions3=positions3)
            new_caches[pos] = c
        return (x, aux), new_caches

    x, _, new_cache = _scan_stack(cfg, params, x, step, cache=cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    emb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(emb, x)
    logits = shd.act(logits, ("batch", None, "vocab"))
    # drop vocab padding at the (tiny) decode output
    return logits[:, 0, :cfg.vocab_size], new_cache


def count_params(cfg: ModelConfig) -> int:
    from repro.models.common import n_params
    return n_params(model_def(cfg))


def count_active_params(cfg: ModelConfig) -> int:
    """Active per-token parameters (MoE: only routed experts count)."""
    total = count_params(cfg)
    if cfg.n_experts == 0:
        return total
    expert_params = 3 * cfg.d_model * cfg.d_expert     # gate/up/down
    inactive = (cfg.n_experts - cfg.experts_per_token) * expert_params
    n_moe_layers = sum(1 for b in cfg.layer_types() if b == "moe")
    return total - n_moe_layers * inactive
