"""GQA attention: query-chunked full attention + single-token decode.

Training / prefill use query-chunked attention (a lax.scan over query
blocks) so the (chunk, S) logit tile — not the full (S, S) matrix — is the
peak live activation; at 32k prefill this is the difference between an 8 GB
and a 256 MB transient per layer. Decode attends one query over the KV
cache with position masking.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.common import ParamDef
from repro.models.rope import apply_mrope, apply_rope

NEG_INF = -1e30


def attn_def(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    d = {
        "wq": ParamDef((D, H, hd), ("fsdp", "heads", None)),
        "wk": ParamDef((D, KV, hd), ("fsdp", "kv_heads", None)),
        "wv": ParamDef((D, KV, hd), ("fsdp", "kv_heads", None)),
        "wo": ParamDef((H, hd, D), ("heads", None, "fsdp"), axis=-3),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H, hd), ("heads", None), init="zeros")
        d["bk"] = ParamDef((KV, hd), ("kv_heads", None), init="zeros")
        d["bv"] = ParamDef((KV, hd), ("kv_heads", None), init="zeros")
    return d


def _project_qkv(cfg, p, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _sdpa(q, k, v, q_pos, k_valid_upto, causal, scale):
    """q: (B, C, KV, G, hd); k/v: (B, S, KV, hd); q_pos: (C,) absolute.

    k_valid_upto: mask keys at positions > this (decode: cache fill level);
    pass None for full validity.
    """
    B, S = k.shape[0], k.shape[1]
    logits = jnp.einsum("bckgh,bskh->bkgcs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    k_pos = jnp.arange(S)
    mask = jnp.ones((q.shape[1], S), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if k_valid_upto is not None:
        mask &= (k_pos[None, :] <= k_valid_upto)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", w, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attention_full(cfg: ModelConfig, p, x, positions, *, causal=True,
                   kv_x=None, positions3=None, return_kv=False):
    """Full-sequence attention (train / prefill). x: (B, S, D)."""
    B, S, D = x.shape
    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _project_qkv(cfg, p, x, kv_x)
    if kv_x is None and cfg.use_rope:      # self-attention -> RoPE
        if cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.rope_theta,
                            cfg.mrope_sections)
            k = apply_mrope(k, positions3, cfg.rope_theta,
                            cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    # TP when heads divide the model axis; otherwise fall back to sequence
    # parallelism on the query axis — without this, GSPMD replicates the
    # whole attention computation across the model axis (15-head smollm /
    # 12-head qwen on a 16-way mesh: ~an order of magnitude wasted FLOPs).
    mesh = shd.current_mesh()
    model_n = mesh.shape.get("model", 1) if mesh is not None else 1
    heads_shardable = cfg.n_heads % model_n == 0
    if heads_shardable:
        q = shd.act(q, ("batch", None, "heads", None))
    elif S % model_n == 0:
        q = shd.act(q, ("batch", "seq_sharded", None, None))
    k = shd.act(k, ("batch", None, "kv_heads", None))
    v = shd.act(v, ("batch", None, "kv_heads", None))
    scale = cfg.head_dim ** -0.5
    qg = q.reshape(B, S, KV, G, cfg.head_dim)

    C = min(cfg.attn_chunk, S)
    if S % C:
        C = S
    nC = S // C

    if nC == 1:
        out = _sdpa(qg, k, v, jnp.arange(S), None, causal, scale)
    else:
        qc = qg.reshape(B, nC, C, KV, G, cfg.head_dim)
        qc = jnp.moveaxis(qc, 1, 0)                  # (nC, B, C, KV, G, hd)

        def chunk_fn(carry, args):
            qi, i = args
            pos = i * C + jnp.arange(C)
            o = _sdpa(qi, k, v, pos, None, causal, scale)
            return carry, o

        _, outs = jax.lax.scan(chunk_fn, None, (qc, jnp.arange(nC)),
                               unroll=nC if cfg.scan_unroll else 1)
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, KV, G, cfg.head_dim)

    out = out.reshape(B, S, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return y, (k, v)
    return y


class KVCache(NamedTuple):
    k: jnp.ndarray    # (B, S_max, KV, hd)
    v: jnp.ndarray


def init_kv_cache(cfg: ModelConfig, batch: int, s_max: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention_decode(cfg: ModelConfig, p, x, cache: KVCache, index,
                     positions3=None, cross: bool = False):
    """One-token decode. x: (B, 1, D); index: scalar position of the new
    token. Cross-attention reads the (pre-filled) cache without updating."""
    B = x.shape[0]
    KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k_new, v_new = _project_qkv(cfg, p, x)
    pos = jnp.full((B, 1), index, dtype=jnp.int32)
    if not cross:
        if not cfg.use_rope:
            pass
        elif cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.rope_theta,
                            cfg.mrope_sections)
            k_new = apply_mrope(k_new, positions3, cfg.rope_theta,
                                cfg.mrope_sections)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k_new = apply_rope(k_new, pos, cfg.rope_theta)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), index, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), index, axis=1)
        cache = KVCache(k, v)
        valid_upto = index
    else:
        k, v = cache.k, cache.v
        valid_upto = None
    qg = q.reshape(B, 1, KV, G, cfg.head_dim)
    out = _sdpa(qg, k, v, pos[0], valid_upto, False, cfg.head_dim ** -0.5)
    out = out.reshape(B, 1, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, cache
