"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM's recurrence C_t = f_t·C_{t-1} + i_t·(v_t k_tᵀ), y_t = (C_t q_t) / nrm
maps directly onto the shared SSD core (ssm.ssd_chunked) with a = log f,
B = k, X = i·v, C = q; the normalizer n_t = f_t·n_{t-1} + i_t·k_t is the
same recurrence with P=1. Gates use sigmoid forget / sigmoid input (the
stabilized-exponential variant of the paper is noted as a simplification in
DESIGN.md — the recurrence structure and state shapes are identical).

sLSTM is inherently sequential (the paper's CUDA kernel is a fused
recurrence); here it is a lax.scan over time with per-head block-diagonal
recurrent weights and exponential-gate stabilization (m state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.common import ParamDef, rmsnorm
from repro.models.ssm import ssd_chunked, ssd_step


# -----------------------------------------------------------------------
# mLSTM
# -----------------------------------------------------------------------

class MlstmCache(NamedTuple):
    c: jnp.ndarray    # (B, H, N, P) matrix memory
    n: jnp.ndarray    # (B, H, N) normalizer


def _mlstm_dims(cfg: ModelConfig):
    D = cfg.d_model
    d_inner = int(cfg.mlstm_proj_factor * D)
    H = cfg.n_heads
    P = d_inner // H
    N = max(8, P // 2)                  # qk dim factor 0.5
    return D, d_inner, H, P, N


def mlstm_def(cfg: ModelConfig) -> dict:
    D, d_inner, H, P, N = _mlstm_dims(cfg)
    return {
        "w_up": ParamDef((D, H, P), ("fsdp", "heads", None)),
        "w_gate": ParamDef((D, H, P), ("fsdp", "heads", None)),
        "wq": ParamDef((D, H, N), ("fsdp", "heads", None)),
        "wk": ParamDef((D, H, N), ("fsdp", "heads", None)),
        "wi": ParamDef((D, H), ("fsdp", "heads")),
        "wf": ParamDef((D, H), ("fsdp", "heads")),
        "f_bias": ParamDef((H,), ("heads",), init="ones"),
        "norm": ParamDef((H, P), ("heads", None), init="ones"),
        "w_down": ParamDef((H, P, D), ("heads", None, "fsdp"), axis=-3),
    }


def _mlstm_gates(p, x):
    # TP: xlstm has only 4 heads, so the model axis shards the qk (N) and
    # value (P) feature dims instead — without this the whole mLSTM cell
    # would be replicated across the model axis.
    v = jnp.einsum("bsd,dhp->bshp", x, p["w_up"].astype(x.dtype))
    v = shd.act(v, ("batch", None, None, "mlp"))
    z = jnp.einsum("bsd,dhp->bshp", x, p["w_gate"].astype(x.dtype))
    z = shd.act(z, ("batch", None, None, "mlp"))
    q = jnp.einsum("bsd,dhn->bshn", x, p["wq"].astype(x.dtype))
    q = shd.act(q, ("batch", None, None, "mlp"))
    k = jnp.einsum("bsd,dhn->bshn", x, p["wk"].astype(x.dtype))
    k = shd.act(k, ("batch", None, None, "mlp"))
    i_raw = jnp.einsum("bsd,dh->bsh", x, p["wi"].astype(x.dtype))
    f_raw = jnp.einsum("bsd,dh->bsh", x, p["wf"].astype(x.dtype)) \
        + p["f_bias"].astype(x.dtype)
    i_g = jax.nn.sigmoid(i_raw.astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    return v, z, q, k, i_g, log_f


def mlstm_apply(cfg: ModelConfig, p, x, return_cache: bool = False):
    B_, S, D = x.shape
    _, d_inner, H, P, N = _mlstm_dims(cfg)
    v, z, q, k, i_g, log_f = _mlstm_gates(p, x)
    scale = N ** -0.5
    X = v.astype(jnp.float32) * i_g[..., None]
    y, cT = ssd_chunked(log_f, k * scale, X, q, cfg.ssm_chunk,
                        unroll=cfg.scan_unroll)
    # normalizer: same recurrence with X = i (P=1)
    ones = i_g[..., None]
    nrm, nT = ssd_chunked(log_f, k * scale, ones, q, cfg.ssm_chunk,
                          unroll=cfg.scan_unroll)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0).astype(y.dtype)
    y = rmsnorm({"scale": p["norm"].reshape(-1)},
                y.reshape(B_, S, H * P)).reshape(B_, S, H, P)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bshp,hpd->bsd", y, p["w_down"].astype(x.dtype))
    if not return_cache:
        return out
    return out, MlstmCache(c=cT, n=nT[..., 0])


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    _, _, H, P, N = _mlstm_dims(cfg)
    return MlstmCache(c=jnp.zeros((batch, H, N, P), jnp.float32),
                      n=jnp.zeros((batch, H, N), jnp.float32))


def mlstm_decode(cfg: ModelConfig, p, x, cache: MlstmCache):
    B_, _, D = x.shape
    _, d_inner, H, P, N = _mlstm_dims(cfg)
    v, z, q, k, i_g, log_f = _mlstm_gates(p, x)
    scale = N ** -0.5
    X = v[:, 0].astype(jnp.float32) * i_g[:, 0, :, None]
    y, c = ssd_step(cache.c, log_f[:, 0], k[:, 0] * scale, X, q[:, 0])
    n = cache.n * jnp.exp(log_f[:, 0])[..., None] \
        + (k[:, 0] * scale).astype(jnp.float32) * i_g[:, 0, :, None]
    nrm = jnp.einsum("bhn,bhn->bh", q[:, 0].astype(jnp.float32), n)
    y = y / jnp.maximum(jnp.abs(nrm), 1.0)[..., None].astype(y.dtype)
    y = rmsnorm({"scale": p["norm"].reshape(-1)},
                y.reshape(B_, 1, H * P)).reshape(B_, H, P)
    y = y * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bhp,hpd->bd", y, p["w_down"].astype(x.dtype))
    return out[:, None, :], MlstmCache(c=c, n=n)


# -----------------------------------------------------------------------
# sLSTM
# -----------------------------------------------------------------------

class SlstmCache(NamedTuple):
    c: jnp.ndarray    # (B, H, P)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray    # exponential-gate stabilizer


def _slstm_dims(cfg: ModelConfig):
    D = cfg.d_model
    H = cfg.n_heads
    P = D // H
    return D, H, P


def slstm_def(cfg: ModelConfig) -> dict:
    D, H, P = _slstm_dims(cfg)
    d = {}
    for g in ("z", "i", "f", "o"):
        d[f"w{g}"] = ParamDef((D, H, P), ("fsdp", "heads", None))
        d[f"r{g}"] = ParamDef((H, P, P), ("heads", None, None), axis=-2)
        d[f"b{g}"] = ParamDef((H, P), ("heads", None), init="zeros")
    # post-FFN (factor 4/3 per the xLSTM paper)
    F = int(D * 4 / 3)
    d["ffn_up"] = ParamDef((D, F), ("fsdp", "mlp"))
    d["ffn_down"] = ParamDef((F, D), ("mlp", "fsdp"))
    return d


def _slstm_cell(p, xg, state: SlstmCache):
    """One step. xg: dict gate -> (B, H, P) pre-activations from input."""
    c, n, h, m = state
    pre = {g: xg[g] + jnp.einsum("bhp,hpq->bhq", h,
                                 p[f"r{g}"].astype(h.dtype))
           for g in ("z", "i", "f", "o")}
    z = jnp.tanh(pre["z"].astype(jnp.float32))
    o = jax.nn.sigmoid(pre["o"].astype(jnp.float32))
    log_i = pre["i"].astype(jnp.float32)                 # exponential gate
    log_f = jax.nn.log_sigmoid(pre["f"].astype(jnp.float32))
    m_new = jnp.maximum(log_f + m, log_i)                # stabilizer
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = o * c_new / n_new
    return SlstmCache(c_new, n_new, h_new.astype(h.dtype), m_new)


def slstm_apply(cfg: ModelConfig, p, x, return_cache: bool = False):
    B_, S, D = x.shape
    D, H, P = _slstm_dims(cfg)
    xg = {g: jnp.einsum("bsd,dhp->bshp", x, p[f"w{g}"].astype(x.dtype))
          + p[f"b{g}"].astype(x.dtype) for g in ("z", "i", "f", "o")}
    state = SlstmCache(
        c=jnp.zeros((B_, H, P), jnp.float32),
        n=jnp.ones((B_, H, P), jnp.float32),
        h=jnp.zeros((B_, H, P), x.dtype),
        m=jnp.zeros((B_, H, P), jnp.float32))

    def step(st, xs):
        st = _slstm_cell(p, {g: xs[gi] for gi, g in
                             enumerate(("z", "i", "f", "o"))}, st)
        return st, st.h

    xs = jnp.stack([jnp.moveaxis(xg[g], 1, 0)
                    for g in ("z", "i", "f", "o")], axis=1)  # (S,4,B,H,P)
    state, hs = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(hs, 0, 1).reshape(B_, S, D)
    # post-FFN
    f = jax.nn.gelu(jnp.einsum(
        "bsd,df->bsf", y, p["ffn_up"].astype(x.dtype)))
    out = jnp.einsum("bsf,fd->bsd", f, p["ffn_down"].astype(x.dtype))
    if not return_cache:
        return out
    return out, state


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    D, H, P = _slstm_dims(cfg)
    return SlstmCache(
        c=jnp.zeros((batch, H, P), jnp.float32),
        n=jnp.ones((batch, H, P), jnp.float32),
        h=jnp.zeros((batch, H, P), dtype),
        m=jnp.zeros((batch, H, P), jnp.float32))


def slstm_decode(cfg: ModelConfig, p, x, cache: SlstmCache):
    B_ = x.shape[0]
    xg = {g: jnp.einsum("bd,dhp->bhp", x[:, 0], p[f"w{g}"].astype(x.dtype))
          + p[f"b{g}"].astype(x.dtype) for g in ("z", "i", "f", "o")}
    cache = _slstm_cell(p, xg, cache)
    D, H, P = _slstm_dims(cfg)
    y = cache.h.reshape(B_, 1, D)
    f = jax.nn.gelu(jnp.einsum(
        "bsd,df->bsf", y, p["ffn_up"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", f, p["ffn_down"].astype(x.dtype)), cache
