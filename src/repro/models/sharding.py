"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Params and activations are annotated with *logical* axis names; a rule table
maps them to mesh axes. Any mapping whose dimension size is not divisible by
the mesh-axis product is dropped (e.g. 8 KV heads cannot shard over a
16-way model axis → replicated), so one rule table serves every
architecture × mesh combination.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (in priority order)
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),       # DP
    "fsdp": ("pod", "data"),        # param/optimizer ZeRO-3 axis
    "heads": ("model",),            # TP
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),           # EP over the TP axis
    "expert_dp": ("data",),         # EP over the data axis (weights stay
                                    # put; token all-to-all — 1T-class MoE)
    "vocab": ("model",),
    "seq_sharded": ("model",),      # SP for long-context KV caches
    "seq_full": ("data", "model"),  # SP when batch cannot shard (B=1)
    # unsharded logicals
    "layers": (), "seq": (), "embed_act": (), "head_dim": (), "state": (),
    "embed": (), "conv": (), "capacity": (), "any": (),
}


def _mesh_axes(mesh: Mesh, names: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(n for n in names if n in mesh.shape)


def spec_for(mesh: Mesh, logical: Sequence[str | None],
             dims: Sequence[int] | None = None) -> P:
    """PartitionSpec for logical axes, dropping non-divisible mappings and
    deduplicating mesh axes across dims (first dim wins)."""
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        axes = tuple(a for a in _mesh_axes(mesh, RULES.get(name, ()))
                     if a not in used)
        if not axes:
            out.append(None)
            continue
        size = dims[i] if dims is not None else None
        if size is not None:
            shard = 1
            for a in axes:
                shard *= mesh.shape[a]
            if size % shard:
                # try progressively fewer axes (suffix first)
                ok = None
                for k in range(len(axes) - 1, 0, -1):
                    s = 1
                    for a in axes[:k]:
                        s *= mesh.shape[a]
                    if size % s == 0:
                        ok = axes[:k]
                        break
                axes = ok or ()
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
            used.add(axes[0])
        else:
            out.append(tuple(axes))
            used.update(axes)
    return P(*out)


def sharding_for(mesh: Mesh, logical: Sequence[str | None],
                 dims: Sequence[int] | None = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, logical, dims))


def constrain(x, mesh: Mesh, logical: Sequence[str | None]):
    """with_sharding_constraint by logical names (activations)."""
    return jax.lax.with_sharding_constraint(
        x, sharding_for(mesh, logical, x.shape))


_MESH_CTX: list[Mesh | None] = [None]


class use_mesh:
    """Context manager: activation constraints apply under this mesh.

    Model code calls `act(x, logical)` unconditionally; without an active
    mesh (CPU smoke tests) it is a no-op, under the production mesh it
    becomes with_sharding_constraint — same model code for both paths.
    """

    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh

    def __enter__(self):
        _MESH_CTX.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _MESH_CTX.pop()


def current_mesh() -> Mesh | None:
    return _MESH_CTX[-1]


def act(x, logical: Sequence[str | None]):
    """Constrain an activation by logical axes (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(mesh, logical, x.shape))


def tree_shardings(mesh: Mesh, logical_tree, shape_tree):
    """Map a pytree of logical-axis tuples + shapes -> NamedShardings."""
    return jax.tree.map(
        lambda log, shp: sharding_for(mesh, log, shp.shape),
        logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
