"""Mixture-of-Experts with ALTO-linearized sorted dispatch.

This is where the paper's technique is a first-class feature of the LM
stack: the (token, expert) routing assignment is a sparse rank-2 tensor,
and we dispatch it exactly the way ALTO executes an output-oriented
traversal (paper §4.2):

  1. linearize each routing pair to a single integer key with the expert
     bits above the token bits (expert-major — the "output mode" here is
     the expert, since the conflicting resource is the per-expert buffer);
  2. sort by the linearized key (one radix-friendly 1-D sort instead of a
     2-D lexsort — same argument as paper Fig. 13's generation-cost win);
  3. runs of equal expert id become contiguous segments; each token's slot
     is its rank within the segment (the balanced-partition capacity
     bucket), conflict-free by construction.

Experts are EP-sharded over the model axis; the scatter/gather between the
token-sharded and expert-sharded layouts is GSPMD's all-to-all. Tokens past
an expert's capacity are dropped (weight renormalized), standard for
capacity-bucketed MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import sharding as shd
from repro.models.common import ParamDef, swiglu


def moe_def(cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert
    # EP axis choice: "model" (default) or "data" (weights fully resident,
    # token all-to-all — the right trade for 1T-class expert stacks where
    # FSDP would re-gather expert weights every microbatch). The hidden
    # (F) axis also maps to model so MoE compute shards even when the
    # expert count is indivisible (granite's 40 experts on a 16-way axis).
    ep = "expert_dp" if cfg.moe_ep_axis == "data" else "expert"
    return {
        "router": ParamDef((D, E), ("fsdp", None)),
        "w_gate": ParamDef((E, D, F), (ep, "fsdp", "mlp"), axis=-2),
        "w_up": ParamDef((E, D, F), (ep, "fsdp", "mlp"), axis=-2),
        "w_down": ParamDef((E, F, D), (ep, "mlp", "fsdp"), axis=-2),
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.experts_per_token * n_tokens / cfg.n_experts
            * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)          # pad to sublane multiple


def _alto_sort_dispatch(expert_ids, n_experts, n_tokens):
    """ALTO-style linearized sort of (expert, token) pairs.

    expert_ids: (T*k,) int32. Returns (order, slot, seg_expert) where
    `order` sorts pairs expert-major, `slot` is the rank of each sorted
    pair within its expert segment (capacity bucket index).
    """
    tk = expert_ids.shape[0]
    pair_bits = max(1, (tk - 1).bit_length())
    if pair_bits + max(1, (n_experts - 1).bit_length()) > 32:
        raise ValueError("linearized routing key exceeds 32 bits")
    # bit-level gather: expert bits above pair-index bits — one linear key
    key = (expert_ids.astype(jnp.uint32) << pair_bits) | jnp.arange(
        tk, dtype=jnp.uint32)
    order = jnp.argsort(key)                       # expert-major run order
    sorted_e = jnp.take(expert_ids, order)
    # rank within segment: position minus index of the segment start
    idx = jnp.arange(tk)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0))
    slot = idx - seg_start
    return order, slot, sorted_e


def _dispatch_row(cfg: ModelConfig, x_row, top_e, top_p, C: int,
                  alto: bool):
    """Per-batch-row dispatch/combine index computation.

    x_row: (S, D); top_e/top_p: (S, K). Returns (buf (E,C,D) one-hot
    scattered inputs, combine indices). Runs under vmap over the batch
    dim, so the ALTO sort is LOCAL to each data shard — the cross-device
    movement is only the (batch → expert)-sharded einsum that GSPMD lowers
    to an all-to-all, never a replicated global sort.
    """
    S, D = x_row.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    flat_e = top_e.reshape(-1).astype(jnp.int32)          # (S*K,)
    flat_w = top_p.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)

    if alto:
        order, slot, seg_e = _alto_sort_dispatch(flat_e, E, S)
        tok = jnp.take(flat_t, order)
        w = jnp.take(flat_w, order)
        e = seg_e
    else:  # reference path: per-expert cumulative counts without sorting
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = (jnp.cumsum(onehot, axis=0) - 1)[
            jnp.arange(flat_e.shape[0]), flat_e]
        tok, w, e = flat_t, flat_w, flat_e

    keep = slot < C
    slot_c = jnp.where(keep, slot, 0)
    buf = jnp.zeros((E, C, D), x_row.dtype)
    upd = jnp.where(keep[:, None], jnp.take(x_row, tok, axis=0), 0.0)
    buf = buf.at[e, slot_c].add(upd.astype(x_row.dtype))
    return buf, (tok, w, e, slot_c, keep)


def _combine_row(y_row, idx, S: int):
    """y_row: (E, C, D) expert outputs -> (S, D) weighted combine."""
    tok, w, e, slot_c, keep = idx
    D = y_row.shape[-1]
    out_rows = y_row[e, slot_c] * (w * keep)[:, None].astype(y_row.dtype)
    return jnp.zeros((S, D), y_row.dtype).at[tok].add(out_rows)


def moe_ffn(cfg: ModelConfig, p, x, rngs=None):
    """x: (B, S, D) -> (B, S, D), plus router aux loss (load balancing)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)               # (B, S, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch): E * <f_e, p_e>
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    C = _capacity(cfg, S)                                 # per-row buckets
    buf, idx = jax.vmap(
        lambda xr, te, tp: _dispatch_row(cfg, xr, te, tp, C,
                                         cfg.moe_alto_dispatch))(
        x, top_e, top_p)                                  # (B, E, C, D)
    ep = "expert_dp" if cfg.moe_ep_axis == "data" else "expert"
    buf_spec = ((None, ep, None, None) if ep == "expert_dp"
                else ("batch", ep, None, None))           # a2a over data
    buf = shd.act(buf, buf_spec)

    h = swiglu(
        jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype)),
        jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype)))
    h = shd.act(h, buf_spec[:3] + ("mlp",))
    y = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(x.dtype))
    y = shd.act(y, buf_spec)

    out = jax.vmap(lambda yr, ix: _combine_row(yr, ix, S))(y, idx)
    return out, aux
