"""Synthetic sparse tensor generators.

Real FROSTT tensors (Table 1 of the paper) are multi-GB downloads; for an
offline container we generate tensors with the *distributional properties*
the paper's evaluation stresses:

  * ``uniform``  — i.i.d. coordinates: hyper-sparse, limited fiber reuse
                   (DARPA/FB-M-like behaviour).
  * ``zipf``     — power-law skewed coordinates: few hot fibers carry most
                   nonzeros, high fiber reuse (UBER/CHICAGO/ENRON-like).
  * ``blocked``  — nonzeros clustered into random dense-ish blocks
                   (the regime where HiCOO-style tiling wins).
  * ``lowrank_count`` — Poisson counts drawn from a planted rank-R CP model
                   (ground truth for CP-APR recovery tests).
  * ``lowrank_gaussian`` — planted rank-R CP model + noise (CP-ALS tests).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.sparse.tensor import SparseTensor, from_dense


def _dedup(dims, coords, values) -> SparseTensor:
    return SparseTensor(tuple(dims), coords, values).deduplicate()


def uniform_tensor(dims: Sequence[int], nnz: int, seed: int = 0,
                   count_data: bool = False) -> SparseTensor:
    rng = np.random.default_rng(seed)
    coords = np.stack([rng.integers(0, I, size=nnz) for I in dims],
                      axis=1).astype(np.int32)
    if count_data:
        values = rng.integers(1, 10, size=nnz).astype(np.float32)
    else:
        values = rng.standard_normal(nnz).astype(np.float32)
    return _dedup(dims, coords, values)


def zipf_tensor(dims: Sequence[int], nnz: int, a: float = 1.4,
                seed: int = 0, count_data: bool = False) -> SparseTensor:
    """Skewed coordinates: mode-n index ~ truncated Zipf(a)."""
    rng = np.random.default_rng(seed)
    cols = []
    for I in dims:
        # Inverse-CDF sampling of a truncated zipf to stay in [0, I).
        ranks = rng.zipf(a, size=nnz)
        cols.append(((ranks - 1) % I).astype(np.int32))
        # Random per-mode permutation so hot indices differ between modes.
        perm = rng.permutation(I).astype(np.int32)
        cols[-1] = perm[cols[-1]]
    coords = np.stack(cols, axis=1)
    if count_data:
        values = rng.integers(1, 20, size=nnz).astype(np.float32)
    else:
        values = rng.standard_normal(nnz).astype(np.float32)
    return _dedup(dims, coords, values)


def blocked_tensor(dims: Sequence[int], nnz: int, block: int = 8,
                   n_blocks: int = 64, seed: int = 0,
                   count_data: bool = False) -> SparseTensor:
    """Nonzeros clustered in `n_blocks` random multi-dimensional blocks.
    Dense-ish blocks -> high fiber reuse along every mode (the regime
    where the paper's recursive traversal wins)."""
    rng = np.random.default_rng(seed)
    base = np.stack(
        [rng.integers(0, max(1, I - block), size=n_blocks) for I in dims],
        axis=1)
    which = rng.integers(0, n_blocks, size=nnz)
    offs = np.stack([rng.integers(0, min(block, I), size=nnz) for I in dims],
                    axis=1)
    coords = (base[which] + offs).astype(np.int32)
    if count_data:
        values = rng.integers(1, 15, size=nnz).astype(np.float32)
    else:
        values = rng.standard_normal(nnz).astype(np.float32)
    return _dedup(dims, coords, values)


def lowrank_factors(dims: Sequence[int], rank: int, seed: int = 0,
                    nonneg: bool = False) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    fs = []
    for I in dims:
        A = rng.standard_normal((I, rank)).astype(np.float32)
        if nonneg:
            A = np.abs(A)
        fs.append(A)
    return fs


def lowrank_gaussian(dims: Sequence[int], rank: int, nnz: int,
                     noise: float = 0.01, seed: int = 0) -> tuple[
                         SparseTensor, list[np.ndarray]]:
    """Sample nnz coordinates; values from a planted rank-R model + noise."""
    rng = np.random.default_rng(seed)
    factors = lowrank_factors(dims, rank, seed=seed + 1)
    coords = np.stack([rng.integers(0, I, size=nnz) for I in dims],
                      axis=1).astype(np.int32)
    vals = np.ones(nnz, dtype=np.float32)
    prod = np.ones((nnz, rank), dtype=np.float32)
    for n, A in enumerate(factors):
        prod *= A[coords[:, n]]
    vals = prod.sum(axis=1) + noise * rng.standard_normal(nnz).astype(
        np.float32)
    return _dedup(dims, coords, vals), factors


def sparse_lowrank(dims: Sequence[int], rank: int, col_support: float = 0.2,
                   noise: float = 0.0, seed: int = 0,
                   nonneg: bool = False) -> tuple[SparseTensor,
                                                  list[np.ndarray]]:
    """An *exactly* low-rank sparse tensor: factors have sparse columns, so
    the full tensor (zeros included) is rank-R and sparse. Ground truth for
    CP-ALS recovery tests. Small dims only (builds a dense intermediate)."""
    rng = np.random.default_rng(seed)
    factors = []
    for I in dims:
        A = rng.standard_normal((I, rank)).astype(np.float32)
        if nonneg:
            A = np.abs(A)
        keep = rng.random((I, rank)) < col_support
        # ensure every column keeps at least one entry
        for r in range(rank):
            if not keep[:, r].any():
                keep[rng.integers(0, I), r] = True
        factors.append(A * keep)
    letters = "abcdefgh"[:len(dims)]
    expr = ",".join(f"{c}r" for c in letters) + "->" + letters
    dense = np.einsum(expr, *factors)
    if noise:
        mask = dense != 0
        dense = dense + noise * mask * rng.standard_normal(
            dense.shape).astype(np.float32)
    x = from_dense(dense.astype(np.float32))
    return x, factors


def lowrank_count(dims: Sequence[int], rank: int, nnz_target: int,
                  scale: float = 2.0, seed: int = 0) -> tuple[
                      SparseTensor, list[np.ndarray]]:
    """Poisson counts from a planted non-negative CP model (CP-APR oracle).

    Samples candidate coordinates and draws Poisson(rate); keeps positives.
    """
    rng = np.random.default_rng(seed)
    factors = lowrank_factors(dims, rank, seed=seed + 1, nonneg=True)
    n_cand = nnz_target * 3
    coords = np.stack([rng.integers(0, I, size=n_cand) for I in dims],
                      axis=1).astype(np.int32)
    prod = np.ones((n_cand, rank), dtype=np.float32)
    for n, A in enumerate(factors):
        prod *= A[coords[:, n]]
    rate = scale * prod.sum(axis=1)
    counts = rng.poisson(np.maximum(rate, 0.0)).astype(np.float32)
    keep = counts > 0
    return _dedup(dims, coords[keep], counts[keep]), factors


PAPER_LIKE = {
    # name: (builder, kwargs) — small-scale stand-ins for the Table 1
    # fiber-reuse regimes (class in comment = min-mode reuse class).
    "uber_like": (blocked_tensor, dict(                    # high reuse
        dims=(183, 24, 1024, 1536), nnz=260_000, block=12, n_blocks=8,
        count_data=True)),
    "chicago_like": (blocked_tensor, dict(                 # limited/medium
        dims=(1024, 24, 77, 32), nnz=120_000, block=16, n_blocks=10,
        count_data=True)),
    "darpa_like": (uniform_tensor, dict(                   # limited reuse
        dims=(2048, 2048, 65536), nnz=50_000, count_data=True)),
    "nell2_like": (blocked_tensor, dict(                   # high reuse
        dims=(2048, 1024, 4096), nnz=140_000, block=24, n_blocks=16)),
    "fbm_like": (uniform_tensor, dict(                     # limited reuse
        dims=(65536, 65536, 166), nnz=60_000)),
    "enron_like": (blocked_tensor, dict(                   # high reuse
        dims=(1024, 1024, 8192, 512), nnz=300_000, block=12, n_blocks=10,
        count_data=True)),
    "deli_like": (blocked_tensor, dict(                    # limited/medium
        dims=(4096, 2048, 1024, 64), nnz=100_000, block=16, n_blocks=40)),
}


def paper_like(name: str, seed: int = 0) -> SparseTensor:
    builder, kw = PAPER_LIKE[name]
    return builder(seed=seed, **kw)
