from repro.sparse.tensor import SparseTensor, from_dense
from repro.sparse import synthetic
from repro.sparse.io import read_tns, write_tns

__all__ = ["SparseTensor", "from_dense", "synthetic", "read_tns",
           "write_tns"]
