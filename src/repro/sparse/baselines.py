"""Baseline sparse tensor formats the paper compares against (§2.3):

  * HiCOO  — block-based hierarchical COO (Li et al. [18]): nonzeros
    sorted by multi-dimensional block key; per-block coordinates split
    into (block index, element offset) with small offset types.
  * CSF    — compressed sparse fiber (SPLATT [20]): a fiber tree per mode
    order; MTTKRP is the classic bottom-up traversal, expressed here as a
    chain of sorted segment reductions (the TPU-native equivalent of the
    per-subtree accumulation).

Both exist to make Fig. 9 (MTTKRP across formats) and Fig. 12 (storage)
honest head-to-heads inside one runtime, and to document *why* the
mode-agnostic single-copy ALTO wins: CSF needs one tree per mode for
conflict-free updates; HiCOO's compression and balance depend on the
block occupancy of the data.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.tensor import SparseTensor


# ---------------------------------------------------------------------------
# HiCOO
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HiCooTensor:
    dims: tuple[int, ...]
    block_bits: int
    bptr: np.ndarray          # (n_blocks + 1,) int64 — nnz range per block
    bcoords: np.ndarray       # (n_blocks, N) int32 — block indices
    ecoords: np.ndarray       # (M, N) uint8 — element offsets in block
    values: jnp.ndarray       # (M,)
    blk_of_nnz: jnp.ndarray   # (M,) int32 — owning block per nonzero

    @property
    def nnz(self) -> int:
        return self.ecoords.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.bcoords.shape[0]

    def storage_bytes(self) -> int:
        """Paper Fig. 12 accounting: bptr 8B/block, bi 4B/mode/block,
        ei 1B/mode/nnz, values 4B."""
        N = len(self.dims)
        return (8 * (self.n_blocks + 1) + 4 * N * self.n_blocks
                + 1 * N * self.nnz + 4 * self.nnz)


def build_hicoo(x: SparseTensor, block_bits: int = 7) -> HiCooTensor:
    """Sort by block key, split coords into (block, offset) (Fig. 3b)."""
    b = (x.coords >> block_bits).astype(np.int64)
    e = (x.coords & ((1 << block_bits) - 1)).astype(np.uint8)
    order = np.lexsort(tuple(b[:, n] for n in range(x.ndim - 1, -1, -1)))
    b, e, v = b[order], e[order], np.asarray(x.values)[order]
    new_blk = np.any(b[1:] != b[:-1], axis=1)
    starts = np.concatenate([[0], np.nonzero(new_blk)[0] + 1])
    bptr = np.concatenate([starts, [x.nnz]]).astype(np.int64)
    blk_id = np.cumsum(np.concatenate([[0], new_blk.astype(np.int64)]))
    return HiCooTensor(dims=x.dims, block_bits=block_bits, bptr=bptr,
                       bcoords=b[starts].astype(np.int32), ecoords=e,
                       values=jnp.asarray(v),
                       blk_of_nnz=jnp.asarray(blk_id.astype(np.int32)))


def hicoo_coords(h: HiCooTensor) -> jnp.ndarray:
    """Reconstruct full coordinates (block << bits | offset)."""
    b = jnp.asarray(h.bcoords)[h.blk_of_nnz]
    return ((b << h.block_bits)
            | jnp.asarray(h.ecoords.astype(np.int32))).astype(jnp.int32)


def mttkrp_hicoo(h: HiCooTensor, factors: Sequence[jnp.ndarray],
                 mode: int) -> jnp.ndarray:
    """HiCOO MTTKRP: delinearize block+offset, scatter-add (block-sorted
    order gives the cache locality on CPU; on TPU it is a scatter like
    COO — which is the paper's point about block formats)."""
    coords = hicoo_coords(h)
    out = None
    for m, A in enumerate(factors):
        if m == mode:
            continue
        rows = A[coords[:, m]]
        out = rows if out is None else out * rows
    contrib = h.values[:, None] * out
    res = jnp.zeros((factors[mode].shape[0], contrib.shape[-1]),
                    contrib.dtype)
    return res.at[coords[:, mode]].add(contrib)


# ---------------------------------------------------------------------------
# CSF
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CsfTensor:
    """One fiber tree for a given mode order (root first)."""
    dims: tuple[int, ...]
    mode_order: tuple[int, ...]        # e.g. (1, 0, 2): root mode first
    fids: list[np.ndarray]             # per level: node ids (mode index)
    parent: list[np.ndarray]           # per level>0: parent node position
    values: jnp.ndarray                # (M,) leaf values (sorted)

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    def storage_bytes(self) -> int:
        """fids 4B/node + parent ptr 4B/node + values 4B/nnz (a SPLATT
        fptr-style layout lower bound)."""
        total = 4 * self.nnz
        for lvl in range(len(self.fids)):
            total += 4 * len(self.fids[lvl])
            if lvl > 0:
                total += 4 * len(self.parent[lvl])
        return total


def build_csf(x: SparseTensor, root: int = 0) -> CsfTensor:
    order = (root,) + tuple(m for m in range(x.ndim) if m != root)
    c = x.coords[:, order]
    perm = np.lexsort(tuple(c[:, n] for n in range(x.ndim - 1, -1, -1)))
    c = c[perm]
    v = np.asarray(x.values)[perm]
    N = x.ndim
    fids, parent = [], []
    prev_node_of_row = None                 # node position per nnz row
    for lvl in range(N):
        prefix = c[:, :lvl + 1]
        new = np.ones(len(c), bool)
        new[1:] = np.any(prefix[1:] != prefix[:-1], axis=1)
        node_of_row = np.cumsum(new) - 1
        starts = np.nonzero(new)[0]
        fids.append(c[starts, lvl].astype(np.int32))
        if lvl == 0:
            parent.append(np.zeros(0, np.int32))
        else:
            parent.append(prev_node_of_row[starts].astype(np.int32))
        prev_node_of_row = node_of_row
    return CsfTensor(dims=x.dims, mode_order=order, fids=fids,
                     parent=parent, values=jnp.asarray(v))


def mttkrp_csf_root(t: CsfTensor, factors: Sequence[jnp.ndarray]
                    ) -> jnp.ndarray:
    """Root-mode MTTKRP: bottom-up traversal (paper §2.3.3) as a chain of
    sorted segment sums. Conflict-free per subtree — the reason CSF needs
    one tree copy per mode."""
    N = len(t.dims)
    R = factors[0].shape[1]
    # leaves: val * A^(leaf mode) rows
    leaf_mode = t.mode_order[-1]
    cur = t.values[:, None] * factors[leaf_mode][jnp.asarray(t.fids[-1])]
    # fold up: at each internal level, segment-sum children then multiply
    # by that level's factor rows
    for lvl in range(N - 2, 0, -1):
        seg = jnp.asarray(t.parent[lvl + 1])
        cur = jax.ops.segment_sum(cur, seg,
                                  num_segments=len(t.fids[lvl]),
                                  indices_are_sorted=True)
        m = t.mode_order[lvl]
        cur = cur * factors[m][jnp.asarray(t.fids[lvl])]
    seg = jnp.asarray(t.parent[1])
    cur = jax.ops.segment_sum(cur, seg, num_segments=len(t.fids[0]),
                              indices_are_sorted=True)
    root = t.mode_order[0]
    out = jnp.zeros((t.dims[root], R), cur.dtype)
    return out.at[jnp.asarray(t.fids[0])].set(cur)


class CsfAll:
    """The paper's 'SPLATT-ALL' configuration: N tree copies, best speed,
    N× the storage (Fig. 12's mode-specific cost)."""

    def __init__(self, x: SparseTensor):
        self.trees = [build_csf(x, root=m) for m in range(x.ndim)]

    def mttkrp(self, factors, mode: int) -> jnp.ndarray:
        return mttkrp_csf_root(self.trees[mode], factors)

    def storage_bytes(self) -> int:
        return sum(t.storage_bytes() for t in self.trees)
