"""Sparse tensor substrate: COO container + dense conversions.

The COO form is the paper's baseline format (Fig. 3a) and the input to ALTO
format generation. Coordinates are kept as int32 (every assigned data set has
mode lengths < 2**31); values default to float32 (float64 works when
jax_enable_x64 is on).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """A mode-N sparse tensor in list-of-nonzeros (COO) form.

    Attributes:
      dims:   static mode lengths (I_1, ..., I_N).
      coords: (M, N) int32 multi-dimensional indices.
      values: (M,) float values.
    """

    dims: tuple[int, ...]
    coords: np.ndarray
    values: np.ndarray

    def __post_init__(self):
        coords = np.asarray(self.coords, dtype=np.int32)
        values = np.asarray(self.values)
        if coords.ndim != 2 or coords.shape[1] != len(self.dims):
            raise ValueError(
                f"coords shape {coords.shape} does not match dims {self.dims}")
        if values.shape != (coords.shape[0],):
            raise ValueError(
                f"values shape {values.shape} != ({coords.shape[0]},)")
        for n, I in enumerate(self.dims):
            if coords.shape[0] and (coords[:, n].min() < 0
                                    or coords[:, n].max() >= I):
                raise ValueError(f"mode-{n} coordinates out of range [0,{I})")
        object.__setattr__(self, "coords", coords)
        object.__setattr__(self, "values", values)

    @property
    def nnz(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def density(self) -> float:
        total = float(np.prod([float(d) for d in self.dims]))
        return self.nnz / total if total else 0.0

    def todense(self) -> np.ndarray:
        """Dense ndarray (small tensors / test oracles only)."""
        out = np.zeros(self.dims, dtype=self.values.dtype)
        # += via np.add.at to honour duplicate coordinates like scatter-add.
        np.add.at(out, tuple(self.coords[:, n] for n in range(self.ndim)),
                  self.values)
        return out

    def deduplicate(self) -> "SparseTensor":
        """Sum values of duplicate coordinates (canonicalisation)."""
        order = np.lexsort(tuple(self.coords[:, n]
                                 for n in range(self.ndim - 1, -1, -1)))
        c = self.coords[order]
        v = self.values[order]
        if c.shape[0] == 0:
            return self
        new_run = np.any(c[1:] != c[:-1], axis=1)
        starts = np.concatenate([[0], np.nonzero(new_run)[0] + 1])
        seg_id = np.cumsum(np.concatenate([[0], new_run.astype(np.int64)]))
        sums = np.zeros(len(starts), dtype=v.dtype)
        np.add.at(sums, seg_id, v)
        return SparseTensor(self.dims, c[starts], sums)

    def permute_modes(self, perm: Sequence[int]) -> "SparseTensor":
        perm = list(perm)
        return SparseTensor(tuple(self.dims[p] for p in perm),
                            self.coords[:, perm], self.values)


def from_dense(arr: np.ndarray) -> SparseTensor:
    coords = np.argwhere(arr != 0).astype(np.int32)
    values = arr[tuple(coords[:, n] for n in range(arr.ndim))]
    return SparseTensor(tuple(arr.shape), coords, values)
