"""FROSTT ``.tns`` sparse-tensor text format reader/writer.

Format: one nonzero per line, 1-based coordinates followed by the value:
``i_1 i_2 ... i_N v``. Lines beginning with ``#`` are comments.
"""
from __future__ import annotations

import numpy as np

from repro.sparse.tensor import SparseTensor


def read_tns(path: str, dims: tuple[int, ...] | None = None) -> SparseTensor:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            rows.append([float(t) for t in line.split()])
    if not rows:
        raise ValueError(f"{path}: empty tensor file")
    arr = np.asarray(rows)
    coords = arr[:, :-1].astype(np.int64) - 1  # 1-based -> 0-based
    values = arr[:, -1].astype(np.float32)
    if dims is None:
        dims = tuple(int(coords[:, n].max()) + 1
                     for n in range(coords.shape[1]))
    return SparseTensor(dims, coords.astype(np.int32), values)


def write_tns(path: str, x: SparseTensor) -> None:
    with open(path, "w") as f:
        for c, v in zip(x.coords, x.values):
            f.write(" ".join(str(int(i) + 1) for i in c) + f" {float(v)}\n")
