"""Batched CP-ALS / CP-APR: one executable sweeps a whole shape class.

Tenants that :func:`shapeclass.classify` buckets into the same class
share an `AltoEncoding`, a padded stream length, and a canonical
`AltoMeta` — so their `AltoTensor` / `OrientedView` pytrees have
identical treedefs and leaf shapes. Stacking K tenants leaf-wise gives
one pytree with a leading tenant axis, and ``jax.vmap`` of the EXISTING
single-tensor sweeps (`cpals._sweep`, `cpapr._mode_update`) runs all K
through one jitted executable. Nothing about the per-tensor math is
reimplemented here; this module only stacks, masks, and unstacks.

Per-tenant convergence: a converged tenant cannot leave the bucket (its
bucket-mates still need the executable's shapes), so its state freezes —
the batched step computes the update for every slot and applies
``jnp.where(active, new, old)`` per leaf. Frozen tenants burn flops but
never drift: their factors, λ, and (for CP-APR) Φ memory are bit-frozen
at the converged iterate while neighbours keep sweeping.

Exactness of bucketing (why a tenant's answer matches its solo run):
each tenant enters with its solo init embedded into the class dims
(`embed_factors` — extra rows are exact zeros). Padded factor rows
receive no stream contributions (pad elements carry value 0, so their
row updates add exact IEEE zeros) and a zero row of the MTTKRP stays a
zero factor row through the pinv solve; zero rows also contribute
nothing to Gram matrices, λ, or the fit. The batched trajectory is
therefore the solo trajectory with zeros appended — sliced back to real
dims on exit.

The batched sweeps run the reference (pure-jnp) backend: those
traversals are ordinary vmappable jnp programs. The Pallas kernels are
not vmap-wired (Mosaic batching rules are carry-over work; see
docs/known-issues.md) — the canonical meta's ``fiber_reuse = 1.0``
already routes every mode to the output-oriented jnp family.

Trace accounting mirrors `alto.device_ingest_traces`: `sweep_traces()`
counts actual jit traces of the batched cores, and the serving tests pin
"one trace per shape class, not per tenant" with before/after deltas.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cpals, cpapr, faults
from repro.core import health as health_mod
from repro.core import plan as plan_mod
from repro.core.alto import AltoTensor, OrientedView


# Jitted batched cores, keyed on (algorithm, plan[, statics]); the
# stacked input shapes are a pure function of the plan's meta + bucket
# capacity, so one entry per key is one XLA executable. Guarded like the
# ingest cache — serving drivers hit this from worker threads.
_SWEEP_FNS: dict[tuple, object] = {}
_SWEEP_TRACES = {"als": 0, "apr": 0}
_SWEEP_LOCK = threading.Lock()


def sweep_traces() -> dict[str, int]:
    """Trace counts of the batched cores (per algorithm). The serving
    acceptance test asserts the delta is bounded by the number of shape
    classes, never the number of tenants."""
    with _SWEEP_LOCK:
        return dict(_SWEEP_TRACES)


def sweep_cache_clear() -> None:
    with _SWEEP_LOCK:
        _SWEEP_FNS.clear()
        _SWEEP_TRACES["als"] = 0
        _SWEEP_TRACES["apr"] = 0


def _cached_sweep_fn(key: tuple, build):
    with _SWEEP_LOCK:
        fn = _SWEEP_FNS.get(key)
        if fn is None:
            fn = _SWEEP_FNS[key] = build()
        return fn


def stack_tenants(items: Sequence):
    """Leaf-wise stack of same-class pytrees → one pytree, leading K axis.

    Works for `AltoTensor`, view dicts, factor lists — any pytree whose
    members agree on treedef and static aux (which same-class tenants
    do by construction: they share the canonical meta).
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *items)


def embed_factors(factors: Sequence[jnp.ndarray],
                  class_dims: Sequence[int]) -> list[jnp.ndarray]:
    """Embed real-dims factor matrices into class dims with zero rows.

    The zero rows are the exactness anchor: they stay exactly zero
    through every CP-ALS/CP-APR update (see module docstring), so the
    embedded trajectory IS the solo trajectory.
    """
    out = []
    for A, D in zip(factors, class_dims):
        pad = int(D) - A.shape[0]
        if pad < 0:
            raise ValueError(f"factor rows {A.shape[0]} exceed class "
                             f"dim {D}")
        out.append(jnp.pad(A, ((0, pad), (0, 0))) if pad else A)
    return out


def _slice_factors(factors, dims):
    return [A[:int(I)] for A, I in zip(factors, dims)]


# ---------------------------------------------------------------------------
# Batched CP-ALS
# ---------------------------------------------------------------------------

def _als_sweep_fn(plan: plan_mod.ExecutionPlan):
    """One jitted batched ALS sweep: vmap of `cpals._sweep` + freeze mask."""
    def core(at, views, factors, lam, active):
        with _SWEEP_LOCK:
            _SWEEP_TRACES["als"] += 1                    # trace-time only
        new_factors, new_lam, M_last = jax.vmap(
            functools.partial(cpals._sweep, plan))(at, views, factors, lam)
        a3 = active[:, None, None]
        factors = [jnp.where(a3, nf, f)
                   for nf, f in zip(new_factors, factors)]
        lam = jnp.where(active[:, None], new_lam, lam)
        return factors, lam, M_last

    return _cached_sweep_fn(("als", plan), lambda: jax.jit(core))


@dataclasses.dataclass
class BatchedCpalsResult:
    results: list[cpals.CpalsResult]   # per tenant, factors at REAL dims
    n_sweeps: int                      # batched sweeps executed
    # quarantined[i]: tenant i's update went non-finite under guard=True;
    # its result is the last good iterate, frozen from that sweep on.
    quarantined: list[bool] = dataclasses.field(default_factory=list)


def batched_cp_als(ats: Sequence[AltoTensor],
                   views: Sequence[dict[int, OrientedView]],
                   real_dims: Sequence[tuple[int, ...]],
                   rank: int, *,
                   plan: plan_mod.ExecutionPlan,
                   n_iters: int = 50, tol: float = 1e-5,
                   seeds: Sequence[int] | None = None,
                   init_factors: Sequence[list[jnp.ndarray]] | None = None,
                   capacity: int | None = None,
                   guard: bool = False) -> BatchedCpalsResult:
    """CP-ALS over K same-class tenants through ONE jitted executable.

    ``ats``/``views`` are the canonicalized class members (all sharing
    ``plan.meta``); ``real_dims[i]`` are tenant i's true extents, used
    for the solo-equivalent init and to slice the answer back out.
    ``capacity`` (≥ K) fixes the stacked leading axis: short buckets are
    filled with inactive replicas of tenant 0, so every bucket of the
    class reuses one trace regardless of how full it is. Per-tenant
    convergence uses the same host-side Kolda–Bader fit and ``tol`` as
    solo `cp_als`; a converged tenant freezes while bucket-mates sweep.

    ``guard=True`` adds the per-tenant quarantine (`core.health`): after
    each sweep a jitted per-slot all-finite mask flags tenants whose
    update went non-finite (vmap keeps lanes independent, so the poison
    never crosses slots); a flagged tenant rolls back to its previous
    iterate and freezes through the SAME where-mask machinery that
    freezes converged tenants — bucket-mates keep sweeping, bitwise
    unaffected, and the offender's result carries ``quarantined=True``.
    """
    K = len(ats)
    if K == 0:
        return BatchedCpalsResult(results=[], n_sweeps=0)
    if len(views) != K or len(real_dims) != K:
        raise ValueError("ats/views/real_dims length mismatch")
    for at in ats:
        if at.meta != plan.meta:
            raise ValueError("tenant meta differs from plan meta — "
                             "canonicalize (shapeclass.canonicalize_tensor) "
                             "before batching")
    cap = K if capacity is None else int(capacity)
    if cap < K:
        raise ValueError(f"capacity {cap} < bucket size {K}")
    class_dims = plan.meta.dims
    dtype = ats[0].values.dtype
    if seeds is None:
        seeds = [0] * K
    if init_factors is None:
        init_factors = [cpals.init_factors(real_dims[i], rank,
                                           seed=int(seeds[i]), dtype=dtype)
                        for i in range(K)]
    factors_k = [embed_factors(f, class_dims) for f in init_factors]

    # Fill to capacity with inactive replicas of slot 0 (frozen from the
    # first sweep, discarded on exit) so K never perturbs trace shapes.
    fill = cap - K
    at_b = stack_tenants(list(ats) + [ats[0]] * fill)
    views_b = stack_tenants(list(views) + [views[0]] * fill)
    factors_b = stack_tenants(factors_k + [factors_k[0]] * fill)
    lam_b = jnp.ones((cap, rank), dtype=dtype)

    normX2 = [float((np.asarray(at.values, np.float64) ** 2).sum())
              for at in ats]
    active = np.zeros(cap, bool)
    active[:K] = True
    quarantined = np.zeros(cap, bool)
    fits: list[list[float]] = [[] for _ in range(K)]
    prev = np.full(K, -np.inf)
    sweep = _als_sweep_fn(plan)
    n_sweeps = 0
    for _ in range(n_iters):
        faults.inject("batched.sweep")
        good_f, good_l = factors_b, lam_b
        factors_b, lam_b, M_last = sweep(at_b, views_b, factors_b, lam_b,
                                         jnp.asarray(active))
        n_sweeps += 1
        pd = faults.fire("batched.nan")
        if pd is not None:
            t = int(pd.get("tenant", 0))
            poison = pd.get("value", float("nan"))
            factors_b = list(factors_b)
            factors_b[-1] = factors_b[-1].at[t, 0, 0].set(poison)
        if guard:
            ok = health_mod.tenants_finite([*factors_b, lam_b, M_last])
            bad = active & ~ok
        else:
            bad = np.zeros(cap, bool)
        for i in range(K):
            if not active[i] or bad[i]:
                continue
            fit = cpals._fit_host(M_last[i], [A[i] for A in factors_b],
                                  lam_b[i], normX2[i])
            if guard and (not np.isfinite(fit)
                          or fit < health_mod.FIT_FLOOR):
                # Huge-but-finite poison: this slot must be quarantined
                # NOW — its Grams overflow the next vmapped sweep and a
                # non-finite SVD can spin forever (health.FIT_FLOOR).
                bad[i] = True
                continue
            fits[i].append(fit)
            if abs(fit - prev[i]) < tol:
                active[i] = False
            prev[i] = fit
        if guard and bad.any():
            # Roll the poisoned slots back to their previous iterate
            # and freeze them — the same where-mask that freezes
            # converged tenants, so bucket-mates are untouched.
            b3 = jnp.asarray(bad)[:, None, None]
            factors_b = [jnp.where(b3, g, f)
                         for g, f in zip(good_f, factors_b)]
            lam_b = jnp.where(jnp.asarray(bad)[:, None], good_l, lam_b)
            quarantined |= bad
            active &= ~bad
        if not active[:K].any():
            break

    results = []
    for i in range(K):
        fac = _slice_factors([A[i] for A in factors_b], real_dims[i])
        results.append(cpals.CpalsResult(
            lam=lam_b[i], factors=fac, fits=fits[i],
            n_iters=len(fits[i]), plan=plan))
    return BatchedCpalsResult(results=results, n_sweeps=n_sweeps,
                              quarantined=[bool(q)
                                           for q in quarantined[:K]])


# ---------------------------------------------------------------------------
# Batched CP-APR
# ---------------------------------------------------------------------------

def _apr_update_fn(plan: plan_mod.ExecutionPlan, mode: int,
                   first_outer: bool, pre_pi: bool, p: cpapr.CpaprParams):
    """One jitted batched CP-APR mode update: vmap of `cpapr._mode_update`
    + per-tenant freeze of factors[mode], λ, and the Φ memory."""
    def core(at, view, lam, factors, phi_prev, active):
        with _SWEEP_LOCK:
            _SWEEP_TRACES["apr"] += 1                    # trace-time only
        def upd(t, v, l, f, ph):
            return cpapr._mode_update(t, v, mode, l, f, ph,
                                      first_outer=first_outer,
                                      pre_pi=pre_pi, p=p, plan=plan)
        A_new, lam_new, Phi, conv, n_inner, kkt = jax.vmap(upd)(
            at, view, lam, factors, phi_prev)
        a3 = active[:, None, None]
        A = jnp.where(a3, A_new, factors[mode])
        lam = jnp.where(active[:, None], lam_new, lam)
        Phi = jnp.where(a3, Phi, phi_prev)
        n_inner = jnp.where(active, n_inner, 0)
        return A, lam, Phi, conv, n_inner, kkt

    key = ("apr", plan, mode, bool(first_outer), bool(pre_pi), p)
    return _cached_sweep_fn(key, lambda: jax.jit(core))


@dataclasses.dataclass
class BatchedCpaprResult:
    results: list[cpapr.CpaprResult]   # per tenant, factors at REAL dims
    n_outer: int                       # batched outer iterations executed
    # Same contract as BatchedCpalsResult.quarantined (guard=True only).
    quarantined: list[bool] = dataclasses.field(default_factory=list)


def batched_cp_apr(ats: Sequence[AltoTensor],
                   views: Sequence[dict[int, OrientedView]],
                   real_dims: Sequence[tuple[int, ...]],
                   rank: int, *,
                   plan: plan_mod.ExecutionPlan,
                   params: cpapr.CpaprParams | None = None,
                   seeds: Sequence[int] | None = None,
                   capacity: int | None = None,
                   guard: bool = False) -> BatchedCpaprResult:
    """CP-APR over K same-class tenants through one executable per mode.

    Same stacking/masking contract as `batched_cp_als`. A tenant freezes
    (factors, λ, AND its Φ inadmissible-zero memory) once every mode
    reports KKT convergence, exactly the solo driver's stopping rule.
    The jit key includes the static mode/first_outer flags, so a class
    costs 2·N traces for N-mode tensors — still independent of K and of
    how many buckets the class serves.
    """
    K = len(ats)
    if K == 0:
        return BatchedCpaprResult(results=[], n_outer=0)
    for at in ats:
        if at.meta != plan.meta:
            raise ValueError("tenant meta differs from plan meta — "
                             "canonicalize before batching")
    p = params or cpapr.CpaprParams()
    cap = K if capacity is None else int(capacity)
    if cap < K:
        raise ValueError(f"capacity {cap} < bucket size {K}")
    N = len(plan.meta.dims)
    class_dims = plan.meta.dims
    dtype = ats[0].values.dtype
    pre_pi = plan.pi_policy.value == "pre"
    if seeds is None:
        seeds = [0] * K

    lam_k, factors_k = [], []
    for i in range(K):
        total = float(jnp.sum(ats[i].values))
        lam_i, fac_i = cpapr.init_factors(real_dims[i], rank,
                                          seed=int(seeds[i]), total=total,
                                          dtype=dtype)
        lam_k.append(lam_i)
        factors_k.append(embed_factors(fac_i, class_dims))

    fill = cap - K
    at_b = stack_tenants(list(ats) + [ats[0]] * fill)
    views_b = {n: stack_tenants([v[n] for v in views]
                                + [views[0][n]] * fill)
               for n in views[0]}
    factors_b = stack_tenants(factors_k + [factors_k[0]] * fill)
    lam_b = stack_tenants(lam_k + [lam_k[0]] * fill)
    phi_b = [jnp.zeros_like(A) for A in factors_b]

    active = np.zeros(cap, bool)
    active[:K] = True
    quarantined = np.zeros(cap, bool)
    kkt_hist: list[list[float]] = [[] for _ in range(K)]
    n_inner_tot = np.zeros(cap, np.int64)
    n_outer_seen = np.zeros(K, np.int32)
    n_outer = 0
    for outer in range(1, p.k_max + 1):
        faults.inject("batched.sweep")
        good = (lam_b, list(factors_b), list(phi_b))
        n_outer = outer
        conv_all = np.ones(cap, bool)
        kkt_max = np.zeros(cap)
        for n in range(N):
            fn = _apr_update_fn(plan, n, outer == 1, pre_pi, p)
            A, lam_b, Phi, conv, n_inner, kkt = fn(
                at_b, views_b.get(n), lam_b, factors_b, phi_b[n],
                jnp.asarray(active))
            pd = faults.fire("batched.nan")
            if pd is not None:
                t = int(pd.get("tenant", 0))
                A = A.at[t, 0, 0].set(pd.get("value", float("nan")))
            factors_b = list(factors_b)
            factors_b[n] = A
            phi_b[n] = Phi
            conv_all &= np.asarray(conv)
            n_inner_tot += np.asarray(n_inner, np.int64)
            kkt_max = np.maximum(kkt_max, np.asarray(kkt))
        if guard:
            ok = health_mod.tenants_finite([lam_b, *factors_b])
            ok &= np.isfinite(kkt_max)
            bad = active & ~ok
            if bad.any():
                g_lam, g_fac, g_phi = good
                b3 = jnp.asarray(bad)[:, None, None]
                factors_b = [jnp.where(b3, g, f)
                             for g, f in zip(g_fac, factors_b)]
                phi_b = [jnp.where(b3, g, f)
                         for g, f in zip(g_phi, phi_b)]
                lam_b = jnp.where(jnp.asarray(bad)[:, None], g_lam, lam_b)
                quarantined |= bad
                active &= ~bad
        for i in range(K):
            if active[i]:
                kkt_hist[i].append(float(kkt_max[i]))
                n_outer_seen[i] = outer
        newly_done = active & conv_all
        active &= ~newly_done
        if not active[:K].any():
            break

    results = []
    for i in range(K):
        fac = _slice_factors([A[i] for A in factors_b], real_dims[i])
        results.append(cpapr.CpaprResult(
            lam=lam_b[i], factors=fac, kkt_violations=kkt_hist[i],
            log_likelihoods=[], n_outer=int(n_outer_seen[i]),
            n_inner_total=int(n_inner_tot[i]),
            pi_policy=plan.pi_policy.value,
            traversals=[plan.modes[n].traversal.value for n in range(N)],
            plan=plan))
    return BatchedCpaprResult(results=results, n_outer=n_outer,
                              quarantined=[bool(q)
                                           for q in quarantined[:K]])
