"""Host-resident ALTO streams for out-of-core (chunked) execution.

The in-core oriented path (`core.views`) keeps one device-resident
row-sorted copy of the stream per (tensor, mode). For tensors whose
padded stream does not fit the device byte budget (`core.plan`'s
streaming decision) the same copy lives HERE instead: host numpy arrays
— optionally memory-mapped from disk — that the chunked executors in
`kernels.ops` slice into row-sorted chunks and feed through device
memory with double-buffered `jax.device_put` prefetch.

Contracts that make chunking bitwise-exact against the in-core
`oriented_carry` kernels:

* **Same element order.** `host_stream` builds the oriented permutation
  with the identical extract + stable-argsort the in-core builders use
  (`alto.oriented_view` / `oriented_view_device` are bit-identical to
  each other; this is the same numpy path), so element k of the host
  stream is element k of the in-core view.

* **Same padding rule.** The stream is padded once, host-side, to a
  multiple of :data:`STREAM_ALIGN` with `ops.pad_sorted_stream`'s rule —
  replicated final row/words, zero values (an empty stream pads with
  zero rows/words). ``STREAM_ALIGN`` (1024, == ``plan.MAX_BLOCK_M``) is
  a multiple of every legal ``block_m``, and the padded prefix of length
  ``ceil(Mp/block_m)·block_m`` is element-for-element what
  `ops.pad_sorted_stream` would have produced at that ``block_m`` —
  replicated padding is self-similar under truncation. Chunk slicing at
  ``block_m`` multiples therefore cuts the exact block sequence the
  in-core kernel scans.

* **Zero-copy slices.** :meth:`HostStream.chunk` returns numpy views
  (no copy); `jax.device_put` on the slice is the only transfer. Numpy
  refcounting keeps a slice's backing buffer alive even if the cache
  entry that produced it is evicted mid-flight — the no-use-after-evict
  property `tests/test_outofcore.py` pins.
"""
from __future__ import annotations

import dataclasses
import os
import pathlib
import threading
import zlib

import jax
import numpy as np

from repro.core import encoding as enc_mod
from repro.core import faults
from repro.core.alto import AltoMeta, AltoTensor, OrientedView

# One alignment for every host stream: a multiple of every legal oriented
# block_m (powers of two in [plan.MIN_BLOCK_M, plan.MAX_BLOCK_M]), so one
# padded copy serves any tiling. Must equal plan.MAX_BLOCK_M.
STREAM_ALIGN = 1024


class StreamIntegrityError(RuntimeError):
    """A spilled stream's content checksum does not match its payload —
    a torn multi-file write (crash between `_respill`'s replaces) or
    on-disk corruption. Detected at LOAD time so a wrong stream never
    reaches an executor; recovery is `load_or_rebuild`."""


# Integrity accounting the serving stats surface (instead of log-scraping).
_INTEGRITY_LOCK = threading.Lock()
_INTEGRITY = {"checksum_failures": 0, "rebuilds": 0}


def integrity_stats() -> dict[str, int]:
    with _INTEGRITY_LOCK:
        return dict(_INTEGRITY)


def integrity_stats_clear() -> None:
    with _INTEGRITY_LOCK:
        for k in _INTEGRITY:
            _INTEGRITY[k] = 0


def _integrity_bump(counter: str) -> None:
    with _INTEGRITY_LOCK:
        _INTEGRITY[counter] += 1


def stream_checksum(rows: np.ndarray, words: np.ndarray,
                    values: np.ndarray) -> int:
    """crc32 over the padded payload bytes (rows ‖ words ‖ values).

    One sequential pass at spill/load time — for a memmap-backed stream
    the verify pages the file in once, which is the price of never
    handing a torn generation to the chunked executors.
    """
    c = zlib.crc32(np.ascontiguousarray(rows).tobytes())
    c = zlib.crc32(np.ascontiguousarray(words).tobytes(), c)
    c = zlib.crc32(np.ascontiguousarray(values).tobytes(), c)
    return c & 0xFFFFFFFF


@dataclasses.dataclass
class HostStream:
    """One (tensor, mode) row-sorted stream, host-resident and pre-padded.

    ``length`` is the real (partition-padded) stream length Mp; the
    arrays extend to the next :data:`STREAM_ALIGN` multiple with
    replicated-row / zero-value padding. ``rows`` is int32 ascending,
    ``words`` is (La, W) uint32, ``values`` matches the tensor dtype.
    Arrays may be plain numpy or read-only ``np.memmap`` (disk-backed).
    """
    meta: AltoMeta
    mode: int
    length: int
    rows: np.ndarray
    words: np.ndarray
    values: np.ndarray
    # Content checksum of the padded payload (`stream_checksum`). None for
    # in-memory streams (never at risk of a torn write); spilled streams
    # carry it and `from_memmap` verifies it against the mapped bytes.
    checksum: int | None = None

    def padded_len(self, block_m: int) -> int:
        """Stream length after `ops.pad_sorted_stream` at ``block_m``."""
        if STREAM_ALIGN % block_m:
            raise ValueError(f"block_m {block_m} does not divide "
                             f"STREAM_ALIGN {STREAM_ALIGN}")
        return -(-self.length // block_m) * block_m

    def chunk(self, start: int, stop: int):
        """Zero-copy (rows, words, values) numpy views of [start, stop)."""
        return (self.rows[start:stop], self.words[start:stop],
                self.values[start:stop])

    def nbytes(self) -> int:
        return int(self.rows.nbytes + self.words.nbytes
                   + self.values.nbytes)


def pad_host_stream(rows: np.ndarray, words: np.ndarray,
                    values: np.ndarray, mult: int):
    """Numpy twin of `ops.pad_sorted_stream` (single padding rule).

    Replicates the final row/words with zero values so padded elements
    contribute nothing; an empty stream pads one full ``mult`` block of
    zero rows/words (still sorted, still value-0).
    """
    M = words.shape[0]
    pad = mult if M == 0 else (-M) % mult
    if pad == 0:
        return rows, words, values
    if M == 0:
        pad_rows = np.zeros((pad,), rows.dtype)
        pad_words = np.zeros((pad, words.shape[1]), words.dtype)
    else:
        pad_rows = np.broadcast_to(rows[-1:], (pad,))
        pad_words = np.broadcast_to(words[-1:], (pad, words.shape[1]))
    rows = np.concatenate([rows, pad_rows])
    words = np.concatenate([words, pad_words])
    values = np.concatenate([values, np.zeros((pad,), values.dtype)])
    return rows, words, values


def host_stream(at: AltoTensor, mode: int) -> HostStream:
    """Build the host-resident oriented stream for ``(at, mode)``.

    Same extract + stable argsort as `alto.oriented_view`, kept in numpy
    end to end (no device round-trip for the sorted copy), then padded
    once to the :data:`STREAM_ALIGN` multiple.
    """
    words_np = np.asarray(at.words)
    values_np = np.asarray(at.values)
    rows = enc_mod.extract_mode(at.meta.enc, words_np, mode)
    order = np.argsort(rows, kind="stable")
    rows = np.ascontiguousarray(rows[order].astype(np.int32))
    words = np.ascontiguousarray(words_np[order])
    values = np.ascontiguousarray(values_np[order])
    length = words.shape[0]
    rows, words, values = pad_host_stream(rows, words, values, STREAM_ALIGN)
    return HostStream(meta=at.meta, mode=mode, length=length,
                      rows=np.ascontiguousarray(rows),
                      words=np.ascontiguousarray(words),
                      values=np.ascontiguousarray(values))


def ensure_host(view) -> HostStream:
    """Adapt an in-core `OrientedView` (or pass through a HostStream).

    Lets the chunked executors accept either representation — tests and
    benchmarks chunk existing device views without rebuilding.
    """
    if isinstance(view, HostStream):
        return view
    if isinstance(view, OrientedView):
        rows = np.asarray(view.rows)
        words = np.asarray(view.words)
        values = np.asarray(view.values)
        length = words.shape[0]
        rows, words, values = pad_host_stream(rows, words, values,
                                              STREAM_ALIGN)
        return HostStream(meta=view.meta, mode=view.mode, length=length,
                          rows=rows, words=words, values=values)
    raise TypeError(f"expected HostStream or OrientedView, got "
                    f"{type(view).__name__}")


# ---------------------------------------------------------------------------
# Disk backing (optional): .npy files re-opened as read-only memmaps
# ---------------------------------------------------------------------------

def _respill(hs: HostStream, d: pathlib.Path) -> HostStream:
    """Write ``hs`` into ``d`` atomically and reopen it memory-mapped.

    Two phases: every array is fully written to a ``.tmp`` sibling
    first, then ALL tmps are moved into place with ``os.replace`` —
    readers holding memmaps of the OLD files keep the old inodes alive
    (no torn reads, no SIGBUS from a truncating in-place ``np.save``),
    and a crash anywhere in the write phase leaves the previous
    generation byte-identical on disk (the ``stream.respill`` fault site
    sits between the phases; `tests/test_resilience.py` kills the spill
    there and asserts the old stream still loads and verifies). A crash
    *between replaces* can still tear across files — which is exactly
    what the content checksum (written alongside, verified by
    `from_memmap`) turns from silent corruption into a load-time
    `StreamIntegrityError`.
    """
    d.mkdir(parents=True, exist_ok=True)
    checksum = stream_checksum(hs.rows, hs.words, hs.values)
    payload = {"rows": np.asarray(hs.rows), "words": np.asarray(hs.words),
               "values": np.asarray(hs.values),
               "length": np.asarray([hs.length], np.int64),
               "checksum": np.asarray([checksum], np.int64)}
    tmps = {}
    for name, arr in payload.items():
        tmp = d / f".{name}.tmp.npy"
        np.save(tmp, arr)
        tmps[name] = tmp
    faults.inject("stream.respill")
    for name, tmp in tmps.items():
        os.replace(tmp, d / f"{name}.npy")
    return from_memmap(d, hs.meta, hs.mode)


def to_memmap(hs: HostStream, directory) -> HostStream:
    """Spill ``hs`` to ``directory`` and reopen it memory-mapped.

    Writes ``rows/words/values`` as ``.npy`` plus the real length, and
    returns a HostStream whose arrays are read-only ``np.memmap`` views —
    the OS pages chunks in as the executors slice them, so the host
    working set is bounded by the touched chunks, not the stream.
    """
    return _respill(hs, pathlib.Path(directory))


def from_memmap(directory, meta: AltoMeta, mode: int) -> HostStream:
    """Reopen a spilled stream (`to_memmap`) as read-only memmaps.

    Verifies the stored content checksum against the mapped payload
    before returning — a generation torn across the per-array files
    (crash between `_respill` replaces, disk corruption) raises
    `StreamIntegrityError` here instead of producing a silently wrong
    decomposition downstream. Pre-checksum spills (no ``checksum.npy``)
    load unverified for compatibility.
    """
    faults.inject("stream.memmap_load")
    d = pathlib.Path(directory)
    length = int(np.load(d / "length.npy")[0])
    hs = HostStream(meta=meta, mode=mode, length=length,
                    rows=np.load(d / "rows.npy", mmap_mode="r"),
                    words=np.load(d / "words.npy", mmap_mode="r"),
                    values=np.load(d / "values.npy", mmap_mode="r"))
    cpath = d / "checksum.npy"
    if cpath.exists():
        stored = int(np.load(cpath)[0])
        if faults.fire("stream.checksum") is not None:
            stored ^= 1                       # simulate on-disk corruption
        actual = stream_checksum(hs.rows, hs.words, hs.values)
        if stored != actual:
            _integrity_bump("checksum_failures")
            raise StreamIntegrityError(
                f"spilled stream at {d} fails its checksum "
                f"(stored {stored:#010x}, payload {actual:#010x}) — "
                f"torn write or corruption; rebuild from source "
                f"(stream.load_or_rebuild)")
        hs.checksum = stored
    return hs


def load_or_rebuild(directory, at: AltoTensor, mode: int) -> HostStream:
    """`from_memmap` with the rebuild-from-source recovery rung.

    A checksum-failing (or unreadable) spill is rebuilt from the
    resident tensor — `host_stream` + a fresh atomic spill into the same
    directory — so one torn write costs a re-sort and a re-write, never
    a wrong answer or a dead tensor. The serving runtime counts these
    (``rebuilds`` in `integrity_stats`).
    """
    try:
        return from_memmap(directory, at.meta, mode)
    except (StreamIntegrityError, OSError):
        _integrity_bump("rebuilds")
        return _respill(host_stream(at, mode), pathlib.Path(directory))


def append_stream(hs: HostStream, at_new: AltoTensor) -> HostStream:
    """In-place update path for host/memmap streams after an append.

    Rebuilds the oriented stream for ``hs.mode`` from the merged tensor
    (`core.ingest.append_delta`'s result). A plain-numpy stream returns a
    fresh host-resident one; a memmap-backed stream is re-spilled into
    ITS OWN directory (recovered from ``np.memmap.filename``) via the
    atomic `_respill`, so the out-of-core tensor updates in place on disk
    while executors still slicing the previous generation keep reading
    the old inodes.
    """
    merged = host_stream(at_new, hs.mode)
    if isinstance(hs.words, np.memmap):
        return _respill(merged, pathlib.Path(hs.words.filename).parent)
    return merged


def put_chunk(hs: HostStream, start: int, stop: int):
    """Upload one chunk to device: (rows, words, values) jax arrays.

    `jax.device_put` on the zero-copy numpy slices; on accelerator
    backends the transfers are dispatched asynchronously, so issuing the
    NEXT chunk's put before computing on the current one overlaps copy
    with compute (the double-buffer loop in `kernels.ops`).
    """
    faults.inject("stream.chunk_io")
    rows, words, values = hs.chunk(start, stop)
    return (jax.device_put(rows), jax.device_put(words),
            jax.device_put(values))
