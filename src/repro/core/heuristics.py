"""Input-aware adaptation heuristics (paper §4.2, §4.3, Table 1).

All decisions are made from *static* tensor statistics at build/trace time,
selecting which compiled variant runs — the JAX/TPU analogue of the paper's
runtime dispatch (jit control flow must be static).
"""
from __future__ import annotations

import dataclasses
import enum

from repro.core.alto import AltoMeta

# Paper §4.2: the two-stage buffered accumulation costs at worst 4 memory
# operations (2 reads + 2 writes); recursive traversal pays off only when the
# average reuse per output fiber exceeds that.
BUFFERED_ACCUM_COST = 4.0

# Paper §5.1.2 (Table 1) classification thresholds.
HIGH_REUSE = 8.0
MEDIUM_REUSE = 5.0

# Fast-memory budget used by the PRE/OTF decision. On the TPU target this is
# per-core VMEM; on the CPU test host it approximates L2+L3 per core.
DEFAULT_FAST_MEM_BYTES = 128 * 1024 * 1024


class Traversal(enum.Enum):
    RECURSIVE = "recursive"          # ALTO order + Temp + pull reduction
    OUTPUT_ORIENTED = "oriented"     # output-mode order + segment reduction


class PiPolicy(enum.Enum):
    PRE = "pre"    # precompute & stream the (M, R) Khatri-Rao rows
    OTF = "otf"    # recompute KRP rows on the fly


def classify_reuse(reuse: float) -> str:
    if reuse > HIGH_REUSE:
        return "high"
    if reuse >= MEDIUM_REUSE:
        return "medium"
    return "limited"


def tensor_reuse_class(meta: AltoMeta) -> str:
    """A tensor is limited/medium if ANY mode is (paper §5.1.2)."""
    classes = [classify_reuse(r) for r in meta.fiber_reuse]
    for level in ("limited", "medium"):
        if level in classes:
            return level
    return "high"


def choose_traversal(meta: AltoMeta, mode: int) -> Traversal:
    """Recursive traversal iff fiber reuse amortizes the buffered
    accumulation (> 4 memory ops), else output-oriented (paper §4.2)."""
    if meta.fiber_reuse[mode] > BUFFERED_ACCUM_COST:
        return Traversal.RECURSIVE
    return Traversal.OUTPUT_ORIENTED


def candidate_traversals(meta: AltoMeta, mode: int) -> tuple[Traversal, ...]:
    """Both traversals, static choice first.

    The measured autotuner (`core.autotune`) re-ranks this candidate list
    by timing; the static heuristic survives as the *prior* — it orders
    the candidates (so a capped search keeps the analytic choice) and
    remains the answer whenever no measurement is available.
    """
    first = choose_traversal(meta, mode)
    second = (Traversal.OUTPUT_ORIENTED if first is Traversal.RECURSIVE
              else Traversal.RECURSIVE)
    return (first, second)


def choose_pi_policy(meta: AltoMeta, rank: int, value_bytes: int = 4,
                     fast_mem_bytes: int = DEFAULT_FAST_MEM_BYTES
                     ) -> PiPolicy:
    """ALTO-PRE iff reuse is low AND factors overflow fast memory (§4.3)."""
    factor_bytes = sum(I * rank * value_bytes for I in meta.dims)
    low_reuse = tensor_reuse_class(meta) == "limited"
    if low_reuse and factor_bytes > fast_mem_bytes:
        return PiPolicy.PRE
    return PiPolicy.OTF
