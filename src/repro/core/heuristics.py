"""Input-aware adaptation heuristics (paper §4.2, §4.3, Table 1).

All decisions are made from *static* tensor statistics at build/trace time,
selecting which compiled variant runs — the JAX/TPU analogue of the paper's
runtime dispatch (jit control flow must be static).
"""
from __future__ import annotations

import dataclasses
import enum

from repro.core.alto import AltoMeta

# Paper §4.2: the two-stage buffered accumulation costs at worst 4 memory
# operations (2 reads + 2 writes); recursive traversal pays off only when the
# average reuse per output fiber exceeds that.
BUFFERED_ACCUM_COST = 4.0

# Paper §5.1.2 (Table 1) classification thresholds.
HIGH_REUSE = 8.0
MEDIUM_REUSE = 5.0

# Fast-memory budget used by the PRE/OTF decision. On the TPU target this is
# per-core VMEM; on the CPU test host it approximates L2+L3 per core.
DEFAULT_FAST_MEM_BYTES = 128 * 1024 * 1024


class Traversal(enum.Enum):
    RECURSIVE = "recursive"          # ALTO order + Temp + pull reduction
    OUTPUT_ORIENTED = "oriented"     # output-mode order + segment reduction
    # output-mode order + sequential scratch-carry scan: partial sums ride
    # a VMEM carry across grid steps and land directly in the (I_n, R)
    # output — no (n_blocks, block_m, R) partials buffer, no host merge.
    ORIENTED_CARRY = "oriented_carry"


# Both output-oriented variants consume the same row-sorted view and obey
# the same carry-merge correctness condition; routing code that only cares
# about "recursive vs oriented" should test membership here, not identity
# with OUTPUT_ORIENTED.
ORIENTED_FAMILY = (Traversal.OUTPUT_ORIENTED, Traversal.ORIENTED_CARRY)


def is_oriented(traversal: Traversal) -> bool:
    """True for either output-oriented variant (one-hot merge or carry)."""
    return traversal in ORIENTED_FAMILY


class PiPolicy(enum.Enum):
    PRE = "pre"    # precompute & stream the (M, R) Khatri-Rao rows
    OTF = "otf"    # recompute KRP rows on the fly


def classify_reuse(reuse: float) -> str:
    if reuse > HIGH_REUSE:
        return "high"
    if reuse >= MEDIUM_REUSE:
        return "medium"
    return "limited"


def tensor_reuse_class(meta: AltoMeta) -> str:
    """A tensor is limited/medium if ANY mode is (paper §5.1.2)."""
    classes = [classify_reuse(r) for r in meta.fiber_reuse]
    for level in ("limited", "medium"):
        if level in classes:
            return level
    return "high"


def choose_traversal(meta: AltoMeta, mode: int) -> Traversal:
    """Recursive traversal iff fiber reuse amortizes the buffered
    accumulation (> 4 memory ops), else output-oriented (paper §4.2)."""
    if meta.fiber_reuse[mode] > BUFFERED_ACCUM_COST:
        return Traversal.RECURSIVE
    return Traversal.OUTPUT_ORIENTED


def candidate_traversals(meta: AltoMeta, mode: int) -> tuple[Traversal, ...]:
    """All traversals, static family choice first.

    The measured autotuner (`core.autotune`) re-ranks this candidate list
    by timing; the static heuristic survives as the *prior* — it orders
    the candidates (so a capped search keeps the analytic choice) and
    remains the answer whenever no measurement is available. Both
    output-oriented variants are listed — the carry variant's VMEM
    feasibility is the plan layer's call (`plan.candidate_mode_plans`
    prunes by the per-kernel footprints).
    """
    first = choose_traversal(meta, mode)
    rest = tuple(t for t in (Traversal.OUTPUT_ORIENTED,
                             Traversal.ORIENTED_CARRY, Traversal.RECURSIVE)
                 if t is not first)
    return (first,) + rest


# ---------------------------------------------------------------------------
# Oriented-variant choice: one-hot merge vs scratch-carry, by HBM traffic
# ---------------------------------------------------------------------------

def stream_len(meta: AltoMeta) -> int:
    """Length of the (partition-padded) sorted nonzero stream the oriented
    kernels consume. The further padding to a ``block_m`` multiple is at
    most one block and is ignored by the traffic model."""
    L = meta.n_partitions
    return -(-max(meta.nnz, L) // L) * L


def oriented_merge_traffic_bytes(meta: AltoMeta, mode: int, rank: int,
                                 dtype_bytes: int = 4) -> int:
    """HBM bytes the one-hot oriented path moves BEYOND the stream read.

    The kernel materializes ``(n_blocks, block_m, R)`` per-block segment
    sums to HBM (one write), which `ops.segment_merge` immediately reads
    back together with the row stream and scatters into the ``(I_n, R)``
    output (one read + the output write). For typical tensors the
    partials round-trip dwarfs everything else — it is the term the
    scratch-carry traversal deletes.
    """
    M = stream_len(meta)
    partials_round_trip = 2 * M * rank * dtype_bytes   # write, then re-read
    merge_rows = M * 4                                 # merge re-reads rows
    out_write = meta.dims[mode] * rank * dtype_bytes
    return partials_round_trip + merge_rows + out_write


def carry_traffic_bytes(meta: AltoMeta, mode: int, rank: int,
                        dtype_bytes: int = 4) -> int:
    """HBM bytes the scratch-carry path moves BEYOND the stream read.

    The ``(I_n, r_block)`` output tile stays VMEM-resident across the
    sequential grid (loaded once from the aliased zero buffer, stored
    once), so the only materialized intermediate is the output itself:
    ``I_n·R`` read + ``I_n·R`` write, independent of nnz.
    """
    return 2 * meta.dims[mode] * rank * dtype_bytes


def choose_oriented_variant(meta: AltoMeta, mode: int, rank: int,
                            dtype_bytes: int = 4,
                            carry_feasible: bool = True) -> Traversal:
    """Pick between the output-oriented variants by modelled HBM traffic.

    The carry traversal wins whenever its resident-output traffic is
    below the one-hot path's partials round-trip — i.e. unless the mode
    dimension dwarfs the nonzero stream (hyper-sparse long modes, where
    keeping ``(I_n, r_block)`` resident costs more than it saves) — and
    only while its VMEM footprint is satisfiable at all
    (``carry_feasible``, the plan layer's `plan.carry_fits_vmem`).
    """
    if not carry_feasible:
        return Traversal.OUTPUT_ORIENTED
    if (carry_traffic_bytes(meta, mode, rank, dtype_bytes)
            < oriented_merge_traffic_bytes(meta, mode, rank, dtype_bytes)):
        return Traversal.ORIENTED_CARRY
    return Traversal.OUTPUT_ORIENTED


def choose_pi_policy(meta: AltoMeta, rank: int, value_bytes: int = 4,
                     fast_mem_bytes: int = DEFAULT_FAST_MEM_BYTES
                     ) -> PiPolicy:
    """ALTO-PRE iff reuse is low AND factors overflow fast memory (§4.3)."""
    factor_bytes = sum(I * rank * value_bytes for I in meta.dims)
    low_reuse = tensor_reuse_class(meta) == "limited"
    if low_reuse and factor_bytes > fast_mem_bytes:
        return PiPolicy.PRE
    return PiPolicy.OTF
