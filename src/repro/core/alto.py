"""ALTO tensor: linearized storage, balanced partitioning, traversal views.

Format generation (paper §3.1) happens host-side: linearize (bit gather),
sort by the linearized index, then impose the balanced partitioning of §4.1.
The resulting `AltoTensor` is a JAX pytree whose static aux data (encoding,
partition intervals, fiber-reuse stats) drives *trace-time* selection of the
paper's adaptive execution variants — the TPU analogue of the paper's
runtime heuristics (JAX control flow must be static under jit).

Partitioning: the sorted nonzero list is cut into L equal-size segments
(perfect workload balance). Each segment's bounding box `T_l` (per-mode
closed intervals) is computed exactly; intervals of different partitions may
overlap (paper Fig. 7) — the pull-based reduction resolves the overlap.
The max interval length per mode is a *static* bound used to size the dense
`Temp` scratch (VMEM tile in the Pallas kernel).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding as enc_mod
from repro.core.encoding import AltoEncoding, make_encoding
from repro.sparse.tensor import SparseTensor


# ---------------------------------------------------------------------------
# Device-side bit scatter/gather (jnp) — mirrors encoding.linearize_np.
# ---------------------------------------------------------------------------

def delinearize(enc: AltoEncoding, words: jnp.ndarray) -> jnp.ndarray:
    """(..., n_words) u32 -> (..., N) int32 coordinates (bit scatter)."""
    out = [jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
           for _ in range(enc.ndim)]
    for r in enc.runs:
        chunk = (words[..., r.word] >> np.uint32(r.dst_shift)) & np.uint32(
            r.mask)
        out[r.mode] = out[r.mode] | (chunk << np.uint32(r.src_shift))
    return jnp.stack(out, axis=-1).astype(jnp.int32)


def linearize(enc: AltoEncoding, coords: jnp.ndarray) -> jnp.ndarray:
    """(..., N) int coords -> (..., n_words) u32 index (bit gather)."""
    c = coords.astype(jnp.uint32)
    out = [jnp.zeros(coords.shape[:-1], dtype=jnp.uint32)
           for _ in range(enc.n_words)]
    for r in enc.runs:
        chunk = (c[..., r.mode] >> np.uint32(r.src_shift)) & np.uint32(r.mask)
        out[r.word] = out[r.word] | (chunk << np.uint32(r.dst_shift))
    return jnp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# AltoTensor pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AltoMeta:
    """Hashable static metadata travelling in the pytree aux."""
    enc: AltoEncoding
    nnz: int                      # real nonzeros (before padding)
    n_partitions: int
    temp_rows: tuple[int, ...]    # per mode: max partition interval length
    fiber_reuse: tuple[float, ...]  # per mode: avg nnz per fiber

    @property
    def dims(self) -> tuple[int, ...]:
        return self.enc.dims


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AltoTensor:
    """Linearized sparse tensor, sorted by ALTO index, padded to L·chunk."""

    meta: AltoMeta
    words: jnp.ndarray        # (Mp, n_words) u32, ascending
    values: jnp.ndarray       # (Mp,)
    part_start: jnp.ndarray   # (L, N) int32 — T_l^s per partition/mode
    part_end: jnp.ndarray     # (L, N) int32 — T_l^e (inclusive)

    def tree_flatten(self):
        return ((self.words, self.values, self.part_start, self.part_end),
                self.meta)

    @classmethod
    def tree_unflatten(cls, meta, leaves):
        return cls(meta, *leaves)

    # convenience ---------------------------------------------------------
    @property
    def dims(self) -> tuple[int, ...]:
        return self.meta.dims

    @property
    def nnz(self) -> int:
        return self.meta.nnz

    @property
    def n_partitions(self) -> int:
        return self.meta.n_partitions

    def coords(self) -> jnp.ndarray:
        return delinearize(self.meta.enc, self.words)

    def storage_bytes(self) -> int:
        """Index + value storage (paper Fig. 12 accounting, real nnz)."""
        idx = self.meta.nnz * self.meta.enc.runtime_index_bits() // 8
        val = self.meta.nnz * self.values.dtype.itemsize
        return idx + val


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OrientedView:
    """Output-oriented traversal copy for one mode (paper Fig. 8 right).

    Nonzeros permuted into ascending order of the target mode (then ALTO
    order within a row for input locality). Conflict-free updates become a
    sorted segment reduction — the TPU-native form of "atomics only at
    partition boundaries".
    """
    meta: AltoMeta
    mode: int
    rows: jnp.ndarray     # (Mp,) int32 target-mode index, ascending
    words: jnp.ndarray    # (Mp, n_words) u32 permuted ALTO indices
    values: jnp.ndarray   # (Mp,)
    perm: jnp.ndarray     # (Mp,) int32 position in ALTO order (for Π reuse)

    def tree_flatten(self):
        return ((self.rows, self.words, self.values, self.perm),
                (self.meta, self.mode))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux[0], aux[1], *leaves)


# ---------------------------------------------------------------------------
# Format generation (host side)
# ---------------------------------------------------------------------------

def fiber_reuse_stats(enc: AltoEncoding, words_np: np.ndarray,
                      nnz: int) -> tuple[float, ...]:
    """Average nonzeros per fiber along each mode (paper §4.2).

    #fibers along mode n = #distinct coordinates with mode-n bits masked
    out of the linearized index — ALTO makes this a cheap masked unique.
    """
    masks = enc.mode_masks()           # (N, W)
    out = []
    w = words_np[:nnz]
    for n in range(enc.ndim):
        masked = w & ~masks[n][None, :]
        n_fibers = len(np.unique(masked, axis=0)) if nnz else 1
        out.append(float(nnz) / max(1, n_fibers))
    return tuple(out)


def build(x: SparseTensor, n_partitions: int = 8,
          compute_reuse: bool = True) -> AltoTensor:
    """ALTO format generation: linearize -> sort -> partition (paper §3.1)."""
    enc = make_encoding(x.dims)
    L = max(1, int(n_partitions))
    words = enc_mod.linearize_np(enc, x.coords)
    order = enc_mod.sort_key_np(words)
    words = words[order]
    values = np.asarray(x.values)[order]
    coords = x.coords[order]          # reordered original coords: cheaper
    M = x.nnz                         # than a delinearization pass

    # Pad to a multiple of L with value-0 copies of the last element so the
    # padded tail stays inside the final partition's bounding box.
    chunk = -(-max(M, L) // L)
    Mp = chunk * L
    if Mp > M:
        pad = Mp - M
        if M == 0:
            pad_words = np.zeros((pad, enc.n_words), dtype=np.uint32)
            pad_coords = np.zeros((pad, enc.ndim), dtype=coords.dtype)
        else:
            pad_words = np.repeat(words[-1:], pad, axis=0)
            pad_coords = np.repeat(coords[-1:], pad, axis=0)
        words = np.concatenate([words, pad_words], axis=0)
        values = np.concatenate(
            [values, np.zeros(pad, dtype=values.dtype)], axis=0)
        coords = np.concatenate([coords, pad_coords], axis=0)
    cc = coords.reshape(L, chunk, enc.ndim)
    part_start = cc.min(axis=1).astype(np.int32)          # (L, N)
    part_end = cc.max(axis=1).astype(np.int32)
    temp_rows = tuple(int((part_end[:, n] - part_start[:, n]).max()) + 1
                      for n in range(enc.ndim))

    reuse = (fiber_reuse_stats(enc, words, M) if compute_reuse
             else tuple(float("nan") for _ in range(enc.ndim)))
    meta = AltoMeta(enc=enc, nnz=M, n_partitions=L, temp_rows=temp_rows,
                    fiber_reuse=reuse)
    return AltoTensor(meta=meta,
                      words=jnp.asarray(words),
                      values=jnp.asarray(values),
                      part_start=jnp.asarray(part_start),
                      part_end=jnp.asarray(part_end))


def oriented_view(at: AltoTensor, mode: int) -> OrientedView:
    """Build the output-oriented permutation for ``mode`` (host side)."""
    words_np = np.asarray(at.words)
    values_np = np.asarray(at.values)
    coords = enc_mod.delinearize_np(at.meta.enc, words_np)
    rows = coords[:, mode]
    # stable sort by row keeps ALTO order within each row (input locality)
    order = np.argsort(rows, kind="stable")
    return OrientedView(meta=at.meta, mode=mode,
                        rows=jnp.asarray(rows[order].astype(np.int32)),
                        words=jnp.asarray(words_np[order]),
                        values=jnp.asarray(values_np[order]),
                        perm=jnp.asarray(order.astype(np.int32)))


def to_sparse(at: AltoTensor) -> SparseTensor:
    """Back to COO (drops padding)."""
    coords = np.asarray(at.coords())[:at.nnz]
    values = np.asarray(at.values)[:at.nnz]
    return SparseTensor(at.dims, coords, values)
