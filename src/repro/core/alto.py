"""ALTO tensor: linearized storage, balanced partitioning, traversal views.

Format generation (paper §3.1) = linearize (bit gather), sort by the
linearized index, then impose the balanced partitioning of §4.1. It exists
twice, bit-identically:

* ``build`` / ``oriented_view`` — host-side numpy, the parity reference;
* ``build_device`` / ``oriented_view_device`` — `jax.lax.sort` on the
  packed multi-word key (`encoding.sort_by_key`), jit-compatible with
  zero host callbacks. The paper's Fig. 13 headline (ALTO generation is
  ONE key sort) is what makes this viable on accelerators: the whole
  ingest is a linearize + a stable sort carrying values/coords, so
  nothing upstream of MTTKRP needs a NumPy round-trip and regeneration
  can sit under `jit`/`shard_map` (the prerequisite for dynamic
  relayout à la ReLATE/Dynasor).

The resulting `AltoTensor` is a JAX pytree whose static aux data (encoding,
partition intervals, fiber-reuse stats) drives *trace-time* selection of the
paper's adaptive execution variants — the TPU analogue of the paper's
runtime heuristics (JAX control flow must be static under jit). The static
meta (temp_rows, fiber_reuse) is data-dependent, so the device build ends
with one tiny host transfer — the (L, N) bounding boxes and N fiber
counts, O(L·N) scalars — while the O(nnz) stream never leaves the device.

Partitioning: the sorted nonzero list is cut into L equal-size segments
(perfect workload balance). Each segment's bounding box `T_l` (per-mode
closed intervals) is computed exactly; intervals of different partitions may
overlap (paper Fig. 7) — the pull-based reduction resolves the overlap.
The max interval length per mode is a *static* bound used to size the dense
`Temp` scratch (VMEM tile in the Pallas kernel).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding as enc_mod
from repro.core.encoding import AltoEncoding, make_encoding
from repro.sparse.tensor import SparseTensor


# ---------------------------------------------------------------------------
# Device-side bit scatter/gather (jnp) — mirrors encoding.linearize_np.
# ---------------------------------------------------------------------------

def delinearize(enc: AltoEncoding, words: jnp.ndarray) -> jnp.ndarray:
    """(..., n_words) u32 -> (..., N) int32 coordinates (bit scatter)."""
    out = [jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
           for _ in range(enc.ndim)]
    for r in enc.runs:
        chunk = (words[..., r.word] >> np.uint32(r.dst_shift)) & np.uint32(
            r.mask)
        out[r.mode] = out[r.mode] | (chunk << np.uint32(r.src_shift))
    return jnp.stack(out, axis=-1).astype(jnp.int32)


def linearize(enc: AltoEncoding, coords: jnp.ndarray) -> jnp.ndarray:
    """(..., N) int coords -> (..., n_words) u32 index (bit gather)."""
    c = coords.astype(jnp.uint32)
    out = [jnp.zeros(coords.shape[:-1], dtype=jnp.uint32)
           for _ in range(enc.n_words)]
    for r in enc.runs:
        chunk = (c[..., r.mode] >> np.uint32(r.src_shift)) & np.uint32(r.mask)
        out[r.word] = out[r.word] | (chunk << np.uint32(r.dst_shift))
    return jnp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# AltoTensor pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AltoMeta:
    """Hashable static metadata travelling in the pytree aux."""
    enc: AltoEncoding
    nnz: int                      # real nonzeros (before padding)
    n_partitions: int
    temp_rows: tuple[int, ...]    # per mode: max partition interval length
    fiber_reuse: tuple[float, ...]  # per mode: avg nnz per fiber

    @property
    def dims(self) -> tuple[int, ...]:
        return self.enc.dims


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AltoTensor:
    """Linearized sparse tensor, sorted by ALTO index, padded to L·chunk."""

    meta: AltoMeta
    words: jnp.ndarray        # (Mp, n_words) u32, ascending
    values: jnp.ndarray       # (Mp,)
    part_start: jnp.ndarray   # (L, N) int32 — T_l^s per partition/mode
    part_end: jnp.ndarray     # (L, N) int32 — T_l^e (inclusive)

    def tree_flatten(self):
        return ((self.words, self.values, self.part_start, self.part_end),
                self.meta)

    @classmethod
    def tree_unflatten(cls, meta, leaves):
        return cls(meta, *leaves)

    # convenience ---------------------------------------------------------
    @property
    def dims(self) -> tuple[int, ...]:
        return self.meta.dims

    @property
    def nnz(self) -> int:
        return self.meta.nnz

    @property
    def n_partitions(self) -> int:
        return self.meta.n_partitions

    def coords(self) -> jnp.ndarray:
        return delinearize(self.meta.enc, self.words)

    def storage_bytes(self) -> int:
        """Index + value storage (paper Fig. 12 accounting, real nnz)."""
        idx = self.meta.nnz * self.meta.enc.runtime_index_bits() // 8
        val = self.meta.nnz * self.values.dtype.itemsize
        return idx + val


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OrientedView:
    """Output-oriented traversal copy for one mode (paper Fig. 8 right).

    Nonzeros permuted into ascending order of the target mode (then ALTO
    order within a row for input locality). Conflict-free updates become a
    sorted segment reduction — the TPU-native form of "atomics only at
    partition boundaries".
    """
    meta: AltoMeta
    mode: int
    rows: jnp.ndarray     # (Mp,) int32 target-mode index, ascending
    words: jnp.ndarray    # (Mp, n_words) u32 permuted ALTO indices
    values: jnp.ndarray   # (Mp,)
    perm: jnp.ndarray     # (Mp,) int32 position in ALTO order (for Π reuse)

    def tree_flatten(self):
        return ((self.rows, self.words, self.values, self.perm),
                (self.meta, self.mode))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(aux[0], aux[1], *leaves)


# ---------------------------------------------------------------------------
# Format generation (host side)
# ---------------------------------------------------------------------------

def fiber_reuse_stats(enc: AltoEncoding, words_np: np.ndarray,
                      nnz: int) -> tuple[float, ...]:
    """Average nonzeros per fiber along each mode (paper §4.2).

    #fibers along mode n = #distinct coordinates with mode-n bits masked
    out of the linearized index. Counted by a masked packed-key sort +
    adjacent-diff (`encoding.count_distinct_np`) — same result as the
    old ``np.unique(axis=0)`` void-view scan, which was the dominant
    ``build(compute_reuse=True)`` cost on large tensors (unique built
    and hashed an (M, W·4)-byte view per mode; the packed sort is one
    u64 argsort-free ``np.sort``).
    """
    masks = enc.mode_masks()           # (N, W)
    out = []
    w = words_np[:nnz]
    for n in range(enc.ndim):
        masked = w & ~masks[n][None, :]
        n_fibers = enc_mod.count_distinct_np(masked) if nnz else 1
        out.append(float(nnz) / max(1, n_fibers))
    return tuple(out)


def build(x: SparseTensor, n_partitions: int = 8,
          compute_reuse: bool = True) -> AltoTensor:
    """ALTO format generation: linearize -> sort -> partition (paper §3.1)."""
    enc = make_encoding(x.dims)
    L = max(1, int(n_partitions))
    words = enc_mod.linearize_np(enc, x.coords)
    order = enc_mod.sort_key_np(words)
    words = words[order]
    values = np.asarray(x.values)[order]
    coords = x.coords[order]          # reordered original coords: cheaper
    M = x.nnz                         # than a delinearization pass

    # Pad to a multiple of L with value-0 copies of the last element so the
    # padded tail stays inside the final partition's bounding box.
    chunk = -(-max(M, L) // L)
    Mp = chunk * L
    if Mp > M:
        pad = Mp - M
        if M == 0:
            pad_words = np.zeros((pad, enc.n_words), dtype=np.uint32)
            pad_coords = np.zeros((pad, enc.ndim), dtype=coords.dtype)
        else:
            pad_words = np.repeat(words[-1:], pad, axis=0)
            pad_coords = np.repeat(coords[-1:], pad, axis=0)
        words = np.concatenate([words, pad_words], axis=0)
        values = np.concatenate(
            [values, np.zeros(pad, dtype=values.dtype)], axis=0)
        coords = np.concatenate([coords, pad_coords], axis=0)
    cc = coords.reshape(L, chunk, enc.ndim)
    part_start = cc.min(axis=1).astype(np.int32)          # (L, N)
    part_end = cc.max(axis=1).astype(np.int32)
    temp_rows = tuple(int((part_end[:, n] - part_start[:, n]).max()) + 1
                      for n in range(enc.ndim))

    reuse = (fiber_reuse_stats(enc, words, M) if compute_reuse
             else tuple(float("nan") for _ in range(enc.ndim)))
    meta = AltoMeta(enc=enc, nnz=M, n_partitions=L, temp_rows=temp_rows,
                    fiber_reuse=reuse)
    return AltoTensor(meta=meta,
                      words=jnp.asarray(words),
                      values=jnp.asarray(values),
                      part_start=jnp.asarray(part_start),
                      part_end=jnp.asarray(part_end))


def oriented_view(at: AltoTensor, mode: int) -> OrientedView:
    """Build the output-oriented permutation for ``mode`` (host side).

    Only the target mode's bit runs are decoded (`encoding.extract_mode`,
    shared with the device path) — a full delinearize just to read one
    column was the old cost here.
    """
    words_np = np.asarray(at.words)
    values_np = np.asarray(at.values)
    rows = enc_mod.extract_mode(at.meta.enc, words_np, mode)
    # stable sort by row keeps ALTO order within each row (input locality)
    order = np.argsort(rows, kind="stable")
    return OrientedView(meta=at.meta, mode=mode,
                        rows=jnp.asarray(rows[order].astype(np.int32)),
                        words=jnp.asarray(words_np[order]),
                        values=jnp.asarray(values_np[order]),
                        perm=jnp.asarray(order.astype(np.int32)))


# ---------------------------------------------------------------------------
# Format generation (device side): jittable linearize -> sort -> partition
# ---------------------------------------------------------------------------

# Jitted ingest cores, keyed on static meta only (encoding, partition
# count, nnz, dtypes) — one trace per meta, then jit's C++ fast path.
# LRU-bounded: a streaming ingest loop sees a distinct nnz (hence key)
# per tensor, and an unbounded map would pin one compiled executable
# per size forever.
_DEVICE_INGEST_FNS: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_DEVICE_INGEST_FNS_MAX = 128
_DEVICE_INGEST_TRACES = {"build": 0, "view": 0, "merge": 0}
# Concurrent serving drivers ingest in parallel; the OrderedDict
# move_to_end/popitem pair is not atomic, so guard all mutations.
_DEVICE_INGEST_LOCK = threading.Lock()


def _cached_ingest_fn(key: tuple, build):
    with _DEVICE_INGEST_LOCK:
        fn = _DEVICE_INGEST_FNS.get(key)
        if fn is None:
            fn = _DEVICE_INGEST_FNS[key] = build()
        else:
            _DEVICE_INGEST_FNS.move_to_end(key)
        while len(_DEVICE_INGEST_FNS) > _DEVICE_INGEST_FNS_MAX:
            _DEVICE_INGEST_FNS.popitem(last=False)
        return fn


def device_ingest_traces() -> dict[str, int]:
    """Trace counts of the jitted build/view cores (tests pin the
    once-per-meta contract with this; the serving layer pins its
    one-trace-per-shape-class contract with before/after deltas)."""
    with _DEVICE_INGEST_LOCK:
        return dict(_DEVICE_INGEST_TRACES)


def _build_device_fn(enc: AltoEncoding, L: int, M: int,
                     compute_reuse: bool, val_dtype):
    """The cached jitted device-build core for one static meta."""
    key = ("build", enc, L, M, bool(compute_reuse),
           jnp.dtype(val_dtype).name)
    N, W = enc.ndim, enc.n_words
    chunk = -(-max(M, L) // L)
    Mp = chunk * L
    # Host-precomputed complement masks: which index bits do NOT belong
    # to each mode (fiber counting masks the mode out of the key).
    not_masks = ~enc.mode_masks()                        # (N, W) u32

    def core(coords, values):
        _DEVICE_INGEST_TRACES["build"] += 1              # trace-time only
        words = linearize(enc, coords)                   # (M, W) u32
        ccols = [coords[:, n].astype(jnp.int32) for n in range(N)]
        words, values, *ccols = enc_mod.sort_by_key(words, values, *ccols)
        if Mp > M:
            # Same padding rule as build(): value-0 copies of the last
            # element so the tail stays inside the final bounding box.
            pad = Mp - M
            if M == 0:
                pw = jnp.zeros((pad, W), jnp.uint32)
                pc = [jnp.zeros((pad,), jnp.int32)] * N
            else:
                pw = jnp.broadcast_to(words[-1:], (pad, W))
                pc = [jnp.broadcast_to(c[-1:], (pad,)) for c in ccols]
            words = jnp.concatenate([words, pw])
            values = jnp.concatenate(
                [values, jnp.zeros((pad,), values.dtype)])
            ccols = [jnp.concatenate([c, p]) for c, p in zip(ccols, pc)]
        cc = jnp.stack(ccols, axis=-1).reshape(L, chunk, N)
        part_start = jnp.min(cc, axis=1).astype(jnp.int32)
        part_end = jnp.max(cc, axis=1).astype(jnp.int32)
        if compute_reuse and M > 0:
            fibers = jnp.stack([
                enc_mod.count_distinct(
                    words[:M] & jnp.asarray(not_masks[n])[None, :])
                for n in range(N)])
        else:
            fibers = jnp.ones((N,), jnp.int32)
        return words, values, part_start, part_end, fibers

    return _cached_ingest_fn(key, lambda: jax.jit(core))


def build_device(x: SparseTensor, n_partitions: int = 8,
                 compute_reuse: bool = True) -> AltoTensor:
    """ALTO format generation on device — `build`'s jittable twin.

    linearize (jnp bit gather) → ONE stable multi-word key sort carrying
    values + coordinate columns (`encoding.sort_by_key`) → reshaped
    min/max partition bounding boxes, all inside a single jitted core
    with zero host callbacks, traced once per (encoding, L, nnz, dtype).
    Bit-identical to `build` — same element order (stable sort, so
    duplicate linearized keys keep COO input order), same padding, same
    static meta (the (L, N) bounding boxes and N fiber counts are the
    only host transfer, to finalize the hashable `AltoMeta`).
    """
    enc = make_encoding(x.dims)
    L = max(1, int(n_partitions))
    M = x.nnz
    coords = jnp.asarray(x.coords)
    values = jnp.asarray(x.values)
    fn = _build_device_fn(enc, L, M, compute_reuse, values.dtype)
    words, vals, part_start, part_end, fibers = fn(coords, values)
    ps = np.asarray(part_start)                          # (L, N): tiny
    pe = np.asarray(part_end)
    temp_rows = tuple(int((pe[:, n] - ps[:, n]).max()) + 1
                      for n in range(enc.ndim))
    if compute_reuse:
        reuse = tuple(float(M) / max(1, int(f)) for f in np.asarray(fibers))
    else:
        reuse = tuple(float("nan") for _ in range(enc.ndim))
    meta = AltoMeta(enc=enc, nnz=M, n_partitions=L, temp_rows=temp_rows,
                    fiber_reuse=reuse)
    return AltoTensor(meta=meta, words=words, values=vals,
                      part_start=part_start, part_end=part_end)


def _view_device_fn(enc: AltoEncoding, mode: int, Mp: int, val_dtype):
    """The cached jitted oriented-view core for one static meta/mode."""
    key = ("view", enc, mode, Mp, jnp.dtype(val_dtype).name)
    W = enc.n_words

    def core(words, values):
        _DEVICE_INGEST_TRACES["view"] += 1               # trace-time only
        rows = enc_mod.extract_mode(enc, words, mode)    # (Mp,) int32
        perm0 = jnp.arange(Mp, dtype=jnp.int32)
        cols = [words[:, w] for w in range(W)]
        res = jax.lax.sort((rows, *cols, values, perm0), num_keys=1,
                           is_stable=True)
        return (res[0], jnp.stack(res[1:1 + W], axis=-1), res[1 + W],
                res[2 + W])

    return _cached_ingest_fn(key, lambda: jax.jit(core))


def oriented_view_device(at: AltoTensor, mode: int) -> OrientedView:
    """Output-oriented permutation for ``mode``, built on device.

    Target-mode rows come from a masked bit-extract of the words
    (`encoding.extract_mode` — no full delinearize), then ONE stable
    `lax.sort` by row carries the words, values, and the Π permutation
    (an iota, which IS the stable argsort). Stability keeps ALTO order
    within each row — bit-identical to the host `oriented_view`,
    duplicate-coordinate ties included. Jit-compatible, zero host
    callbacks, traced once per (encoding, mode, Mp, dtype).
    """
    fn = _view_device_fn(at.meta.enc, mode, at.words.shape[0],
                         at.values.dtype)
    rows, words, values, perm = fn(at.words, at.values)
    return OrientedView(meta=at.meta, mode=mode, rows=rows, words=words,
                        values=values, perm=perm)


def to_sparse(at: AltoTensor) -> SparseTensor:
    """Back to COO (drops padding)."""
    coords = np.asarray(at.coords())[:at.nnz]
    values = np.asarray(at.values)[:at.nnz]
    return SparseTensor(at.dims, coords, values)


# ---------------------------------------------------------------------------
# Incremental-ingest host reference (core.ingest's parity oracle)
# ---------------------------------------------------------------------------

MERGE_POLICIES = ("sum", "last")


def grown_dims(dims: Sequence[int], coords,
               override: Sequence[int] | None = None) -> tuple[int, ...]:
    """Smallest extents covering ``dims`` and every delta coordinate.

    ``override`` fixes the result explicitly (it must cover both); by
    default extents grow exactly as far as the delta reaches. Extent
    growth can change `make_encoding`'s bit assignment, which is why the
    merge paths re-linearize the resident stream when the encoding
    moves.
    """
    coords = np.asarray(coords)
    need = list(int(d) for d in dims)
    if coords.size:
        mx = coords.reshape(-1, len(need)).max(axis=0)
        need = [max(d, int(m) + 1) for d, m in zip(need, mx)]
    if override is None:
        return tuple(need)
    out = tuple(int(d) for d in override)
    if len(out) != len(need) or any(o < n for o, n in zip(out, need)):
        raise ValueError(f"dims override {out} does not cover required "
                         f"extents {tuple(need)}")
    return out


def merge_coo(x: SparseTensor, coords, values, policy: str = "sum",
              dims: Sequence[int] | None = None) -> SparseTensor:
    """The merged COO an append denotes: resident entries (in stream
    order) followed by the delta batch (in input order), with the
    duplicate policy applied over FULL coordinates (equal linearized
    keys).

    * ``"sum"`` — every entry is kept; after the key sort duplicates sit
      adjacent and accumulate in every downstream reduction (exactly how
      `build` already treats duplicate-coordinate COO input).
    * ``"last"`` — the last-written entry of each duplicate group keeps
      its value and every earlier one is masked to value 0. A pure mask
      (no arithmetic), so the jitted merge reproduces it bit-for-bit;
      value-0 entries are inert in MTTKRP/Φ/likelihood, and writing
      value 0 acts as a delete.

    The entry count is always ``x.nnz + len(values)``: compaction would
    make the merged size data-dependent, which the static-shape jitted
    merge core cannot express.
    """
    if policy not in MERGE_POLICIES:
        raise ValueError(f"policy {policy!r}: expected one of "
                         f"{MERGE_POLICIES}")
    coords = np.asarray(coords, dtype=np.int32).reshape(-1, x.ndim)
    values = np.asarray(values).astype(x.values.dtype, copy=False)
    new_dims = grown_dims(x.dims, coords, dims)
    all_c = np.concatenate([x.coords, coords], axis=0)
    all_v = np.concatenate([x.values, values], axis=0)
    if policy == "last" and all_v.shape[0] > 1:
        enc = make_encoding(new_dims)
        words = enc_mod.linearize_np(enc, all_c)
        order = enc_mod.sort_key_np(words)
        srt = words[order]
        is_last = np.concatenate(
            [np.any(srt[1:] != srt[:-1], axis=-1), [True]])
        keep = np.zeros(all_v.shape[0], dtype=bool)
        keep[order] = is_last
        all_v = np.where(keep, all_v, np.zeros_like(all_v))
    return SparseTensor(new_dims, all_c, all_v)


def merge_reference(at: AltoTensor, coords, values, policy: str = "sum",
                    dims: Sequence[int] | None = None,
                    n_partitions: int | None = None,
                    compute_reuse: bool = True) -> AltoTensor:
    """From-scratch host rebuild of an append — `core.ingest.append_delta`'s
    bit-for-bit parity reference: the standard numpy `build` over
    `merge_coo`'s concatenated COO, under the grown dims. The jitted
    merge's one stable sort of [resident stream; delta batch] must equal
    this stable sort of the same multiset in the same input order —
    stream, values, partition boxes, and meta all bit-identical.
    """
    x = to_sparse(at)
    merged = merge_coo(x, coords, values, policy=policy,
                       dims=grown_dims(x.dims, coords, dims))
    L = at.meta.n_partitions if n_partitions is None else n_partitions
    return build(merged, n_partitions=L, compute_reuse=compute_reuse)
