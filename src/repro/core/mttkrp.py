"""MTTKRP and the generic ALTO sparse row-reduction engine (paper Alg. 3/4).

Every ALTO tensor kernel in this framework (MTTKRP for CP-ALS, Φ for CP-APR)
has the shape: *per-nonzero contribution of R values, reduced by the target
mode row*. The two paper traversals are implemented as:

  * recursive      — ALTO-ordered chunks per balanced partition, local dense
                     ``Temp`` buffers bounded by the partition's mode
                     interval, then a pull-based reduction into the output
                     (Alg. 4 lines 6 / 14-18).
  * output-oriented— nonzeros permuted by target row; conflict-free updates
                     become a sorted segment reduction (the TPU-native form
                     of "atomics only at partition boundaries").

`mttkrp_adaptive` picks the traversal per mode from fiber-reuse statistics
(heuristics.choose_traversal) at trace time.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import heuristics
from repro.core.alto import AltoTensor, OrientedView, delinearize


def krp_rows(coords: jnp.ndarray, factors: Sequence[jnp.ndarray],
             mode: int) -> jnp.ndarray:
    """Khatri-Rao rows: prod_{m != mode} A^(m)[i_m, :]  -> (..., R)."""
    out = None
    for m, A in enumerate(factors):
        if m == mode:
            continue
        rows = A[coords[..., m]]
        out = rows if out is None else out * rows
    return out


# ---------------------------------------------------------------------------
# Baseline: COO scatter-add (the paper's list-based baseline, §2.3.1)
# ---------------------------------------------------------------------------

def mttkrp_coo(coords: jnp.ndarray, values: jnp.ndarray,
               factors: Sequence[jnp.ndarray], mode: int) -> jnp.ndarray:
    """COO MTTKRP: unordered scatter-add (XLA scatter ~ CPU atomics)."""
    contrib = values[:, None] * krp_rows(coords, factors, mode)
    out_dim = factors[mode].shape[0]
    out = jnp.zeros((out_dim, contrib.shape[-1]), dtype=contrib.dtype)
    return out.at[coords[:, mode]].add(contrib)


# ---------------------------------------------------------------------------
# Generic ALTO row reductions
# ---------------------------------------------------------------------------

def row_reduce_recursive(at: AltoTensor, mode: int,
                         contrib: jnp.ndarray) -> jnp.ndarray:
    """Reduce (Mp, R) contributions by target row, recursive traversal.

    Per partition l: Temp_l[i - T_l^s, :] += contrib (Alg. 4 line 6), then
    out[b, :] += Temp_l[b - T_l^s, :] for all overlapping l (lines 14-18).
    """
    meta = at.meta
    L = meta.n_partitions
    Mp = at.words.shape[0]
    chunk = Mp // L
    R = contrib.shape[-1]
    I_n = meta.dims[mode]
    T = meta.temp_rows[mode]

    coords = delinearize(meta.enc, at.words)
    rows = coords[:, mode].reshape(L, chunk)
    local = rows - at.part_start[:, mode][:, None]          # in [0, T)
    c = contrib.reshape(L, chunk, R)

    def one_partition(loc, con):
        return jnp.zeros((T, R), dtype=con.dtype).at[loc].add(con)

    temp = jax.vmap(one_partition)(local, c)                 # (L, T, R)

    # Pull-based reduction. Rows past the partition interval hold zeros;
    # clamp their global index so the scatter stays in bounds (adds zeros).
    out_rows = at.part_start[:, mode][:, None] + jnp.arange(T)[None, :]
    out_rows = jnp.minimum(out_rows, I_n - 1)                # (L, T)
    out = jnp.zeros((I_n, R), dtype=contrib.dtype)
    return out.at[out_rows].add(temp)


def row_reduce_oriented(view: OrientedView,
                        contrib: jnp.ndarray) -> jnp.ndarray:
    """Reduce (Mp, R) contributions by target row, output-oriented order.

    `contrib` must already be in the view's (row-sorted) element order.
    Sorted segment-sum == conflict-free updates with boundary merges.
    """
    I_n = view.meta.dims[view.mode]
    return jax.ops.segment_sum(contrib, view.rows, num_segments=I_n,
                               indices_are_sorted=True)


# ---------------------------------------------------------------------------
# MTTKRP variants
# ---------------------------------------------------------------------------

def mttkrp_recursive(at: AltoTensor, factors: Sequence[jnp.ndarray],
                     mode: int) -> jnp.ndarray:
    coords = delinearize(at.meta.enc, at.words)
    contrib = at.values[:, None] * krp_rows(coords, factors, mode)
    return row_reduce_recursive(at, mode, contrib)


def mttkrp_oriented(view: OrientedView, factors: Sequence[jnp.ndarray]
                    ) -> jnp.ndarray:
    coords = delinearize(view.meta.enc, view.words)
    contrib = view.values[:, None] * krp_rows(coords, factors, view.mode)
    return row_reduce_oriented(view, contrib)


def mttkrp_adaptive(at: AltoTensor,
                    views: dict[int, OrientedView] | None,
                    factors: Sequence[jnp.ndarray], mode: int,
                    plan=None) -> jnp.ndarray:
    """Adaptive conflict resolution (paper §4.2), selected at trace time.

    With a ``plan`` (see `core.plan.make_plan`) the resolved kernel routing
    is used — including the Pallas backends; without one, the heuristic
    picks between the two pure-jnp traversals below (the plan layer's
    reference backend).
    """
    if plan is not None:
        from repro.core import plan as plan_mod
        return plan_mod.execute_mttkrp(plan, at, views, factors, mode)
    choice = heuristics.choose_traversal(at.meta, mode)
    if (choice is heuristics.Traversal.OUTPUT_ORIENTED and views
            and mode in views):
        return mttkrp_oriented(views[mode], factors)
    return mttkrp_recursive(at, factors, mode)


def dense_mttkrp_reference(dense, factors: Sequence[jnp.ndarray],
                           mode: int) -> jnp.ndarray:
    """Oracle: matricized-dense einsum MTTKRP (tests only)."""
    import numpy as np
    dense = jnp.asarray(dense)
    N = dense.ndim
    letters = "abcdefghij"[:N]
    out = None
    # X_(n) (KRP of others) == einsum over all other modes with their factor
    operands = []
    subs = [letters]
    for m in range(N):
        if m == mode:
            continue
        operands.append(factors[m])
        subs.append(letters[m] + "r")
    expr = ",".join(subs) + "->" + letters[mode] + "r"
    return jnp.einsum(expr, dense, *operands)
