"""Budgeted plan search: a seeded GA over plan candidates + a learned
cost model, replacing exhaustive candidate timing.

The exhaustive tuner (`core.autotune.tune_plan`) times EVERY feasible
(traversal × r_block × block_m) candidate per mode. That does not
survive the plan space the later tiers created — × chunk_m for
streaming plans, × shape class for serving — so this module spends a
*measurement budget* (run count and/or wall-clock seconds) instead:

* **Genome** — per-mode genes are (traversal, r_block, block_m)
  triples drawn from the feasible pool `plan.candidate_mode_plans`
  already prunes by the per-kernel VMEM models; streaming plans add a
  genome-level ``chunk_m`` gene (block-aligned, byte-model-clamped by
  `plan.choose_chunk_m`). Mutation and crossover operate on the raw
  triple, then a **repair step** snaps the child to the nearest pool
  member — re-applying `plan.carry_fits_vmem` and the VMEM/byte-model
  feasibility by construction, so no infeasible candidate is ever timed.
* **Fitness** — measured wall-clock through the same protocol as the
  exhaustive tuner: one cached executable per candidate plan,
  `ops.timing_stats` (median, IQR) of blocking calls after warmup.
  Per-(mode, gene, chunk) measurements are memoized, so re-visiting a
  gene is free; the fitness of a full plan is separable across modes
  (each mode's kernel runs independently), which is what lets a
  per-mode GA share one global budget.
* **Cost model** — ridge regression on log-seconds over analytic
  features of (meta fingerprint, gene): nnz, density, mode extents,
  fiber-reuse stats, the modelled HBM traffic of the gene's traversal,
  its VMEM footprint, tile/chunk geometry. Fit from the measurement
  samples persisted in the plan store (every exhaustive OR search run
  contributes), so the model **transfers across tensors**: a new tensor
  with a warm store gets model-ranked candidates before any
  measurement, and ``budget_runs=0`` returns a zero-measurement
  model-picked plan. The model only decides *what to measure*
  (pre-ranking the population so just the top-k per generation are
  timed); the plan store stays the ground truth.
* **Seeding** — the population starts from the static analytic gene
  (always measured first, so the search winner is never worse than the
  static choice under the measurement whenever the budget allows ≥ 1
  run per mode) plus the winners of the nearest store records by
  meta-feature distance (same ndim; log-dims/log-nnz/log-rank).

Every measurement is appended as a JSONL record under
``$REPRO_TUNE_LOG`` (generation, candidate, predicted vs measured,
budget spent) — greppable observability for tuning regressions.

On CPU the kernels run under the Pallas interpreter, so both the
measurements and the model trained on them are *proxy* rankings
(docs/known-issues.md); on TPU the same protocol measures real Mosaic
executables.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import pathlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heuristics
from repro.core import mttkrp as core_mttkrp
from repro.core import plan as plan_mod
from repro.core.alto import AltoMeta, AltoTensor, delinearize

TUNE_LOG_ENV = "REPRO_TUNE_LOG"

DEFAULT_GENERATIONS = 4
DEFAULT_POPULATION = 8
DEFAULT_TOP_K = 2            # measured candidates per mode per generation
DEFAULT_MUTATE_P = 0.35
MODEL_MIN_SAMPLES = 8        # below this the model stays unfit (prior order)
RIDGE_LAMBDA = 1e-2
MAX_RECORD_SAMPLES = 48      # samples persisted per store record (capped)
MAX_CHUNK_CANDIDATES = 4     # halving ladder below the byte-model maximum
N_FEATURES = 18


# ---------------------------------------------------------------------------
# JSONL experiment log ($REPRO_TUNE_LOG)
# ---------------------------------------------------------------------------

class TuneLogger:
    """Append-only JSONL experiment log; disabled when no path is set.

    One line per event (``search_start`` / ``measure`` / ``search_end``),
    flat JSON with sorted keys so the log greps and diffs cleanly.
    """

    def __init__(self, path=None):
        p = path if path is not None else os.environ.get(TUNE_LOG_ENV)
        self.path = pathlib.Path(p).expanduser() if p else None
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        return self.path is not None

    def write(self, event: str, **fields) -> None:
        if self.path is None:
            return
        fields["event"] = event
        fields["ts"] = time.time()
        line = json.dumps(fields, sort_keys=True)
        with self._lock:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with open(self.path, "a") as f:
                f.write(line + "\n")


# ---------------------------------------------------------------------------
# Measurement budget
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SearchBudget:
    """Measurement budget: run count and/or wall-clock seconds.

    ``None`` means unlimited on that axis; both None means the caller
    gets the default run budget (25% of the feasible space, at least
    two runs per mode). ``max_runs=0`` is the zero-measurement warm
    start: nothing is timed, the cost model picks the plan.
    """
    max_runs: int | None = None
    max_seconds: float | None = None
    runs_used: int = 0
    seconds_used: float = 0.0

    def allows(self) -> bool:
        if self.max_runs is not None and self.runs_used >= self.max_runs:
            return False
        if (self.max_seconds is not None
                and self.seconds_used >= self.max_seconds):
            return False
        return True

    def charge(self, seconds: float) -> None:
        self.runs_used += 1
        self.seconds_used += seconds


# ---------------------------------------------------------------------------
# Analytic candidate features + the ridge cost model
# ---------------------------------------------------------------------------

def gene_features(meta: AltoMeta, rank: int, mode: int,
                  traversal: heuristics.Traversal, r_block: int,
                  block_m: int, *, chunk_m: int = 0,
                  objective: str = "mttkrp",
                  dtype_bytes: int = 4) -> list[float]:
    """Feature vector of one (tensor, mode, gene) pair — all analytic,
    computable with zero measurements, so predictions transfer to
    never-measured tensors through the shared feature space."""
    log = math.log
    M = heuristics.stream_len(meta)
    dims = meta.dims
    log_vol = sum(log(d) for d in dims)            # log ∏ dims, no overflow
    density = log(max(meta.nnz, 1)) - log_vol
    if traversal is heuristics.Traversal.RECURSIVE:
        traffic = plan_mod.recursive_vmem_bytes(meta, mode, r_block,
                                                dtype_bytes)
    elif traversal is heuristics.Traversal.ORIENTED_CARRY:
        traffic = heuristics.carry_traffic_bytes(meta, mode, rank,
                                                 dtype_bytes)
    else:
        traffic = heuristics.oriented_merge_traffic_bytes(meta, mode, rank,
                                                          dtype_bytes)
    vmem = plan_mod._mode_plan(meta, mode, rank, traversal, r_block,
                               block_m, dtype_bytes, False).vmem_bytes
    n_chunks = plan_mod.chunk_count(meta, chunk_m) if chunk_m else 1
    return [
        1.0,                                           # bias
        log(max(meta.nnz, 1)),
        log(max(M, 1)),
        log(dims[mode]),
        log(sum(dims)),
        density,
        float(meta.fiber_reuse[mode]),
        float(np.mean(meta.fiber_reuse)),
        log(rank),
        log(r_block),
        log(block_m),
        log(max(1, -(-M // block_m))),                 # oriented grid steps
        1.0 if traversal is heuristics.Traversal.RECURSIVE else 0.0,
        1.0 if traversal is heuristics.Traversal.ORIENTED_CARRY else 0.0,
        log(max(traffic + M * plan_mod.stream_elem_bytes(meta,
                                                         dtype_bytes), 1)),
        log(max(vmem, 1)),
        log(max(n_chunks, 1)),
        1.0 if objective == "phi" else 0.0,
    ]


class CostModel:
    """Ridge regression on log-seconds over `gene_features` vectors.

    Closed-form fit on standardized features (numpy only). Unfit until
    ``MODEL_MIN_SAMPLES`` samples exist — predictions return None then
    and the search falls back to the pool's analytic prior order.
    """

    def __init__(self):
        self._X: list[list[float]] = []
        self._y: list[float] = []
        self._w = None
        self._mu = None
        self._sd = None

    @property
    def n_samples(self) -> int:
        return len(self._y)

    @property
    def ready(self) -> bool:
        return self._w is not None

    def add_sample(self, features, seconds: float) -> None:
        if len(features) != N_FEATURES or not (seconds > 0):
            return                      # malformed store sample: skip
        self._X.append([float(f) for f in features])
        self._y.append(math.log(seconds))
        self._w = None                  # stale until the next fit

    def fit(self) -> bool:
        if len(self._y) < MODEL_MIN_SAMPLES:
            return False
        X = np.asarray(self._X, dtype=np.float64)
        y = np.asarray(self._y, dtype=np.float64)
        mu = X.mean(axis=0)
        sd = X.std(axis=0)
        sd[sd < 1e-12] = 1.0
        mu[0], sd[0] = 0.0, 1.0         # keep the bias column as-is
        Z = (X - mu) / sd
        A = Z.T @ Z + RIDGE_LAMBDA * len(y) * np.eye(N_FEATURES)
        try:
            self._w = np.linalg.solve(A, Z.T @ y)
        except np.linalg.LinAlgError:
            return False
        self._mu, self._sd = mu, sd
        return True

    def predict(self, features) -> float | None:
        """Predicted seconds, or None while unfit."""
        if self._w is None:
            return None
        z = (np.asarray(features, dtype=np.float64) - self._mu) / self._sd
        return float(math.exp(float(z @ self._w)))


def model_from_store(plans: dict, platform: str | None = None) -> CostModel:
    """Cost model trained on every sample persisted in the plan store.

    Samples are gated on the platform they were measured on — a CPU
    proxy sample must never train a model that ranks TPU candidates.
    """
    platform = platform or jax.default_backend()
    model = CostModel()
    for record in plans.values():
        if not isinstance(record, dict):
            continue
        meta_p = (record.get("tuned") or {}).get("platform")
        if meta_p is not None and meta_p != platform:
            continue
        for sample in record.get("samples") or []:
            try:
                model.add_sample(sample["f"], float(sample["s"]))
            except (KeyError, TypeError, ValueError):
                continue
    model.fit()
    return model


def store_neighbors(plans: dict, meta: AltoMeta, rank: int, *,
                    objective: str = "mttkrp",
                    limit: int = 3) -> list[dict]:
    """Nearest store records by meta-feature distance (same ndim only).

    Distance: Σ|Δlog dims| + |Δlog nnz| + |Δlog rank| — the fingerprint
    axes a plan decision actually reads. Their winning mode genes seed
    the GA population, so a tensor similar to an already-tuned one
    starts the search at (a neighborhood of) that tensor's winner.
    """
    scored = []
    for record in plans.values():
        if not isinstance(record, dict):
            continue
        dims = record.get("dims")
        if (not isinstance(dims, list) or len(dims) != len(meta.dims)
                or not record.get("modes")):
            continue
        obj = (record.get("tuned") or {}).get("objective")
        if obj is not None and obj != objective:
            continue
        try:
            d = sum(abs(math.log(int(a)) - math.log(b))
                    for a, b in zip(dims, meta.dims))
            d += abs(math.log(max(int(record.get("nnz", 1)), 1))
                     - math.log(max(meta.nnz, 1)))
            d += abs(math.log(max(int(record.get("rank", rank)), 1))
                     - math.log(rank))
        except (TypeError, ValueError):
            continue
        scored.append((d, record))
    scored.sort(key=lambda t: t[0])
    return [r for _, r in scored[:limit]]


# ---------------------------------------------------------------------------
# The candidate pools (feasible-by-construction gene spaces)
# ---------------------------------------------------------------------------

def _dedupe_pool(pool, backend: str, objective: str,
                 streaming: bool):
    """Collapse genes that time identically — same rules the exhaustive
    tuner applies, so budgets are spent on distinguishable candidates.

    Reference-backend chunked executors have no tiling knobs at all
    (one gene); in-core reference collapses to one per traversal
    family; the fused Φ kernel has no rank tiling (r_block is dead)."""
    if backend == "reference":
        if streaming:
            key = lambda g: ()                               # noqa: E731
        else:
            key = lambda g: (                                # noqa: E731
                "oriented" if heuristics.is_oriented(g.traversal)
                else g.traversal,)
    elif objective == "phi":
        key = lambda g: (g.traversal, g.block_m)             # noqa: E731
    else:
        return pool
    seen, out = set(), []
    for g in pool:
        k = key(g)
        if k not in seen:
            seen.add(k)
            out.append(g)
    return tuple(out)


def mode_pool(meta: AltoMeta, mode: int, rank: int, *,
              backend: str, objective: str = "mttkrp",
              dtype_bytes: int = 4,
              vmem_limit: int = plan_mod.VMEM_BYTES,
              pre_pi: bool = False,
              streaming: bool = False) -> tuple[plan_mod.ModePlan, ...]:
    """The feasible gene pool for one mode, static analytic gene FIRST.

    This IS the repair domain: every pool member already passed the
    VMEM models and the `carry_fits_vmem` gate inside
    `plan.candidate_mode_plans`, so snapping a mutated gene into the
    pool re-applies feasibility for free. Streaming pools pin the
    scratch-carry traversal (the chunked executors ARE the carry scan)
    with the static force-carry gene kept even when the carry gate
    fails (the budget turns advisory out-of-core, exactly as in
    `plan.static_mode_plan`)."""
    if not streaming:
        pool = plan_mod.candidate_mode_plans(
            meta, mode, rank, dtype_bytes=dtype_bytes,
            vmem_limit=vmem_limit, pre_pi=pre_pi)
        return _dedupe_pool(pool, backend, objective, streaming=False)
    static = plan_mod.static_mode_plan(
        meta, mode, rank, dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
        force_carry=True, pre_pi=pre_pi)
    pool = [static]
    seen = {(static.r_block, static.block_m)}
    for rb in plan_mod._divisors_desc(rank):
        bm = plan_mod.MAX_BLOCK_M
        while bm >= plan_mod.MIN_BLOCK_M:
            if ((rb, bm) not in seen
                    and plan_mod.oriented_carry_vmem_bytes(
                        meta, mode, bm, rb, dtype_bytes) <= vmem_limit):
                seen.add((rb, bm))
                pool.append(plan_mod._mode_plan(
                    meta, mode, rank, heuristics.Traversal.ORIENTED_CARRY,
                    rb, bm, dtype_bytes, pre_pi))
            bm //= 2
    return _dedupe_pool(tuple(pool), backend, objective, streaming=True)


def _gene_distance(g: plan_mod.ModePlan, traversal, r_block: int,
                   block_m: int) -> float:
    d = 0.0 if g.traversal is traversal else 4.0
    d += abs(math.log2(g.block_m) - math.log2(max(block_m, 1)))
    d += abs(math.log2(g.r_block) - math.log2(max(r_block, 1)))
    return d


def repair(pool, traversal, r_block: int, block_m: int) -> int:
    """Snap an arbitrary (traversal, r_block, block_m) triple to the
    nearest feasible pool gene (index). Deterministic: ties break to
    the earlier pool entry (the pool orders static-first, larger tiles
    first)."""
    return min(range(len(pool)),
               key=lambda i: (_gene_distance(pool[i], traversal, r_block,
                                             block_m), i))


def chunk_ladder(meta: AltoMeta, rank: int, device_bytes: int,
                 align: int, dtype_bytes: int = 4) -> list[int]:
    """Feasible chunk_m candidates: the byte-model maximum (the analytic
    choice, always first) then a halving ladder down to one block.
    Every entry is ``align``-aligned (``align`` = max block_m, a power
    of two, so chunk boundaries sit on block boundaries for every mode
    — the bitwise-parity precondition) and fits the double-buffer byte
    model by construction (smaller chunks need fewer bytes)."""
    top = plan_mod.choose_chunk_m(meta, rank, device_bytes, align,
                                  dtype_bytes)
    ladder, cm = [], top
    while cm >= align and len(ladder) < MAX_CHUNK_CANDIDATES:
        ladder.append(cm)
        nxt = ((cm // 2) // align) * align
        if nxt == cm:
            break
        cm = nxt
    return ladder


# ---------------------------------------------------------------------------
# Timing (same protocol + executable cache as the exhaustive tuner)
# ---------------------------------------------------------------------------

def _time_mttkrp(cand_plan, at, views, factors, mode, warmup, iters):
    from repro.kernels import ops
    if cand_plan.streaming is not None:
        # The chunked executors are host loops over a host-resident
        # stream — not a jit operand, so the candidate is timed as-is
        # (each per-chunk call inside is itself jitted/cached).
        def fn():
            return plan_mod.execute_mttkrp(cand_plan, at, views, factors,
                                           mode)
        return ops.timing_stats(fn, warmup=warmup, iters=iters)

    def build():
        def run(at, views, factors):
            return plan_mod.execute_mttkrp(cand_plan, at, views, factors,
                                           mode)
        return jax.jit(run)

    fn = ops._cached_executable(("tune_mttkrp", cand_plan, mode), build)
    return ops.timing_stats(fn, at, views, factors,
                            warmup=warmup, iters=iters)


def _time_phi(cand_plan, at, view, B, factors, pi, mode, warmup, iters,
              eps=1e-10):
    from repro.kernels import ops
    if cand_plan.streaming is not None:
        def fn():
            return plan_mod.execute_phi(cand_plan, at, view, B, mode,
                                        factors=factors, eps=eps)
        return ops.timing_stats(fn, warmup=warmup, iters=iters)
    pre_pi = pi is not None

    def build():
        def run(at, view, B, factors, pi):
            return plan_mod.execute_phi(
                cand_plan, at, view, B, mode,
                factors=None if pre_pi else factors, pi=pi, eps=eps)
        return jax.jit(run)

    fn = ops._cached_executable(("tune_phi", cand_plan, mode, pre_pi, eps),
                                build)
    return ops.timing_stats(fn, at, view, B, factors, pi,
                            warmup=warmup, iters=iters)


# ---------------------------------------------------------------------------
# Search report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModeWinner:
    mode: int
    traversal: str
    r_block: int
    block_m: int
    measured_s: float | None      # None on a zero-measurement warm start
    predicted_s: float | None
    is_static: bool               # the analytic gene won (or was the only)


@dataclasses.dataclass(frozen=True)
class SearchReport:
    key: str
    store: str                    # path persisted to ("" if not)
    objective: str
    backend: str
    budget_runs: int | None
    budget_s: float | None
    runs_used: int
    seconds_used: float
    generations: int
    pool_sizes: tuple[int, ...]
    model_samples: int            # training samples available at start
    model_used: bool              # the model pre-ranked candidates
    warm_start: bool              # zero measurements, model picked the plan
    neighbors: int                # store records that seeded the population
    winners: tuple[ModeWinner, ...]
    chunk_m: int | None           # streaming plans only
    chunk_candidates: int

    @property
    def best_time_s(self) -> float | None:
        """Sum of the winners' measured medians (None if any unmeasured)."""
        ts = [w.measured_s for w in self.winners]
        return None if any(t is None for t in ts) else float(sum(ts))


# ---------------------------------------------------------------------------
# The GA search
# ---------------------------------------------------------------------------

class _ModeSearch:
    """GA state for one mode: population of pool indices + memoized
    measurements. The pool is the feasible space; indices never leave
    it, so every genome is feasible by construction."""

    def __init__(self, mode, pool, rng, population, seeds):
        self.mode = mode
        self.pool = pool
        self.rng = rng
        self.size = max(2, min(population, max(2, len(pool))))
        pop = [0]                       # the static analytic gene, always
        for s in seeds:
            if s not in pop:
                pop.append(s)
        while len(pop) < self.size:
            c = int(rng.integers(len(pool)))
            if c not in pop or len(pop) >= len(pool):
                pop.append(c)
        self.population = pop[:self.size]
        self.measured: dict[int, float] = {}     # pool idx -> median_s
        self.predicted: dict[int, float | None] = {}

    def fitness(self, i: int) -> float:
        if i in self.measured:
            return self.measured[i]
        p = self.predicted.get(i)
        if p is not None:
            return p
        # Unfit model: the pool's analytic prior order (static first,
        # larger tiles first) as a pseudo-time far above any real one.
        return 1e6 * (1.0 + i)

    def to_measure(self, top_k: int, first_generation: bool) -> list[int]:
        ranked = sorted(set(self.population),
                        key=lambda i: (self.fitness(i), i))
        picks = [i for i in ranked if i not in self.measured][:top_k]
        if first_generation and 0 not in self.measured and 0 not in picks:
            picks = [0] + picks[:max(0, top_k - 1)]
        return picks

    def _tournament(self) -> int:
        a, b = (int(self.rng.integers(len(self.population)))
                for _ in range(2))
        ia, ib = self.population[a], self.population[b]
        return ia if self.fitness(ia) <= self.fitness(ib) else ib

    def evolve(self, mutate_p: float) -> None:
        if len(self.pool) <= 2:
            return                      # nothing to evolve toward
        elite = sorted(set(self.population),
                       key=lambda i: (self.fitness(i), i))[:2]
        nxt = list(elite)
        while len(nxt) < self.size:
            p1, p2 = self.pool[self._tournament()], \
                self.pool[self._tournament()]
            # Uniform crossover over the three gene fields.
            trav = p1.traversal if self.rng.random() < 0.5 else p2.traversal
            rb = p1.r_block if self.rng.random() < 0.5 else p2.r_block
            bm = p1.block_m if self.rng.random() < 0.5 else p2.block_m
            # Mutation: nudge one field.
            if self.rng.random() < mutate_p:
                field = int(self.rng.integers(3))
                if field == 0:
                    trav = self.pool[int(self.rng.integers(
                        len(self.pool)))].traversal
                elif field == 1:
                    rb = max(1, rb * 2 if self.rng.random() < 0.5
                             else rb // 2)
                else:
                    bm = min(plan_mod.MAX_BLOCK_M,
                             max(plan_mod.MIN_BLOCK_M,
                                 bm * 2 if self.rng.random() < 0.5
                                 else bm // 2))
            # Repair: snap to the nearest feasible pool gene.
            nxt.append(repair(self.pool, trav, rb, bm))
        self.population = nxt[:self.size]

    def winner(self) -> tuple[int, float | None, float | None]:
        """(pool idx, measured_s, predicted_s) — best measured gene if
        anything was measured, else the model's pick, else static."""
        if self.measured:
            i = min(self.measured, key=lambda i: (self.measured[i], i))
            return i, self.measured[i], self.predicted.get(i)
        preds = {i: p for i, p in self.predicted.items() if p is not None}
        if preds:
            i = min(preds, key=lambda i: (preds[i], i))
            return i, None, preds[i]
        return 0, None, None


def search_plan(at: AltoTensor, rank: int, *, backend: str | None = None,
                interpret: bool | None = None, dtype_bytes: int = 4,
                vmem_limit: int = plan_mod.VMEM_BYTES,
                fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
                objective: str = "mttkrp",
                device_bytes: int | None = None,
                budget_runs: int | None = None,
                budget_s: float | None = None,
                seed: int = 0,
                generations: int = DEFAULT_GENERATIONS,
                population: int = DEFAULT_POPULATION,
                top_k: int = DEFAULT_TOP_K,
                mutate_p: float = DEFAULT_MUTATE_P,
                warmup: int = 1, iters: int = 3,
                persist: bool = True, store_path=None, log_path=None,
                ) -> tuple[plan_mod.ExecutionPlan, SearchReport]:
    """Budgeted GA + cost-model plan search. Returns (plan, report).

    ``device_bytes`` non-None (and overflowing) makes the genome
    streaming: the per-mode pools pin the scratch-carry traversal and
    ``chunk_m`` joins the search space (a block-aligned halving ladder
    under the byte-model maximum, evaluated on the bottleneck mode
    after the tiling genes converge).

    Determinism: same (seed, store, tensor, budget) runs measure the
    same candidates in the same order and return the identical winning
    plan — the only nondeterminism is which candidate *times* fastest
    on the host, and the memoized measurement protocol is shared with
    the exhaustive tuner. A subsequent `make_plan(..., tune="search")`
    with the winner persisted is a store hit: zero timing runs.
    """
    from repro.core import autotune
    from repro.core import views as views_mod

    if objective not in ("mttkrp", "phi"):
        raise ValueError(f"unknown objective {objective!r}")
    meta = at.meta
    backend = backend or plan_mod.default_backend()
    streaming = (device_bytes is not None
                 and plan_mod.needs_streaming(meta, rank, device_bytes,
                                              dtype_bytes))
    if not streaming:
        device_bytes = None
    pi_policy = heuristics.choose_pi_policy(
        meta, rank, value_bytes=dtype_bytes, fast_mem_bytes=fast_mem_bytes)
    pre_pi = pi_policy is heuristics.PiPolicy.PRE
    ndim = meta.enc.ndim

    pools = [mode_pool(meta, n, rank, backend=backend, objective=objective,
                       dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
                       pre_pi=pre_pi, streaming=streaming)
             for n in range(ndim)]
    space = sum(len(p) for p in pools)
    if budget_runs is None and budget_s is None:
        budget_runs = max(2 * ndim, -(-space // 4))
    budget = SearchBudget(max_runs=budget_runs, max_seconds=budget_s)

    plans = autotune.load_store(store_path)
    model = model_from_store(plans)
    model_samples = model.n_samples
    neighbors = store_neighbors(plans, meta, rank, objective=objective)

    rng = np.random.default_rng(seed)
    searches = []
    for n in range(ndim):
        seeds = []
        for record in neighbors:
            try:
                g = record["modes"][n]
                seeds.append(repair(
                    pools[n], heuristics.Traversal(g["traversal"]),
                    int(g["r_block"]), int(g["block_m"])))
            except (KeyError, IndexError, ValueError, TypeError):
                continue
        searches.append(_ModeSearch(n, pools[n], rng, population, seeds))

    # --- measurement setup (exhaustive tuner's protocol) ---------------
    rng_f = np.random.default_rng(seed)
    factors = [jnp.asarray(rng_f.standard_normal((I, rank))
                           .astype(np.float32)) for I in meta.dims]
    analytic_chunk = None
    if streaming:
        align0 = max(max(g.block_m for g in p) for p in pools)
        analytic_chunk = plan_mod.choose_chunk_m(meta, rank, device_bytes,
                                                 align0, dtype_bytes)

    def candidate_plan(mode: int, gene: plan_mod.ModePlan,
                       chunk_m: int | None) -> plan_mod.ExecutionPlan:
        modes = [searches[m].pool[0] for m in range(ndim)]
        modes[mode] = gene
        stream = None
        if streaming:
            cm = chunk_m if chunk_m is not None else analytic_chunk
            # Only the measured mode's kernel runs under this candidate:
            # align the chunk to ITS block (powers of two, so rounding up
            # suffices) — never to the unmeasured base modes, which would
            # silently distort a chunk-ladder measurement.
            cm = -(-cm // gene.block_m) * gene.block_m
            stream = plan_mod.StreamPlan(
                chunk_m=cm, n_chunks=plan_mod.chunk_count(meta, cm),
                device_bytes=device_bytes,
                stream_bytes=plan_mod.incore_working_set_bytes(
                    meta, rank, dtype_bytes))
        return plan_mod.ExecutionPlan(
            meta=meta, rank=rank, backend=backend, interpret=interpret,
            pi_policy=pi_policy, modes=tuple(modes), streaming=stream)

    mode_operands: dict[int, tuple] = {}

    def operands(mode: int):
        """(views, view, B, pi_alto, pi_view) for one mode, lazy-built."""
        if mode in mode_operands:
            return mode_operands[mode]
        if streaming:
            view = views_mod.get_stream(at, mode)
        else:
            oriented_any = any(heuristics.is_oriented(g.traversal)
                               for g in pools[mode])
            view = views_mod.get_view(at, mode) if oriented_any else None
        views = {mode: view} if view is not None else {}
        B = pi_alto = pi_view = None
        if objective == "phi":
            B = jnp.abs(factors[mode]) + jnp.float32(0.1)
            if pre_pi and not streaming:
                pi_alto = core_mttkrp.krp_rows(
                    delinearize(meta.enc, at.words), factors, mode)
                if view is not None:
                    pi_view = core_mttkrp.krp_rows(
                        delinearize(meta.enc, view.words), factors, mode)
        out = (views, view, B, pi_alto, pi_view)
        mode_operands[mode] = out
        return out

    logger = TuneLogger(log_path)
    key = autotune.plan_key(meta, rank, backend, dtype_bytes=dtype_bytes,
                            vmem_limit=vmem_limit,
                            fast_mem_bytes=fast_mem_bytes,
                            objective=objective, device_bytes=device_bytes)
    logger.write("search_start", key=key, objective=objective,
                 backend=backend, streaming=streaming,
                 budget_runs=budget_runs, budget_s=budget_s,
                 pool_sizes=[len(p) for p in pools],
                 model_samples=model_samples, neighbors=len(neighbors),
                 seed=seed, dims=list(meta.dims), nnz=meta.nnz, rank=rank)

    memo: dict[tuple, float] = {}
    new_samples: list[dict] = []

    def measure(mode: int, pool_i: int, chunk_m: int | None,
                generation) -> float | None:
        gene = searches[mode].pool[pool_i]
        cm = (chunk_m if chunk_m is not None else analytic_chunk) \
            if streaming else 0
        mkey = (mode, gene.traversal, gene.r_block, gene.block_m, cm)
        if mkey in memo:
            return memo[mkey]
        if not budget.allows():
            return None
        views, view, B, pi_alto, pi_view = operands(mode)
        cand = candidate_plan(mode, gene, chunk_m)
        feats = gene_features(meta, rank, mode, gene.traversal,
                              gene.r_block, gene.block_m, chunk_m=cm,
                              objective=objective, dtype_bytes=dtype_bytes)
        predicted = model.predict(feats)
        t0 = time.perf_counter()
        if objective == "phi":
            oriented = (view is not None
                        and heuristics.is_oriented(gene.traversal))
            pi = ((pi_view if oriented else pi_alto)
                  if (pre_pi and not streaming) else None)
            median, iqr = _time_phi(cand, at, view, B, factors, pi, mode,
                                    warmup, iters)
        else:
            median, iqr = _time_mttkrp(cand, at, views, factors, mode,
                                       warmup, iters)
        budget.charge(time.perf_counter() - t0)
        median = float(median)
        memo[mkey] = median
        model.add_sample(feats, median)
        new_samples.append({"f": [round(f, 6) for f in feats],
                            "s": median})
        logger.write("measure", key=key, generation=generation, mode=mode,
                     traversal=gene.traversal.value, r_block=gene.r_block,
                     block_m=gene.block_m, chunk_m=cm or None,
                     predicted_us=(None if predicted is None
                                   else predicted * 1e6),
                     measured_us=median * 1e6, iqr_us=iqr * 1e6,
                     budget_runs_used=budget.runs_used,
                     budget_seconds_used=round(budget.seconds_used, 6))
        return median

    # --- the GA loop: round-robin generations over modes ---------------
    def refresh_predictions(ms: _ModeSearch) -> None:
        for i in set(ms.population):
            g = ms.pool[i]
            ms.predicted[i] = model.predict(gene_features(
                meta, rank, ms.mode, g.traversal, g.r_block, g.block_m,
                chunk_m=analytic_chunk or 0, objective=objective,
                dtype_bytes=dtype_bytes))

    model_used = model.ready
    gens_run = 0
    for gen in range(generations):
        if not budget.allows() and gen > 0:
            break
        gens_run = gen + 1
        for ms in searches:
            refresh_predictions(ms)
            for i in ms.to_measure(top_k, first_generation=(gen == 0)):
                t = measure(ms.mode, i, None, generation=gen)
                if t is None:
                    break
                ms.measured[i] = t
            ms.evolve(mutate_p)
        model.fit()

    # --- streaming: the chunk_m gene, on the bottleneck mode ------------
    chunk_winner = analytic_chunk
    n_chunk_cands = 0
    if streaming:
        win_genes = [ms.pool[ms.winner()[0]] for ms in searches]
        align = max(g.block_m for g in win_genes)
        ladder = chunk_ladder(meta, rank, device_bytes, align, dtype_bytes)
        n_chunk_cands = len(ladder)
        measured_modes = [ms for ms in searches if ms.measured]
        if measured_modes:
            bottleneck = max(measured_modes,
                             key=lambda ms: ms.winner()[1]).mode
        else:
            bottleneck = int(np.argmax(meta.dims))
        chunk_times = {}
        for cm in ladder:
            wi = searches[bottleneck].winner()[0]
            t = measure(bottleneck, wi, cm, generation="chunk")
            if t is None:
                break
            chunk_times[cm] = t
        if chunk_times:
            chunk_winner = min(chunk_times,
                               key=lambda c: (chunk_times[c], -c))
        else:
            chunk_winner = ladder[0] if ladder else analytic_chunk
        # The winning chunk must stay aligned to the winning tiling.
        chunk_winner = max(chunk_winner, align)

    # --- assemble the winner plan ---------------------------------------
    winners, win_modes = [], []
    warm = budget.runs_used == 0 and model.ready
    for ms in searches:
        refresh_predictions(ms)
        i, measured_s, predicted_s = ms.winner()
        g = ms.pool[i]
        win_modes.append(g)
        winners.append(ModeWinner(
            mode=ms.mode, traversal=g.traversal.value, r_block=g.r_block,
            block_m=g.block_m, measured_s=measured_s,
            predicted_s=(predicted_s if predicted_s is not None
                         else ms.predicted.get(i)),
            is_static=(i == 0)))
    stream = None
    if streaming:
        stream = plan_mod.StreamPlan(
            chunk_m=chunk_winner,
            n_chunks=plan_mod.chunk_count(meta, chunk_winner),
            device_bytes=device_bytes,
            stream_bytes=plan_mod.incore_working_set_bytes(meta, rank,
                                                           dtype_bytes))
    plan = plan_mod.ExecutionPlan(
        meta=meta, rank=rank, backend=backend, interpret=interpret,
        pi_policy=pi_policy, modes=tuple(win_modes), streaming=stream)

    stored = ""
    if persist:
        record = autotune.serialize_plan(plan)
        record["tuned"] = {
            "mode": "search",
            "platform": jax.default_backend(),
            "objective": objective,
            "seed": seed,
            "generations": gens_run,
            "budget_runs": budget_runs,
            "budget_s": budget_s,
            "runs_used": budget.runs_used,
            "seconds_used": round(budget.seconds_used, 6),
            "warm_start": warm,
        }
        old = plans.get(key) or {}
        keep = (old.get("samples") or [])[:MAX_RECORD_SAMPLES]
        merged = (new_samples + keep)[:MAX_RECORD_SAMPLES]
        record["samples"] = merged
        # Re-load before writing: another process may have persisted
        # since our read, and the store write must not drop its plans.
        plans = autotune.load_store(store_path)
        plans[key] = record
        stored = str(autotune.save_store(plans, store_path))

    report = SearchReport(
        key=key, store=stored, objective=objective, backend=backend,
        budget_runs=budget_runs, budget_s=budget_s,
        runs_used=budget.runs_used,
        seconds_used=budget.seconds_used, generations=gens_run,
        pool_sizes=tuple(len(p) for p in pools),
        model_samples=model_samples, model_used=model_used,
        warm_start=warm, neighbors=len(neighbors),
        winners=tuple(winners),
        chunk_m=chunk_winner if streaming else None,
        chunk_candidates=n_chunk_cands)
    logger.write("search_end", key=key, runs_used=budget.runs_used,
                 seconds_used=round(budget.seconds_used, 6),
                 generations=gens_run, warm_start=warm,
                 chunk_m=report.chunk_m,
                 winners=[{"mode": w.mode, "traversal": w.traversal,
                           "r_block": w.r_block, "block_m": w.block_m,
                           "measured_us": (None if w.measured_s is None
                                           else w.measured_s * 1e6)}
                          for w in winners],
                 store=stored)
    return plan, report
