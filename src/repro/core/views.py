"""Unified, cached oriented-view pipeline: (tensor, mode) -> OrientedView.

Every consumer of the oriented traversal — `cp_als`, `cp_apr`, the
autotuner, the distributed drivers — needs the same row-sorted copy of
the stream per (tensor, mode), and before this module each of them
rebuilt it per call (a host argsort + full host→device copy each time).
This is the single materialization point: views are built once per
(tensor fingerprint, mode) per process, routed host-vs-device, and every
caller shares the cached arrays (`plan.build_views` routes through here).

* **Routing** — ``route="device"`` (default) builds with
  `alto.oriented_view_device` (masked bit-extract + one stable
  `lax.sort`, jit-compiled, no host round-trip); ``route="host"`` keeps
  the numpy parity reference. The two are bit-identical (tier-1
  parity-tested), so the cache never keys on the route. The process
  default comes from ``$REPRO_INGEST`` ("device" | "host").

* **Fingerprinting** — the cache key is content-based, not object-based:
  the hashable `AltoMeta` plus two u32 mixing checksums over the word
  stream and the values (bitcast in their NATIVE dtype, so float64
  tensors differing below float32 resolution cannot alias), reduced on
  device and memoized on the tensor object. Two `AltoTensor`s holding
  the same built data (e.g. rebuilt across driver calls) therefore share
  views, while any change to the data re-keys. The digest transfer is
  two scalars — negligible next to the O(nnz) copies it deduplicates.

* **Accounting & bounds** — hits/misses/builds are counted
  (`cache_stats`) so the "one build per (tensor, mode) per process"
  contract is assertable; per-key build latches keep that contract under
  concurrent drivers *without* serializing unrelated requests (a miss
  registers a pending-build event under the global lock, runs the O(nnz)
  build outside it, and re-acquires only to insert — so a cache hit on
  one tensor never blocks behind another tenant's build).
  The cache is LRU-bounded twice over — by entry count
  (``$REPRO_VIEW_CACHE_SIZE``, default 64) and by approximate resident
  bytes (``$REPRO_VIEW_CACHE_BYTES``, default 2 GiB) — because one view
  is a full O(nnz) copy and a count bound alone would let a sweep over
  large tensors pin multiples of device memory. Dropping a tensor does
  not drop its cached views until they age out; call
  :func:`invalidate` to release them eagerly.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading

import jax
import jax.numpy as jnp

from repro.core import alto
from repro.core import faults
from repro.core import stream as stream_mod
from repro.core.alto import AltoTensor, OrientedView
from repro.core.stream import HostStream

DEFAULT_CACHE_SIZE = 64
DEFAULT_CACHE_BYTES = 2 * 1024 ** 3

_CACHE: "collections.OrderedDict[tuple, OrientedView]" = \
    collections.OrderedDict()
_CACHE_BYTES: dict[tuple, int] = {}
_STATS = {"hits": 0, "misses": 0, "builds": 0, "invalidated": 0}
_LOCK = threading.Lock()
# key -> Event set when that key's in-flight build lands (or fails). The
# global lock only guards map bookkeeping; builds run outside it.
_PENDING: dict[tuple, threading.Event] = {}

_FP_ATTR = "_ingest_fingerprint"


def default_route() -> str:
    """Process-wide ingest routing: ``$REPRO_INGEST`` or "device"."""
    route = os.environ.get("REPRO_INGEST", "device")
    if route not in ("device", "host"):
        raise ValueError(f"REPRO_INGEST={route!r}: expected device|host")
    return route


def _limits() -> tuple[int, int]:
    return (int(os.environ.get("REPRO_VIEW_CACHE_SIZE",
                               DEFAULT_CACHE_SIZE)),
            int(os.environ.get("REPRO_VIEW_CACHE_BYTES",
                               DEFAULT_CACHE_BYTES)))


def _view_bytes(v) -> int:
    """Approximate resident bytes of a cache entry — device `OrientedView`
    or host `core.stream.HostStream` (both count against the byte bound;
    a host stream is still an O(nnz) copy of the tensor)."""
    if isinstance(v, HostStream):
        return v.nbytes()
    return sum(int(a.size) * a.dtype.itemsize
               for a in (v.rows, v.words, v.values, v.perm))


def _u32_mix(x: jnp.ndarray, salt: int) -> jnp.ndarray:
    """Order-sensitive u32 checksum (wrapping arithmetic, eager jnp)."""
    idx = jnp.arange(x.shape[0], dtype=jnp.uint32)
    mixed = (x ^ (idx * jnp.uint32(0x9E3779B1))) * jnp.uint32(salt)
    return jnp.sum(mixed, dtype=jnp.uint32)


def fingerprint(at: AltoTensor) -> tuple:
    """Content fingerprint of a built tensor, memoized on the object.

    Hashable: (meta, padded length, words checksum, values checksum).
    `AltoMeta` already pins shape/nnz/partitioning; the checksums pin the
    actual stream content — values bitcast in their native width, so no
    precision is discarded before hashing — and distinct tensors with
    identical meta cannot alias each other's views.
    """
    fp = getattr(at, _FP_ATTR, None)
    if fp is None:
        w = _u32_mix(at.words.ravel().astype(jnp.uint32), 0x85EBCA6B)
        # f32 -> (M,) u32; f64 -> (M, 2) u32: ravel covers both widths.
        v_bits = jax.lax.bitcast_convert_type(at.values, jnp.uint32)
        v = _u32_mix(v_bits.ravel(), 0xC2B2AE35)
        fp = (at.meta, at.words.shape[0], int(w), int(v))
        at._ingest_fingerprint = fp
    return fp


def mode_fingerprint(at: AltoTensor, mode: int) -> tuple:
    """Per-(tensor content, mode) fingerprint — the invalidation unit.

    Deliberately EXCLUDES the partitioning fields of `AltoMeta`
    (n_partitions, temp_rows, fiber_reuse): an oriented view is a pure
    permutation of the padded stream, so re-tiling the same stream under
    a different partition count leaves every cached view valid. Only the
    encoding, the real/padded lengths, the content checksums, and the
    mode participate — which is what lets `invalidate_changed` keep
    untouched entries alive after a re-tile or a no-op append.
    """
    meta, Mp, w, v = fingerprint(at)
    return (meta.enc, meta.nnz, Mp, w, v, int(mode))


def _rebind_meta(key: tuple, entry, at: AltoTensor):
    """Cached entries key on `mode_fingerprint`, which ignores the
    partitioning fields — so a re-tile can HIT an entry built under a
    different `AltoMeta`. The arrays are identical (pure permutation of
    the same stream); only the meta tag is stale. Rebind it lazily,
    storing the rebound entry back so repeated gets with the same tensor
    return the identical object (callers assert `is`-identity)."""
    if entry.meta == at.meta:
        return entry
    entry = dataclasses.replace(entry, meta=at.meta)
    with _LOCK:
        if key in _CACHE:
            _CACHE[key] = entry
    return entry


def _get_or_build(key: tuple, build):
    """Latched cache lookup shared by `get_view` and `get_stream`.

    Thread-safe with per-key build latches (double-checked): the first
    thread to miss a key registers a pending event under the global lock,
    runs the O(nnz) ``build`` *outside* it, then re-acquires to insert
    and release waiters. Concurrent misses on the SAME key wait on the
    event (one build per key — `cache_stats` keeps that assertable),
    while a hit — or a miss — on any OTHER key proceeds immediately
    instead of blocking behind an unrelated tenant's build.
    """
    while True:
        with _LOCK:
            view = _CACHE.get(key)
            if view is not None:
                _STATS["hits"] += 1
                _CACHE.move_to_end(key)
                return view
            event = _PENDING.get(key)
            if event is None:
                # This thread owns the build for `key`.
                _PENDING[key] = threading.Event()
                _STATS["misses"] += 1
                _STATS["builds"] += 1
        if event is not None:
            # Another thread is building this key: wait, then re-check
            # (normally a hit; a failed or instantly-evicted build makes
            # this thread the next builder).
            event.wait()
            continue
        try:
            view = build()
        except BaseException:
            with _LOCK:
                _PENDING.pop(key).set()   # unblock waiters; one re-builds
            raise
        with _LOCK:
            _CACHE[key] = view
            _CACHE_BYTES[key] = _view_bytes(view)
            max_entries, max_bytes = _limits()
            while len(_CACHE) > max(1, max_entries) or (
                    len(_CACHE) > 1
                    and sum(_CACHE_BYTES.values()) > max_bytes):
                old, _ = _CACHE.popitem(last=False)
                _CACHE_BYTES.pop(old, None)
            _PENDING.pop(key).set()
        return view


def get_view(at: AltoTensor, mode: int,
             route: str | None = None) -> OrientedView:
    """The oriented view for ``(at, mode)``: cached, built on miss
    (per-key latched — see `_get_or_build`)."""
    key = ("view", *mode_fingerprint(at, mode))

    def build():
        # Injection here exercises the latch's failed-build contract: the
        # owner's exception releases waiters and the next caller rebuilds.
        faults.inject("views.build")
        route_ = route or default_route()
        return (alto.oriented_view_device(at, mode)
                if route_ == "device" else alto.oriented_view(at, mode))

    return _rebind_meta(key, _get_or_build(key, build), at)


def get_stream(at: AltoTensor, mode: int) -> HostStream:
    """The HOST-resident stream for ``(at, mode)``: cached, built on miss.

    Same cache, latches, counters, and LRU byte/entry bounds as
    `get_view`, under a key tagged "stream" so a tensor decomposed both
    in-core and out-of-core keeps the two representations distinct.
    Eviction is safe mid-flight: the chunked executors slice the numpy
    arrays zero-copy, and numpy refcounting keeps a slice's backing
    buffer alive after the cache entry is dropped (no use-after-evict —
    pinned by `tests/test_outofcore.py`).
    """
    key = ("stream", *mode_fingerprint(at, mode))

    def build():
        faults.inject("views.build")
        return stream_mod.host_stream(at, mode)

    return _rebind_meta(key, _get_or_build(key, build), at)


def build_views(at: AltoTensor, plan, route: str | None = None) -> dict:
    """Cached views for exactly the modes ``plan`` routes oriented
    (either variant — one-hot merge or scratch carry — consumes the same
    row-sorted view). A STREAMING plan materializes host-resident
    `core.stream.HostStream`s instead of device views — same cache, same
    one-build-per-key contract — which the chunked executors consume."""
    from repro.core import heuristics
    if getattr(plan, "streaming", None) is not None:
        return {m.mode: get_stream(at, m.mode)
                for m in plan.modes if heuristics.is_oriented(m.traversal)}
    return {m.mode: get_view(at, m.mode, route=route)
            for m in plan.modes if heuristics.is_oriented(m.traversal)}


def invalidate(at: AltoTensor, modes=None) -> int:
    """Drop cached views/streams of ``at`` — all modes by default, or only
    ``modes`` — returning how many entries were evicted (also accumulated
    in the ``invalidated`` counter). Per-(fingerprint, mode) surgical:
    untouched modes' O(nnz) copies stay cached. For services that release
    a tensor (or re-ingest one mode) and want the stale copies freed
    before LRU aging would get to them."""
    if modes is None:
        modes = range(len(at.dims))
    fps = {mode_fingerprint(at, int(m)) for m in modes}
    with _LOCK:
        dead = [k for k in _CACHE if k[1:] in fps]
        for k in dead:
            del _CACHE[k]
            _CACHE_BYTES.pop(k, None)
        _STATS["invalidated"] += len(dead)
    return len(dead)


def invalidate_changed(old_at: AltoTensor, new_at: AltoTensor) -> int:
    """Surgical post-append invalidation: drop ``old_at``'s cached entries
    only for the modes whose `mode_fingerprint` actually changed between
    the two tensors. A no-op append (empty delta under the "sum" policy)
    or a pure re-tile changes no fingerprints, so nothing is dropped and
    every cached view keeps serving; a content-changing append stales all
    modes' entries (each oriented view permutes the full stream) and they
    are released eagerly instead of aging out of the LRU."""
    stale = [m for m in range(len(old_at.dims))
             if mode_fingerprint(old_at, m) != mode_fingerprint(new_at, m)]
    return invalidate(old_at, modes=stale) if stale else 0


def cache_stats() -> dict[str, int]:
    """Hit/miss/build counters plus current size (copies, not live)."""
    with _LOCK:
        out = dict(_STATS)
        out["size"] = len(_CACHE)
        out["bytes"] = sum(_CACHE_BYTES.values())
    return out


def cache_clear() -> None:
    with _LOCK:
        _CACHE.clear()
        _CACHE_BYTES.clear()
        for k in _STATS:
            _STATS[k] = 0
