"""Deterministic fault injection for the serving/streaming/ingest stack.

Production-scale serving is defined by what happens on the bad day: a
torn memmap, a device allocator returning RESOURCE_EXHAUSTED mid-bucket,
a tenant whose update NaNs, a corrupted plan store. This module makes
those days reproducible: every recoverable failure the runtime claims to
survive has a *named site* threaded through the real hot path, and a
test (or an operator, via ``$REPRO_FAULTS``) arms the site to fire a
deterministic number of times. `tests/test_resilience.py` pins each
recovery ladder against these sites; the CI resilience lane re-runs the
suite under an env matrix of fault classes.

Design constraints (the tentpole contract):

* **Deterministic.** A site fires on its first ``times`` hits, then goes
  quiet — no randomness, no clocks. Two runs with the same arming see
  the same failures at the same call sites.
* **Zero overhead disabled.** The fast path of :func:`fire` /
  :func:`inject` is one module-global bool check; with nothing armed the
  hot loops pay a single ``if`` per site. No site registers a host
  callback inside jit: sites inside jit-traced code (`plan.execute_*`,
  the in-core `kernels.ops` wrappers) fire at *trace time* only — which
  is exactly when a bad plan's kernel build would fail for real — and
  contribute nothing to the compiled executable.
* **Scoped arming.** Tests use the :func:`injected` context manager;
  operators/CI use ``REPRO_FAULTS="site[:times][,site...]"`` (parsed at
  import; :func:`configure` re-reads). Unknown site names fail fast.

Injected exceptions mimic their real counterparts so the recovery code
paths cannot special-case injection: I/O sites raise an ``OSError``
subclass, OOM sites raise with ``RESOURCE_EXHAUSTED`` in the message
(what `jaxlib`'s allocator failures carry), NaN sites do not raise at
all — they corrupt the value stream (the caller poisons its own state
via :func:`fire`), which is how real non-finite faults arrive.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading

# site name -> fault class. The docs fault-site table (docs/resilience.md)
# is generated from this mapping; adding a site here without threading it
# through a hot path is a docs-lane failure, not a silent no-op.
SITES: dict[str, str] = {
    "stream.memmap_load": "io",        # from_memmap: spilled stream read
    "stream.chunk_io": "io",           # put_chunk: chunk page-in/transfer
    "stream.respill": "interrupt",     # _respill: between tmps and replace
    "stream.checksum": "corrupt",      # from_memmap: stored checksum flips
    "ops.chunk_oom": "oom",            # chunked executors: per-chunk launch
    "ops.exec": "dispatch",            # in-core kernel wrappers (trace time)
    "plan.dispatch": "dispatch",       # execute_mttkrp/execute_phi routing
    "autotune.store": "corrupt",       # load_store: plan-store JSON read
    "ingest.merge": "interrupt",       # _append: before the jitted merge
    "cpals.nan": "nan",                # poison a CP-ALS sweep's factors
    "cpapr.nan": "nan",                # poison a CP-APR mode update
    "batched.nan": "nan",              # poison one tenant slot in a bucket
    "batched.sweep": "interrupt",      # batched drivers: before each sweep
    "views.build": "io",               # view/stream cache build
}


class InjectedFault(RuntimeError):
    """Base for raised injections (NOT for io — see InjectedIOError)."""


class InjectedIOError(OSError):
    """Transient I/O failure (torn page, vanished file, EIO)."""


class InjectedResourceExhausted(InjectedFault):
    """Mimics jaxlib's allocator failure; message carries the marker."""

    def __init__(self, site: str):
        super().__init__(f"RESOURCE_EXHAUSTED: injected at {site}")


class InjectedInterrupt(InjectedFault):
    """A program killed mid-flight (respill, merge, sweep)."""


class InjectedDispatchError(InjectedFault):
    """A plan whose kernel fails to build/dispatch (bad stored tiling)."""


class InjectedCorruption(ValueError):
    """Corrupted serialized state (mangled JSON, flipped bits). A
    ValueError so the real corruption handlers (`autotune.load_store`
    treats bad JSON as an empty store) catch it without special-casing
    injection."""


def _exception_for(site: str) -> BaseException:
    kind = SITES[site]
    if kind == "io":
        return InjectedIOError(f"injected I/O error at {site}")
    if kind == "oom":
        return InjectedResourceExhausted(site)
    if kind == "dispatch":
        return InjectedDispatchError(f"injected dispatch failure at {site}")
    if kind == "corrupt":
        return InjectedCorruption(f"injected corruption at {site}")
    return InjectedInterrupt(f"injected interrupt at {site}")


def is_injected(exc: BaseException) -> bool:
    return isinstance(exc, (InjectedFault, InjectedIOError,
                            InjectedCorruption))


def is_transient(exc: BaseException) -> bool:
    """Worth a blind retry? I/O errors and allocator exhaustion are —
    the next attempt reads a healthy page or a drained allocator. Wrong
    plans / poisoned values are NOT: they need a degradation ladder."""
    return isinstance(exc, OSError) or "RESOURCE_EXHAUSTED" in str(exc)


@dataclasses.dataclass
class _Arm:
    remaining: int
    data: dict
    skip: int = 0          # hits to let through before the first fire


_LOCK = threading.Lock()
_ARMED: dict[str, _Arm] = {}
_FIRED: dict[str, int] = {}
# Fast-path flag: fire()/inject() read it unlocked. Python guarantees
# atomic loads of the bool; stale reads only delay a *newly armed* fault
# by one call on another thread, never fire a disarmed one incorrectly
# (firing re-checks under the lock).
_ENABLED = False


def _refresh_enabled_locked() -> None:
    global _ENABLED
    _ENABLED = bool(_ARMED)


def arm(site: str, times: int = 1, data: dict | None = None,
        after: int = 0) -> None:
    """Arm ``site`` to fire on its next ``times`` hits.

    ``data`` rides along to the caller via :func:`fire` (e.g. which
    tenant slot to poison, what value to poison with). ``after`` lets
    the first ``after`` hits through untouched before the site starts
    firing — deterministic placement ("fail on the Nth call"), e.g. a
    sweep poison that must land once a fit history exists.
    """
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r}; known: "
                         f"{sorted(SITES)}")
    if times < 1:
        raise ValueError(f"times must be >= 1, got {times}")
    if after < 0:
        raise ValueError(f"after must be >= 0, got {after}")
    with _LOCK:
        _ARMED[site] = _Arm(remaining=int(times), data=dict(data or {}),
                            skip=int(after))
        _refresh_enabled_locked()


def disarm(site: str) -> None:
    with _LOCK:
        _ARMED.pop(site, None)
        _refresh_enabled_locked()


def reset() -> None:
    """Disarm everything and zero the fired counters."""
    with _LOCK:
        _ARMED.clear()
        _FIRED.clear()
        _refresh_enabled_locked()


def armed(site: str) -> bool:
    if not _ENABLED:
        return False
    with _LOCK:
        return site in _ARMED


def fired() -> dict[str, int]:
    """Times each site actually fired (cumulative since `reset`)."""
    with _LOCK:
        return dict(_FIRED)


def fire(site: str) -> dict | None:
    """Hot-path hook: returns the arm's ``data`` if ``site`` fires now,
    else None. One unlocked bool check when nothing is armed."""
    if not _ENABLED:
        return None
    with _LOCK:
        a = _ARMED.get(site)
        if a is None:
            return None
        if a.skip > 0:
            a.skip -= 1
            return None
        a.remaining -= 1
        if a.remaining <= 0:
            del _ARMED[site]
            _refresh_enabled_locked()
        _FIRED[site] = _FIRED.get(site, 0) + 1
        return dict(a.data)


def inject(site: str) -> None:
    """Hot-path hook for raising sites: raises the site's exception class
    if armed, else returns immediately (one bool check)."""
    if not _ENABLED:
        return
    if fire(site) is not None:
        raise _exception_for(site)


@contextlib.contextmanager
def injected(site: str, times: int = 1, data: dict | None = None,
             after: int = 0):
    """Scoped arming for tests: arms on entry, disarms on exit (whether
    or not every shot was consumed)."""
    arm(site, times=times, data=data, after=after)
    try:
        yield
    finally:
        disarm(site)


def configure(spec: str | None) -> None:
    """Replace the armed set from a ``$REPRO_FAULTS`` spec string.

    Format: comma/semicolon-separated ``site`` or ``site:times``
    entries, e.g. ``REPRO_FAULTS="stream.chunk_io:2,batched.nan"``.
    Empty/None clears. Unknown sites raise (a typo'd matrix entry must
    fail the lane, not silently test nothing).
    """
    reset()
    if not spec:
        return
    for entry in spec.replace(";", ",").split(","):
        entry = entry.strip()
        if not entry:
            continue
        site, _, times = entry.partition(":")
        arm(site.strip(), times=int(times) if times else 1)


def configure_env() -> None:
    """(Re-)read ``$REPRO_FAULTS``; called once at import."""
    configure(os.environ.get("REPRO_FAULTS"))


configure_env()
