"""ALTO adaptive linearized encoding (paper §3.1, Figs. 4–6).

Maps N-dimensional coordinates onto a single compact linearized index of
``sum_n ceil(log2 I_n)`` bits (Eq. 1). Bit positions are assigned
most-significant-first by repeatedly splitting the mode with the *largest
remaining extent* ("partition along the longest mode first"); ties break
toward the longer original mode, i.e. within a bit group modes appear in
increasing length order toward the LSB ("shortest mode first"). This is the
paper's adaptive, non-fractal alternative to Z-Morton (Eq. 3).

TPU adaptation: the index is stored as ``n_words`` little-endian uint32
words (1/2/4 words ~ the paper's 32/64/128-bit configurations). TPUs have no
native 64-bit integer datapath, so the word decomposition is explicit and
every bit-gather/scatter lowers to vectorizable u32 shifts/ands/ors.

Linearization ("bit-level gather", Fig. 6a) and delinearization ("bit-level
scatter", Fig. 6b) are run-compressed: consecutive index bits that come from
consecutive bits of the same mode and land in the same word are moved with a
single shift+mask, so the op count is O(#runs) ≤ O(total_bits) and in
practice ~N per word.

Two sorting surfaces live here, one per placement:

* host (`sort_key_np`, `count_distinct_np`) — numpy, the parity
  reference used by `alto.build` / `alto.fiber_reuse_stats`;
* device (`sort_by_key`, `count_distinct`) — `jax.lax.sort` on the same
  packed multi-word key, stable, jit-compatible, carrying arbitrary
  value/coordinate operands through the permutation. This is the paper's
  Fig. 13 claim made jittable: format generation is ONE key sort, so it
  can run on the accelerator inside a traced program.

Both orderings are bit-identical (ascending multi-word unsigned key,
ties by original position) — `alto.build_device` relies on that to be a
drop-in replacement for the host build.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def _bits_for(extent: int) -> int:
    """ceil(log2 extent); modes of length 1 contribute zero bits."""
    return (int(extent) - 1).bit_length() if extent > 1 else 0


@dataclasses.dataclass(frozen=True)
class BitRun:
    """A contiguous run of bits moved between a mode coordinate and a word.

    word:       which u32 word of the linearized index.
    mode:       which tensor mode.
    src_shift:  bit offset of the run inside the mode coordinate.
    dst_shift:  bit offset of the run inside the word.
    length:     run length in bits.
    """
    word: int
    mode: int
    src_shift: int
    dst_shift: int
    length: int

    @property
    def mask(self) -> int:
        return (1 << self.length) - 1


@dataclasses.dataclass(frozen=True)
class AltoEncoding:
    """Static encoding metadata for a tensor shape (host-side, hashable)."""

    dims: tuple[int, ...]
    mode_bits: tuple[int, ...]         # bits per mode
    bit_mode: tuple[int, ...]          # bit b (0 = LSB) -> owning mode
    bit_pos: tuple[int, ...]           # bit b -> bit position inside mode
    runs: tuple[BitRun, ...]           # run-compressed gather/scatter plan

    @property
    def total_bits(self) -> int:
        return len(self.bit_mode)

    @property
    def n_words(self) -> int:
        # Round up to 1/2/4 words like the paper rounds to native word sizes.
        needed = max(1, -(-self.total_bits // WORD_BITS))
        for w in (1, 2, 4):
            if needed <= w:
                return w
        raise ValueError(
            f"ALTO index needs {self.total_bits} bits > 128; "
            "unsupported shape {self.dims}")

    @property
    def ndim(self) -> int:
        return len(self.dims)

    def mode_masks(self) -> np.ndarray:
        """(N, n_words) u32 masks: which index bits belong to each mode."""
        masks = np.zeros((self.ndim, self.n_words), dtype=np.uint64)
        for b, m in enumerate(self.bit_mode):
            masks[m, b // WORD_BITS] |= np.uint64(1) << np.uint64(
                b % WORD_BITS)
        return masks.astype(np.uint32)

    # ---- storage accounting (paper Eqs. 1-3) ----
    def storage_bits_alto(self, word_bits: int = WORD_BITS) -> int:
        """Index bits per nonzero in ALTO (Eq. 1), word-rounded (Eq. 2)."""
        return max(1, -(-self.total_bits // word_bits)) * word_bits

    def runtime_index_bits(self) -> int:
        """Bits per nonzero of the in-memory multi-u32 representation."""
        return self.n_words * WORD_BITS

    def storage_bits_coo(self, word_bits: int = WORD_BITS) -> int:
        """Index bits per nonzero in COO on word-addressed hardware (Eq. 2)."""
        return sum(max(1, -(-_bits_for(I) // word_bits)) * word_bits
                   for I in self.dims)

    def storage_bits_sfc(self) -> int:
        """Index bits per nonzero under a fractal SFC (Z-Morton, Eq. 3)."""
        return self.ndim * max(_bits_for(I) for I in self.dims)


def make_encoding(dims: Sequence[int]) -> AltoEncoding:
    """Build the adaptive bit assignment for a tensor shape."""
    dims = tuple(int(d) for d in dims)
    if not dims or any(d < 1 for d in dims):
        raise ValueError(f"invalid dims {dims}")
    mode_bits = tuple(_bits_for(I) for I in dims)
    total = sum(mode_bits)

    remaining = list(mode_bits)
    # extent of mode n after assigning k of its (high) bits: ceil(I / 2^k)
    def extent(n):
        k = mode_bits[n] - remaining[n]
        return -(-dims[n] // (1 << k))

    order: list[int] = []  # mode owning each bit, MSB first
    for _ in range(total):
        # Largest remaining extent first; ties -> longer original mode;
        # final tie -> lower mode id (deterministic).
        n = max((m for m in range(len(dims)) if remaining[m] > 0),
                key=lambda m: (extent(m), dims[m], -m))
        order.append(n)
        remaining[n] -= 1

    bit_mode = [0] * total
    bit_pos = [0] * total
    taken = [0] * len(dims)  # high bits already assigned per mode
    for i, n in enumerate(order):
        b = total - 1 - i           # global bit position (MSB first)
        bit_mode[b] = n
        bit_pos[b] = mode_bits[n] - 1 - taken[n]
        taken[n] += 1

    # Run-compress: scan LSB->MSB, merge while same mode & word and both
    # source and destination positions advance by one.
    runs: list[BitRun] = []
    b = 0
    while b < total:
        m = bit_mode[b]
        w = b // WORD_BITS
        start_b, start_p = b, bit_pos[b]
        length = 1
        while (b + 1 < total and bit_mode[b + 1] == m
               and (b + 1) // WORD_BITS == w
               and bit_pos[b + 1] == bit_pos[b] + 1):
            b += 1
            length += 1
        runs.append(BitRun(word=w, mode=m, src_shift=start_p,
                           dst_shift=start_b % WORD_BITS, length=length))
        b += 1

    return AltoEncoding(dims=dims, mode_bits=mode_bits,
                        bit_mode=tuple(bit_mode), bit_pos=tuple(bit_pos),
                        runs=tuple(runs))


# ---------------------------------------------------------------------------
# Host-side (numpy) linearize / delinearize — used at format generation time.
# ---------------------------------------------------------------------------

def linearize_np(enc: AltoEncoding, coords: np.ndarray) -> np.ndarray:
    """Bit-level gather: (M, N) int coords -> (M, n_words) u32 index."""
    coords = np.asarray(coords)
    M = coords.shape[0]
    out = np.zeros((M, enc.n_words), dtype=np.uint32)
    c = coords.astype(np.uint32)
    for r in enc.runs:
        chunk = (c[:, r.mode] >> np.uint32(r.src_shift)) & np.uint32(r.mask)
        out[:, r.word] |= chunk << np.uint32(r.dst_shift)
    return out


def delinearize_np(enc: AltoEncoding, words: np.ndarray) -> np.ndarray:
    """Bit-level scatter: (M, n_words) u32 index -> (M, N) int32 coords."""
    words = np.asarray(words, dtype=np.uint32)
    M = words.shape[0]
    out = np.zeros((M, enc.ndim), dtype=np.uint32)
    for r in enc.runs:
        chunk = (words[:, r.word] >> np.uint32(r.dst_shift)) & np.uint32(
            r.mask)
        out[:, r.mode] |= chunk << np.uint32(r.src_shift)
    return out.astype(np.int32)


def sort_key_np(words: np.ndarray) -> np.ndarray:
    """Argsort of multi-word linearized indices (LSW last).

    This is the paper's generation-cost win (Fig. 13): ALTO sorts ONE
    packed key (1-2 words) instead of N coordinate keys. Single-word
    indices take the fast scalar argsort; 64-bit indices combine two u32
    words into one u64 key."""
    W = words.shape[1]
    if W == 1:
        return np.argsort(words[:, 0], kind="stable")
    if W == 2:
        key = (words[:, 1].astype(np.uint64) << np.uint64(32)) \
            | words[:, 0].astype(np.uint64)
        return np.argsort(key, kind="stable")
    # np.lexsort: last key is primary -> most significant word last.
    keys = tuple(words[:, w] for w in range(W))
    return np.lexsort(keys)


def extract_mode(enc: AltoEncoding, words, mode: int):
    """Read ONE mode's coordinate out of the linearized index words.

    Only the target mode's bit runs are touched — no full delinearize —
    so the cost is O(#runs of that mode) shifts/masks instead of
    O(#runs total). Pure ufunc arithmetic: ``words`` may be a numpy
    array (host `alto.oriented_view`) or a jax array
    (`alto.oriented_view_device`) of shape (..., n_words) u32; returns
    (...,) int32. The single shared implementation of the host and
    device row-extraction paths.
    """
    out = words[..., 0] & np.uint32(0)
    for r in enc.runs:
        if r.mode != mode:
            continue
        chunk = (words[..., r.word] >> np.uint32(r.dst_shift)) \
            & np.uint32(r.mask)
        out = out | (chunk << np.uint32(r.src_shift))
    return out.astype(np.int32)


def _pack_u64_np(words: np.ndarray) -> np.ndarray:
    """(M, W<=2) u32 -> (M,) u64 packed key (host side; numpy has u64)."""
    key = words[:, 0].astype(np.uint64)
    if words.shape[1] > 1:
        key |= words[:, 1].astype(np.uint64) << np.uint64(32)
    return key


def count_distinct_np(words: np.ndarray) -> int:
    """Distinct rows of an (M, W) u32 word array: packed-key sort +
    adjacent-diff count.

    Replaces the ``np.unique(axis=0)`` void-view scan that dominated
    ``build(compute_reuse=True)``: ≤2 words collapse to ONE u64 sort
    (the same single-packed-key trick as `sort_key_np`), 4 words to a
    two-u64-key lexsort. Counting needs no stability, only ordering.
    """
    M, W = words.shape
    if M == 0:
        return 0
    if W <= 2:
        key = np.sort(_pack_u64_np(words))
        return 1 + int(np.count_nonzero(key[1:] != key[:-1]))
    lo = _pack_u64_np(words[:, :2])
    hi = _pack_u64_np(words[:, 2:])
    order = np.lexsort((lo, hi))
    lo, hi = lo[order], hi[order]
    return 1 + int(np.count_nonzero(
        (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])))


# ---------------------------------------------------------------------------
# Device-side (jax.lax.sort) key packing + multi-word stable sort.
# ---------------------------------------------------------------------------

def pack_key(words: jnp.ndarray):
    """Packed single-lane device sort key, or None when unpackable.

    One word is its own key; two words pack into u64 only when 64-bit
    lanes exist — ``jax_enable_x64`` on AND a non-TPU backend (TPUs have
    no native 64-bit integer datapath regardless of the x64 flag, and
    with x64 off jnp silently truncates u64). Callers fall back to the
    multi-key paths of :func:`sort_by_key` on None.
    """
    W = words.shape[-1]
    if W == 1:
        return words[..., 0]
    if (W == 2 and jax.config.jax_enable_x64
            and jax.default_backend() != "tpu"):
        return (words[..., 1].astype(jnp.uint64) << jnp.uint64(32)) \
            | words[..., 0].astype(jnp.uint64)
    return None


def sort_by_key(words: jnp.ndarray, *operands: jnp.ndarray):
    """Stable ascending device sort by the multi-word ALTO key.

    ``words`` is (M, W) u32; ``operands`` are (M,) arrays carried through
    the same permutation (values, coordinate columns, iota for an
    argsort). Returns ``(sorted_words, *sorted_operands)``.

    Strategy by width: ≤2 words sort ONCE on the packed key
    (:func:`pack_key`; without x64 two words become one two-key
    lexicographic `lax.sort`, MSW primary — same order, no 64-bit
    lanes); beyond that, LSW→MSW stable passes (word-wise LSD radix —
    each pass is a stable single-key sort, so the composition orders by
    the most-significant word with ties resolved by lower words, exactly
    `sort_key_np`'s ``np.lexsort``). Every path is stable, so duplicate
    full keys keep their input order — the tie rule the oriented-view
    and build parity contracts depend on.
    """
    M, W = words.shape
    cols = [words[:, w] for w in range(W)]
    ops = list(operands)
    key = pack_key(words)
    if key is not None:
        res = jax.lax.sort((key, *cols, *ops), num_keys=1, is_stable=True)
        srt = list(res[1:])
    elif W == 2:
        res = jax.lax.sort((cols[1], cols[0], *ops), num_keys=2,
                           is_stable=True)
        srt = [res[1], res[0], *res[2:]]
    else:
        srt = cols + ops
        for w in range(W):                      # LSW -> MSW stable passes
            rest = srt[:w] + srt[w + 1:]
            res = jax.lax.sort((srt[w], *rest), num_keys=1, is_stable=True)
            srt = list(res[1:w + 1]) + [res[0]] + list(res[w + 1:])
    return (jnp.stack(srt[:W], axis=-1), *srt[W:])


def count_distinct(words: jnp.ndarray) -> jnp.ndarray:
    """Distinct rows of an (M, W) u32 array, on device (sort + adjacent
    diff — the jittable sibling of :func:`count_distinct_np`)."""
    if words.shape[0] == 0:
        return jnp.asarray(0, jnp.int32)
    srt = sort_by_key(words)[0]
    neq = jnp.any(srt[1:] != srt[:-1], axis=-1)
    return jnp.asarray(1, jnp.int32) + jnp.sum(neq, dtype=jnp.int32)


def compare_le_np(words: np.ndarray, bound: np.ndarray) -> np.ndarray:
    """Elementwise multi-word unsigned <= against a single bound."""
    M, W = words.shape
    le = np.ones(M, dtype=bool)
    decided = np.zeros(M, dtype=bool)
    for w in range(W - 1, -1, -1):
        lt = words[:, w] < bound[w]
        gt = words[:, w] > bound[w]
        le = np.where(~decided & gt, False, le)
        decided |= lt | gt
    return le
