"""CP-ALS on ALTO tensors (paper Alg. 1).

The MTTKRP bottleneck (line 11) runs through the adaptive ALTO engine; gram
matrices, the pseudo-inverse solve, and normalization are dense JAX. One
full sweep over all modes is a single jitted function; the outer iteration
is a host loop with fit-based early stopping (as in the paper's setup).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import heuristics
from repro.core.alto import AltoTensor, OrientedView, oriented_view
from repro.core.mttkrp import mttkrp_adaptive


@dataclasses.dataclass
class CpalsResult:
    lam: jnp.ndarray                 # (R,) component weights
    factors: list[jnp.ndarray]       # per-mode (I_n, R)
    fits: list[float]                # fit per iteration
    n_iters: int


def init_factors(dims: Sequence[int], rank: int, seed: int = 0,
                 dtype=jnp.float32) -> list[jnp.ndarray]:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims))
    return [jax.random.uniform(k, (I, rank), dtype=dtype)
            for k, I in zip(keys, dims)]


def build_views(at: AltoTensor) -> dict[int, OrientedView]:
    """Oriented views only for modes the heuristic routes that way
    (keeps the single-copy property for high-reuse tensors)."""
    views = {}
    for n in range(len(at.dims)):
        if (heuristics.choose_traversal(at.meta, n)
                is heuristics.Traversal.OUTPUT_ORIENTED):
            views[n] = oriented_view(at, n)
    return views


def _sweep(at: AltoTensor, views, factors, lam, normX2):
    """One CP-ALS sweep over all modes; returns factors, lam, fit."""
    N = len(factors)
    grams = [A.T @ A for A in factors]
    mttkrp_last = None
    for n in range(N):
        V = None
        for m in range(N):
            if m == n:
                continue
            V = grams[m] if V is None else V * grams[m]
        M = mttkrp_adaptive(at, views, factors, n)        # (I_n, R)
        A = M @ jnp.linalg.pinv(V)
        lam = jnp.linalg.norm(A, axis=0)
        lam = jnp.where(lam > 0, lam, 1.0)
        A = A / lam[None, :]
        factors = list(factors)
        factors[n] = A
        grams[n] = A.T @ A
        mttkrp_last = (M, n)

    # Fit (Kolda & Bader): ||X - X̂||² = ||X||² + ||X̂||² - 2<X, X̂>
    M, n = mttkrp_last
    inner = jnp.sum(jnp.sum(factors[n] * M, axis=0) * lam)
    Vall = None
    for m in range(N):
        Vall = grams[m] if Vall is None else Vall * grams[m]
    norm_model2 = jnp.sum(jnp.outer(lam, lam) * Vall)
    resid2 = jnp.maximum(normX2 + norm_model2 - 2.0 * inner, 0.0)
    fit = 1.0 - jnp.sqrt(resid2) / jnp.sqrt(normX2)
    return factors, lam, fit


def cp_als(at: AltoTensor, rank: int, n_iters: int = 50, tol: float = 1e-5,
           seed: int = 0, views: dict[int, OrientedView] | None = None,
           factors: list[jnp.ndarray] | None = None) -> CpalsResult:
    if factors is None:
        factors = init_factors(at.dims, rank, seed=seed,
                               dtype=at.values.dtype)
    if views is None:
        views = build_views(at)
    lam = jnp.ones((rank,), dtype=at.values.dtype)
    normX2 = jnp.sum(at.values.astype(jnp.float32) ** 2)

    sweep = jax.jit(_sweep)
    fits: list[float] = []
    prev_fit = -np.inf
    it = 0
    for it in range(1, n_iters + 1):
        factors, lam, fit = sweep(at, views, factors, lam, normX2)
        fit = float(fit)
        fits.append(fit)
        if abs(fit - prev_fit) < tol:
            break
        prev_fit = fit
    return CpalsResult(lam=lam, factors=list(factors), fits=fits,
                       n_iters=it)


def reconstruct_values(coords: jnp.ndarray, lam: jnp.ndarray,
                       factors: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Model values at given coordinates (for residual checks)."""
    prod = lam[None, :].astype(factors[0].dtype)
    out = jnp.broadcast_to(prod, (coords.shape[0], lam.shape[0]))
    for m, A in enumerate(factors):
        out = out * A[coords[:, m]]
    return jnp.sum(out, axis=-1)
