"""CP-ALS on ALTO tensors (paper Alg. 1).

The MTTKRP bottleneck (line 11) runs through the execution-plan layer
(`core.plan`): the plan resolves the paper's adaptive heuristics into a
concrete kernel per mode — pure-jnp reference traversals by default on CPU,
Pallas kernels (interpret on CPU, Mosaic on TPU) when the plan says so.
Mesh-bearing plans (``make_plan(..., mesh=)``) transparently shard the
MTTKRP over the mesh's devices (`repro.dist.cpd`); the fully distributed
driver (sharded Gram matrices too) is `dist.cpd.distributed_cp_als`.
Gram matrices, the pseudo-inverse solve, and normalization are dense JAX.
One full sweep over all modes is a single jitted function; the outer
iteration is a host loop with fit-based early stopping (as in the paper's
setup).

Fit tracking: the sweep returns the MTTKRP of its *last* mode update — the
one matrix for which ``<X, X̂> = Σ_r λ_r <A_n[:,r], M[:,r]>`` holds exactly
(every other mode's MTTKRP is stale by the end of the sweep, computed
against factors that were subsequently overwritten). The Kolda–Bader
residual identity ``||X-X̂||² = ||X||² + ||X̂||² − 2<X,X̂>`` is then
evaluated on the host in float64: near convergence the three terms agree to
~1e-5 relative, so combining them in float32 inside the jitted sweep left
cancellation noise (~1e-3 in fit units) larger than the per-iteration fit
gain and the reported fit sequence was not monotone even though the
iterates were.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import health as health_mod
from repro.core import ingest as ingest_mod
from repro.core import plan as plan_mod
from repro.core.alto import AltoTensor, OrientedView
from repro.core.mttkrp import mttkrp_adaptive


@dataclasses.dataclass
class CpalsResult:
    lam: jnp.ndarray                 # (R,) component weights
    factors: list[jnp.ndarray]       # per-mode (I_n, R)
    fits: list[float]                # fit per iteration
    n_iters: int
    plan: plan_mod.ExecutionPlan | None = None
    # Guard outcome when the solve ran with guard=True (None otherwise).
    # rolled_back=True means the returned state is the last good iterate
    # before a non-finite or fit-regressing sweep (core.health).
    health: health_mod.HealthReport | None = None


def init_factors(dims: Sequence[int], rank: int, seed: int = 0,
                 dtype=jnp.float32) -> list[jnp.ndarray]:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims))
    return [jax.random.uniform(k, (I, rank), dtype=dtype)
            for k, I in zip(keys, dims)]


def build_views(at: AltoTensor,
                plan: plan_mod.ExecutionPlan | None = None
                ) -> dict[int, OrientedView]:
    """Oriented views only for modes the plan routes that way
    (keeps the single-copy property for high-reuse tensors). Served
    from the process-wide view cache (`core.views`): device-built by
    default, one build per (tensor, mode) shared across drivers."""
    if plan is None:
        plan = plan_mod.make_plan(at.meta, rank=1)  # traversal is rank-free
    return plan_mod.build_views(at, plan)


def _sweep(plan, at: AltoTensor, views, factors, lam, gram_fn=None):
    """One CP-ALS sweep over all modes.

    Returns (factors, lam, M_last): M_last is the final mode's MTTKRP, the
    only one consistent with the returned factors — the host-side fit
    evaluation depends on it being fresh, not reused from earlier modes.

    ``gram_fn`` overrides the Gram computation (default dense AᵀA); the
    distributed driver passes `dist.cpd.sharded_gram` so Grams are
    row-sharded and psum-combined. MTTKRP placement needs no hook — a
    mesh-bearing plan already routes it through the sharded merge.
    """
    gram = gram_fn if gram_fn is not None else (lambda A: A.T @ A)
    N = len(factors)
    grams = [gram(A) for A in factors]
    M = None
    for n in range(N):
        V = None
        for m in range(N):
            if m == n:
                continue
            V = grams[m] if V is None else V * grams[m]
        M = mttkrp_adaptive(at, views, factors, n, plan=plan)  # (I_n, R)
        A = M @ jnp.linalg.pinv(V)
        lam = jnp.linalg.norm(A, axis=0)
        lam = jnp.where(lam > 0, lam, 1.0)
        A = A / lam[None, :]
        factors = list(factors)
        factors[n] = A
        grams[n] = gram(A)
    return factors, lam, M


def _fit_host(M_last, factors, lam, normX2: float) -> float:
    """Kolda–Bader fit from sweep-consistent state, in host float64."""
    if normX2 == 0.0:
        # All-zero (or empty) tensor: the zero model is exact. Without
        # this the fit divides by sqrt(0) and reports NaN forever.
        return 1.0
    n = len(factors) - 1
    fs = [np.asarray(A, np.float64) for A in factors]
    lam64 = np.asarray(lam, np.float64)
    M = np.asarray(M_last, np.float64)
    inner = float(((fs[n] * M).sum(axis=0) * lam64).sum())
    V = np.ones((lam64.size, lam64.size))
    for A in fs:
        V *= A.T @ A
    norm_model2 = float((np.outer(lam64, lam64) * V).sum())
    resid2 = max(normX2 + norm_model2 - 2.0 * inner, 0.0)
    return float(1.0 - np.sqrt(resid2) / np.sqrt(normX2))


def cp_als(at: AltoTensor, rank: int, n_iters: int = 50, tol: float = 1e-5,
           seed: int = 0, views: dict[int, OrientedView] | None = None,
           factors: list[jnp.ndarray] | None = None,
           plan: plan_mod.ExecutionPlan | None = None,
           gram_fn=None, tune: str = "off",
           warm_start=None, guard: bool = False,
           guard_slack: float = 1e-3) -> CpalsResult:
    """CP-ALS driver. ``tune`` ("off"|"auto"|"force"|"search") selects measured
    plans from the autotuner's persistent store — the tensor data is in
    hand here, so a store miss under "auto"/"force" runs the measured
    tuner (`core.autotune`) before the first sweep.

    ``warm_start`` seeds the sweep from a previous solve — a
    `CpalsResult`, ``(lam, factors)``, or a factor list — with rows for
    newly-grown extents filled from the seeded init
    (`ingest.grow_factors`). After `ingest.append_delta` this turns the
    per-delta cost into sweeps-from-converged instead of from-scratch.

    ``guard=True`` runs the per-sweep health guards (`core.health`): a
    jitted all-finite check over the sweep's outputs plus the host-side
    fit-monotonicity check (a drop beyond ``guard_slack``), rolling back
    to the last good (factors, λ) and stopping on violation. On finite
    inputs the guard changes nothing — the returned trajectory stays
    bitwise identical to an unguarded run.
    """
    if factors is not None and warm_start is not None:
        raise ValueError("pass factors= or warm_start=, not both")
    if warm_start is not None:
        lam_w, factors = ingest_mod.grow_factors(
            warm_start, at.dims, rank, seed=seed, dtype=at.values.dtype)
        if lam_w is not None:
            # Fold the previous weights in so the first sweep starts at
            # the previous MODEL, not its column-normalized shadow.
            factors = list(factors)
            factors[0] = factors[0] * lam_w[None, :]
    if plan is None:
        plan = plan_mod.make_plan(at.meta, rank, tune=tune, at=at)
    elif plan.rank != rank:
        raise ValueError(f"plan was built for rank {plan.rank}, "
                         f"cp_als called with rank {rank}")
    if at.meta.nnz == 0:
        # Degenerate tenant input (a public serving endpoint WILL see
        # these): the zero model is the exact decomposition. Well-defined
        # result — zero factors, zero weights, fit 1.0 — not an exception
        # or a NaN fit trajectory.
        dtype = at.values.dtype
        return CpalsResult(lam=jnp.zeros((rank,), dtype),
                           factors=[jnp.zeros((I, rank), dtype)
                                    for I in at.dims],
                           fits=[1.0], n_iters=0, plan=plan)
    if factors is None:
        factors = init_factors(at.dims, rank, seed=seed,
                               dtype=at.values.dtype)
    if views is None:
        views = plan_mod.build_views(at, plan)
    lam = jnp.ones((rank,), dtype=at.values.dtype)
    normX2 = float((np.asarray(at.values, np.float64) ** 2).sum())

    sweep_fn = functools.partial(_sweep, plan, gram_fn=gram_fn)
    # Streaming (out-of-core) plans keep the sweep a host loop: the
    # chunked executors are themselves host loops over per-chunk jitted
    # calls, and a host-resident stream is not a jit operand. The dense
    # algebra still runs the same XLA kernels per op.
    sweep = sweep_fn if plan.streaming is not None else jax.jit(sweep_fn)
    report = health_mod.HealthReport() if guard else None
    fits: list[float] = []
    prev_fit = -np.inf
    it = 0
    for it in range(1, n_iters + 1):
        good = (factors, lam)
        factors, lam, M_last = sweep(at, views, factors, lam)
        pd = faults.fire("cpals.nan")
        if pd is not None:
            # Poison the LAST factor: the next sweep's first mode update
            # consumes it through the Gram products, so an unguarded run
            # propagates the poison everywhere (the realistic hazard).
            poison = pd.get("value", float("nan"))
            factors = list(factors)
            factors[-1] = factors[-1].at[0, 0].set(poison)
        fit = _fit_host(M_last, factors, lam, normX2)
        if guard:
            report.checks += 1
            reason = None
            if not np.isfinite(fit) or not health_mod.all_finite(
                    [*factors, lam, M_last]):
                reason = f"non-finite sweep output at iteration {it}"
            elif fit < health_mod.FIT_FLOOR:
                # Huge-but-finite iterate: must be stopped HERE — its
                # Gram products overflow the next sweep (health.FIT_FLOOR)
                reason = f"fit diverged to {fit:.3e} at iteration {it}"
            elif fits and fit < fits[-1] - guard_slack:
                reason = (f"fit regressed {fits[-1]:.6f} -> {fit:.6f} "
                          f"at iteration {it}")
            if reason is not None:
                report.violations += 1
                report.rolled_back = True
                report.reason = reason
                factors, lam = good
                it -= 1
                break
        fits.append(fit)
        if abs(fit - prev_fit) < tol:
            break
        prev_fit = fit
    return CpalsResult(lam=lam, factors=list(factors), fits=fits,
                       n_iters=it, plan=plan, health=report)


def reconstruct_values(coords: jnp.ndarray, lam: jnp.ndarray,
                       factors: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Model values at given coordinates (for residual checks)."""
    prod = lam[None, :].astype(factors[0].dtype)
    out = jnp.broadcast_to(prod, (coords.shape[0], lam.shape[0]))
    for m, A in enumerate(factors):
        out = out * A[coords[:, m]]
    return jnp.sum(out, axis=-1)
