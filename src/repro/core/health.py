"""Health guards: finite/monotonicity checks, rollback, plan degradation.

The drivers' numerical contract — monotone CP-ALS fit, finite factors —
holds for finite inputs, but a serving endpoint sees the other kind: a
tenant whose values carry NaN/Inf poisons every subsequent sweep, and in
a vmapped bucket its slot stays poisoned while bucket-mates keep paying
for its flops. The guards here are the detection half of the resilience
tentpole (`docs/resilience.md`); `core.faults` provides the injection
half and `launch.serve_cpd` the recovery ladders.

Two guard shapes, both opt-in (``guard=`` on `cpals.cp_als` /
`cpapr.cp_apr`, per-tenant inside `core.batched`):

* **finite guard** — one fused jitted all-finite reduction over the
  sweep's outputs (:func:`all_finite`, per-tenant
  :func:`tenants_finite`). Jitted so the check is a single tiny
  executable per pytree shape, not a host visit per array; the cost is
  one pass over the factors per sweep, which the serving benchmark pins
  at <= 5% of an unguarded sweep (`benchmarks/bench_serving.py`).
* **fit-monotonicity guard** — CP-ALS's fit sequence is monotone
  non-decreasing (PR 1 fixed the float32 cancellation that used to mask
  this); a drop beyond ``slack`` means the iterate left the admissible
  region (huge-but-finite poison, broken kernel) and the last good state
  is the answer to return. Host-side: the fit is already a host scalar.

On violation the drivers roll back to the last good (factors, lam) —
the previous iterate, retained by reference (arrays are immutable, a
rollback copies nothing) — stop, and report a :class:`HealthReport` on
the result instead of raising: a poisoned tenant gets a structured,
finite, degraded answer, not a stack trace.

:func:`degrade_plan` is the plan half of the recovery ladders: given a
plan and the exception it produced, return the next-softer plan (halve
``chunk_m`` on streaming OOM, drop Pallas to the reference backend on a
kernel/dispatch failure) or None when out of rungs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_mod


# Divergence floor for the fit guard. The Kolda–Bader fit is <= 1 by
# construction and can dip mildly negative from a bad init, but a fit
# below this floor means an iterate left the admissible region with
# huge-but-FINITE magnitude (e.g. a ~1e30 poisoned entry: its Gram
# product overflows float32 to inf and XLA's SVD on a non-finite matrix
# can spin forever). The guard must catch that at the iteration that
# PRODUCED it — before the next sweep consumes it — so all-finite checks
# alone are not enough.
FIT_FLOOR = -1e8


@dataclasses.dataclass
class HealthReport:
    """Per-solve guard outcome, attached to CpalsResult/CpaprResult."""
    guarded: bool = True
    checks: int = 0               # guard evaluations run
    violations: int = 0           # non-finite or non-monotone events seen
    rolled_back: bool = False     # result is the last good iterate
    reason: str | None = None     # first violation, human-readable


def _inexact(arrays):
    return [jnp.asarray(a) for a in arrays
            if jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact)]


@jax.jit
def _all_finite_core(arrays):
    ok = jnp.asarray(True)
    for a in arrays:
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(a)))
    return ok


def all_finite(arrays) -> bool:
    """True iff every inexact array is entirely finite (one fused jitted
    reduction; jit caches one executable per shape list)."""
    xs = _inexact(arrays)
    if not xs:
        return True
    return bool(_all_finite_core(xs))


@jax.jit
def _tenants_finite_core(arrays):
    ok = None
    for a in arrays:
        fin = jnp.all(jnp.isfinite(a.reshape(a.shape[0], -1)), axis=1)
        ok = fin if ok is None else jnp.logical_and(ok, fin)
    return ok


def tenants_finite(arrays) -> np.ndarray:
    """Per-tenant all-finite mask over stacked (cap, ...) leaves.

    The batched drivers call this once per sweep to quarantine poisoned
    slots without touching bucket-mates (vmap keeps tenants' lanes
    independent, so NaN cannot cross slots — but an unguarded bucket
    still burns ``n_iters`` full sweeps waiting for a fit that will
    never converge, and returns the poison to the caller).
    """
    xs = _inexact(arrays)
    if not xs:
        raise ValueError("tenants_finite needs at least one inexact array")
    return np.asarray(_tenants_finite_core(xs))


# ---------------------------------------------------------------------------
# Degradation ladder (plan half; the store half lives in serve_cpd)
# ---------------------------------------------------------------------------

def degrade_plan(plan: plan_mod.ExecutionPlan, exc: BaseException):
    """Next-softer plan after ``plan`` failed with ``exc``, or (None, None).

    Rungs, in order:

    1. streaming OOM → halve ``chunk_m`` (kept a multiple of the plan's
       largest block_m so chunk-parity alignment survives) and re-count
       chunks. Repeatable until one aligned chunk remains.
    2. Pallas kernel/dispatch failure → same routing on the reference
       (pure-jnp) backend. The reference path is tolerance-level against
       Pallas, so the degraded answer is still a real answer.

    Transient faults (I/O, allocator blips — `faults.is_transient`)
    should be *retried*, not degraded; callers check that first.
    """
    msg = str(exc)
    if plan.streaming is not None and "RESOURCE_EXHAUSTED" in msg:
        align = max(m.block_m for m in plan.modes)
        cm = plan.streaming.chunk_m
        new_cm = max(align, ((cm // 2) // align) * align)
        if new_cm < cm:
            streaming = dataclasses.replace(
                plan.streaming, chunk_m=new_cm,
                n_chunks=plan_mod.chunk_count(plan.meta, new_cm))
            return (dataclasses.replace(plan, streaming=streaming),
                    f"halved chunk_m {cm} -> {new_cm}")
        # out of chunk headroom: fall through to the backend rung
    if plan.backend == "pallas":
        return (dataclasses.replace(plan, backend="reference"),
                "pallas -> reference backend")
    return None, None
