"""Shape-class bucketing: collapse tenant tensors onto a few executables.

The production workload the ROADMAP targets is not one giant tensor — it
is thousands of small-to-medium decompositions in flight at once
(per-user / per-cohort anomaly streams, paper §1). Today every tenant's
`AltoMeta` is its own jit trace: distinct dims pick distinct encodings,
distinct nnz pick distinct stream lengths, and the data-dependent meta
fields (``temp_rows``, ``fiber_reuse``) differ even between tensors of
identical shape — so a thousand tenants means a thousand compiles.

A :class:`ShapeClass` deletes all three sources of trace divergence:

* **dims** round up per mode to the next power of two. Embedding a
  tensor in larger mode extents is exact — coordinates are unchanged,
  the extra factor rows receive no contributions and (zero-initialized)
  stay exactly zero through every CP-ALS/CP-APR update, so they never
  perturb Gram matrices, λ, or the fit.
* **nnz** rounds up to the next power of two (floored at the partition
  count) and the COO stream is padded to it with the SAME rule the
  kernels already rely on (`ops.pad_sorted_stream`): replicated copies
  of the final element carrying **zero values**, which contribute
  nothing to any reduction. An empty stream pads with the all-zero
  coordinate.
* **meta** canonicalizes: :func:`canonical_meta` builds the one
  `AltoMeta` every member of the class shares — ``temp_rows`` bound by
  the padded class dims (the only bound that holds for every member:
  a partition's mode interval can span the whole extent) and
  ``fiber_reuse`` fixed at the no-reuse worst case 1.0, which routes
  every mode to the output-oriented family (the batchable traversal).

The canonical meta is a pure function of the class, so plans built from
it (`plan.make_class_plan`) are **class-keyed**: one compiled executable
and one autotuner plan-store entry (`autotune.class_plan_key`) serve
every tenant the class ever admits. The padding-overhead tradeoff is the
price — a tenant just past a power-of-two boundary computes on up to 2×
its nonzeros (see docs/known-issues.md) — bought against one trace per
class instead of one per tenant.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.alto import AltoMeta, AltoTensor
from repro.core.encoding import make_encoding
from repro.sparse.tensor import SparseTensor

DEFAULT_PARTITIONS = 8


def _next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class ShapeClass:
    """Hashable bucket descriptor: everything a compiled executable keys on.

    ``dims`` and ``nnz`` are the PADDED class values (per-mode pow2
    extents; pow2 stream length, a multiple of ``n_partitions``), never a
    member tensor's real ones.
    """
    dims: tuple[int, ...]
    nnz: int
    n_partitions: int
    rank: int
    dtype: str = "float32"

    @property
    def order(self) -> int:
        return len(self.dims)

    def admits(self, x: SparseTensor) -> bool:
        """True iff ``x`` fits this class (dims bounded, nnz bounded)."""
        return (len(x.dims) == self.order and x.nnz <= self.nnz
                and all(d <= cd for d, cd in zip(x.dims, self.dims)))


def classify(x: SparseTensor, rank: int,
             n_partitions: int = DEFAULT_PARTITIONS) -> ShapeClass:
    """The shape class a tenant tensor buckets into.

    Per-mode pow2 dim rounding + pow2 nnz rounding (floored at the
    partition count so the padded stream is always a whole number of
    balanced partitions — pow2 ≥ L is automatically a multiple of a
    pow2 L, so `alto.build` adds no further padding of its own).
    """
    L = max(1, int(n_partitions))
    nnz_c = max(_next_pow2(x.nnz), _next_pow2(L))
    return ShapeClass(dims=tuple(_next_pow2(d) for d in x.dims),
                      nnz=nnz_c, n_partitions=L, rank=int(rank),
                      dtype=str(np.dtype(x.values.dtype)))


def pad_to_class(x: SparseTensor, sc: ShapeClass) -> SparseTensor:
    """Embed ``x`` into its class: class dims, stream padded to class nnz.

    The pad elements come from the shared `ops.pad_sorted_stream` rule —
    replicated copies of the final COO element with zero values (they
    land inside an existing coordinate's run after the ALTO sort and
    contribute nothing to any reduction). An nnz=0 tenant pads with the
    all-zero coordinate, same as the rule's empty-stream branch.
    """
    if not sc.admits(x):
        raise ValueError(f"tensor dims={x.dims} nnz={x.nnz} does not fit "
                         f"shape class {sc}")
    coords = np.asarray(x.coords, np.int32)
    values = np.asarray(x.values)
    pad = sc.nnz - x.nnz
    if pad:
        if x.nnz == 0:
            pad_coords = np.zeros((pad, sc.order), np.int32)
        else:
            pad_coords = np.repeat(coords[-1:], pad, axis=0)
        coords = np.concatenate([coords, pad_coords], axis=0)
        values = np.concatenate(
            [values, np.zeros((pad,), values.dtype)], axis=0)
    return SparseTensor(sc.dims, coords, values)


def canonical_meta(sc: ShapeClass) -> AltoMeta:
    """The one `AltoMeta` every member of the class shares.

    A pure function of the class — no data-dependent fields — so plans,
    compiled executables, and autotuner store entries keyed on it are
    keyed on the class itself. ``temp_rows`` uses the padded class dims
    (a partition's mode interval can span the whole extent, so the dim
    is the only bound valid for every member — the plan layer's VMEM
    models become conservative class-wide bounds); ``fiber_reuse`` is
    the no-reuse worst case 1.0, routing every mode output-oriented
    (the traversal the batched layer can vmap).
    """
    return AltoMeta(enc=make_encoding(sc.dims), nnz=sc.nnz,
                    n_partitions=sc.n_partitions,
                    temp_rows=tuple(sc.dims),
                    fiber_reuse=(1.0,) * sc.order)


def canonicalize_tensor(at: AltoTensor, sc: ShapeClass) -> AltoTensor:
    """``at`` (built from a class-padded tensor) with the canonical meta.

    The built meta's data-dependent fields (temp_rows, fiber_reuse)
    differ per tenant; swapping in the canonical meta makes the tensor a
    valid representative for class-keyed tuning (`autotune` requires
    ``at.meta`` to match the meta being tuned) and for the batched
    stacked pytrees. The stream/partition arrays are shared, not copied.
    """
    expect = canonical_meta(sc)
    if (at.meta.enc != expect.enc or at.words.shape[0] != sc.nnz
            or at.meta.n_partitions != sc.n_partitions):
        raise ValueError(f"tensor (dims={at.meta.dims}, "
                         f"Mp={at.words.shape[0]}) was not built from a "
                         f"pad_to_class({sc}) input")
    return AltoTensor(meta=expect, words=at.words, values=at.values,
                      part_start=at.part_start, part_end=at.part_end)
