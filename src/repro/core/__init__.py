# The paper's primary contribution: the ALTO linearized sparse tensor
# format and the adaptive parallel TD algorithms built on it.
from repro.core.encoding import AltoEncoding, make_encoding
from repro.core.alto import (AltoTensor, AltoMeta, OrientedView, build,
                             oriented_view, linearize, delinearize,
                             to_sparse)
from repro.core import autotune, heuristics, mttkrp, plan, cpals, cpapr
from repro.core.heuristics import Traversal
from repro.core.plan import ExecutionPlan, ModePlan, make_plan
from repro.core.autotune import tune_plan

__all__ = [
    "AltoEncoding", "make_encoding", "AltoTensor", "AltoMeta",
    "OrientedView", "build", "oriented_view", "linearize", "delinearize",
    "to_sparse", "autotune", "heuristics", "mttkrp", "plan", "cpals",
    "cpapr", "Traversal", "ExecutionPlan", "ModePlan", "make_plan",
    "tune_plan",
]
