# The paper's primary contribution: the ALTO linearized sparse tensor
# format and the adaptive parallel TD algorithms built on it.
from repro.core.encoding import AltoEncoding, make_encoding
from repro.core.alto import (AltoTensor, AltoMeta, OrientedView, build,
                             build_device, oriented_view,
                             oriented_view_device, linearize, delinearize,
                             to_sparse)
from repro.core import (autotune, heuristics, mttkrp, plan, cpals, cpapr,
                        views)
from repro.core.heuristics import Traversal
from repro.core.plan import (ExecutionPlan, ModePlan, make_plan,
                             resident_bytes)
from repro.core.autotune import tune_plan
from repro.core.views import get_view

__all__ = [
    "AltoEncoding", "make_encoding", "AltoTensor", "AltoMeta",
    "OrientedView", "build", "build_device", "oriented_view",
    "oriented_view_device", "linearize", "delinearize", "to_sparse",
    "autotune", "heuristics", "mttkrp", "plan", "cpals", "cpapr", "views",
    "Traversal", "ExecutionPlan", "ModePlan", "make_plan",
    "resident_bytes", "tune_plan", "get_view",
]
