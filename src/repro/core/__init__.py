# The paper's primary contribution: the ALTO linearized sparse tensor
# format and the adaptive parallel TD algorithms built on it.
from repro.core.encoding import AltoEncoding, make_encoding
from repro.core.alto import (AltoTensor, AltoMeta, OrientedView, build,
                             build_device, oriented_view,
                             oriented_view_device, linearize, delinearize,
                             to_sparse, merge_coo, merge_reference,
                             grown_dims)
from repro.core import (autotune, batched, faults, health, heuristics,
                        ingest, mttkrp, plan, cpals, cpapr, search,
                        shapeclass, stream, views)
from repro.core.ingest import append_delta, append_linearized, grow_factors
from repro.core.heuristics import Traversal
from repro.core.plan import (ExecutionPlan, ModePlan, make_plan,
                             make_class_plan, resident_bytes)
from repro.core.autotune import tune_plan
from repro.core.search import search_plan
from repro.core.shapeclass import ShapeClass, classify, pad_to_class
from repro.core.batched import batched_cp_als, batched_cp_apr
from repro.core.views import get_view

__all__ = [
    "AltoEncoding", "make_encoding", "AltoTensor", "AltoMeta",
    "OrientedView", "build", "build_device", "oriented_view",
    "oriented_view_device", "linearize", "delinearize", "to_sparse",
    "merge_coo", "merge_reference", "grown_dims",
    "autotune", "batched", "faults", "health", "heuristics", "ingest",
    "mttkrp", "plan", "cpals", "cpapr", "search", "shapeclass", "stream",
    "views", "append_delta", "append_linearized", "grow_factors",
    "Traversal", "ExecutionPlan", "ModePlan", "make_plan",
    "make_class_plan", "resident_bytes", "tune_plan", "search_plan",
    "ShapeClass", "classify", "pad_to_class",
    "batched_cp_als", "batched_cp_apr", "get_view",
]
