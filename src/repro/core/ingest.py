"""Incremental ingest: jitted delta-merge into the resident ALTO stream.

Real workloads mutate the tensor — nonzeros arrive continuously — and a
from-scratch `alto.build_device` per delta batch throws away the one
expensive invariant the resident tensor already holds: its stream is
SORTED. This module keeps it. `append_delta` linearizes the delta batch
in-jit, concatenates it after the resident stream, and runs the SAME
stable multi-word key sort `build_device` uses (`encoding.sort_by_key`)
over the combined stream, then re-derives the partition bounding boxes
and fiber counts inside the same jitted core — zero host callbacks, one
trace per static merge meta (the Dynasor/ReLATE dynamic-relayout regime
from PAPERS.md, on PR 5's device-ingest machinery).

Bit-for-bit parity with the host rebuild (`alto.merge_reference`) falls
out of sort stability: the resident stream is the stable sort of the old
COO, so stably sorting ``[resident stream; delta batch]`` equals stably
sorting the concatenated COO itself — element order, padding, boxes, and
meta all identical to `build(merge_coo(...))`. Duplicate-coordinate
policies preserve that exactness by construction:

* ``"sum"`` keeps every entry (duplicates sit adjacent after the sort
  and accumulate in downstream segment reductions, exactly as `build`
  treats duplicate COO input today) — a pure permutation, trivially
  bitwise.
* ``"last"`` masks all but the final occurrence of each duplicate key to
  value 0 — a pure mask from sorted adjacency, no arithmetic, so there
  is no float-association hazard; writing value 0 acts as a delete.

Real group-summation was deliberately rejected: ``np.add.at``
(sequential) vs a jitted segment-sum (tree) associate float additions
differently, which would break the bit-parity contract every other
subsystem (views cache, chunked executors, Mosaic port) leans on.

Extent growth re-encodes in-jit: when the delta pushes a mode past its
extent, `encoding.make_encoding` may re-assign index bits, so the
resident words are round-tripped ``linearize(new, delinearize(old, w))``
— an exact integer bit transform — before the merge sort.

On top: `grow_factors` seeds warm-start CP solves from a previous
result, padding factor rows when extents expanded, so per-delta latency
is sweeps-from-converged instead of from-scratch (`cpals.cp_als` /
`cpapr.cp_apr` take ``warm_start=``).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alto
from repro.core import encoding as enc_mod
from repro.core import faults
from repro.core import views as views_mod
from repro.core.alto import AltoMeta, AltoTensor
from repro.core.encoding import AltoEncoding, make_encoding

POLICIES = alto.MERGE_POLICIES


# ---------------------------------------------------------------------------
# The jitted merge core (cached per static merge meta in alto's LRU)
# ---------------------------------------------------------------------------

def _merge_device_fn(old_enc: AltoEncoding, new_enc: AltoEncoding, L: int,
                     M: int, res_len: int, D: int, policy: str,
                     compute_reuse: bool, val_dtype, delta_form: str):
    """The cached jitted delta-merge core for one static merge meta.

    ``delta_form`` is "coords" ((D, N) int32, linearized in-jit — the
    local `append_delta` path) or "words" ((D, W) u32 already linearized
    under ``new_enc`` — the sharded ingest path, where linearization ran
    under `shard_map`). ``res_len``/``M`` pin the resident padded/real
    lengths so the trace-once contract keys on the full static shape.
    """
    key = ("merge", old_enc, new_enc, L, M, res_len, D, policy,
           bool(compute_reuse), jnp.dtype(val_dtype).name, delta_form)
    N, W = new_enc.ndim, new_enc.n_words
    MD = M + D
    chunk = -(-max(MD, L) // L)
    Mp = chunk * L
    not_masks = ~new_enc.mode_masks()                    # (N, W) u32

    def core(res_words, res_values, delta, delta_values):
        alto._DEVICE_INGEST_TRACES["merge"] += 1         # trace-time only
        rw = res_words[:M]
        if new_enc != old_enc:
            # Extent growth re-assigned index bits: exact integer
            # round-trip of the resident words into the new layout.
            rw = alto.linearize(new_enc, alto.delinearize(old_enc, rw))
        dw = (delta if delta_form == "words"
              else alto.linearize(new_enc, delta))
        words = jnp.concatenate([rw, dw], axis=0)        # (MD, W)
        values = jnp.concatenate([res_values[:M], delta_values], axis=0)
        # Resident is already sorted; the stable sort of [sorted; delta]
        # IS the stable sort of the concatenated COO (ties resident-
        # first, then delta input order) — the host-parity invariant.
        words, values = enc_mod.sort_by_key(words, values)
        if policy == "last" and MD > 1:
            is_last = jnp.concatenate(
                [jnp.any(words[1:] != words[:-1], axis=-1),
                 jnp.ones((1,), bool)])
            values = jnp.where(is_last, values, jnp.zeros_like(values))
        if Mp > MD:
            # build()'s padding rule: value-0 copies of the last element.
            pad = Mp - MD
            pw = (jnp.zeros((pad, W), jnp.uint32) if MD == 0
                  else jnp.broadcast_to(words[-1:], (pad, W)))
            words = jnp.concatenate([words, pw])
            values = jnp.concatenate(
                [values, jnp.zeros((pad,), values.dtype)])
        # delinearize is linearize's exact inverse, so these coords equal
        # the carried-column coords build() takes its boxes from.
        cc = alto.delinearize(new_enc, words).reshape(L, chunk, N)
        part_start = jnp.min(cc, axis=1).astype(jnp.int32)
        part_end = jnp.max(cc, axis=1).astype(jnp.int32)
        if compute_reuse and MD > 0:
            fibers = jnp.stack([
                enc_mod.count_distinct(
                    words[:MD] & jnp.asarray(not_masks[n])[None, :])
                for n in range(N)])
        else:
            fibers = jnp.ones((N,), jnp.int32)
        return words, values, part_start, part_end, fibers

    return alto._cached_ingest_fn(key, lambda: jax.jit(core))


def _finalize(fn_out, new_enc: AltoEncoding, MD: int, L: int,
              compute_reuse: bool) -> AltoTensor:
    """Host meta finalization — same tiny transfer as `build_device`:
    the (L, N) boxes and N fiber counts, never the O(nnz) stream."""
    words, vals, part_start, part_end, fibers = fn_out
    ps = np.asarray(part_start)
    pe = np.asarray(part_end)
    temp_rows = tuple(int((pe[:, n] - ps[:, n]).max()) + 1
                      for n in range(new_enc.ndim))
    if compute_reuse:
        reuse = tuple(float(MD) / max(1, int(f))
                      for f in np.asarray(fibers))
    else:
        reuse = tuple(float("nan") for _ in range(new_enc.ndim))
    meta = AltoMeta(enc=new_enc, nnz=MD, n_partitions=L,
                    temp_rows=temp_rows, fiber_reuse=reuse)
    return AltoTensor(meta=meta, words=words, values=vals,
                      part_start=part_start, part_end=part_end)


def _append(at: AltoTensor, delta, delta_values, new_dims: tuple[int, ...],
            delta_form: str, policy: str, n_partitions, compute_reuse,
            invalidate_stale: bool) -> AltoTensor:
    if policy not in POLICIES:
        raise ValueError(f"policy {policy!r}: expected one of {POLICIES}")
    old_enc = at.meta.enc
    new_enc = make_encoding(new_dims)
    L = (at.meta.n_partitions if n_partitions is None
         else max(1, int(n_partitions)))
    if compute_reuse is None:
        # Match the resident tensor's choice (NaN reuse == it was off).
        compute_reuse = not math.isnan(at.meta.fiber_reuse[0])
    M = at.meta.nnz
    D = int(delta.shape[0])
    fn = _merge_device_fn(old_enc, new_enc, L, M, int(at.words.shape[0]),
                          D, policy, bool(compute_reuse), at.values.dtype,
                          delta_form)
    # Interruption site: the merge is functional (the resident tensor is
    # never mutated), so a kill here leaves `at` fully serviceable and a
    # retry re-runs the identical jitted program.
    faults.inject("ingest.merge")
    out = fn(at.words, at.values, delta, delta_values)
    new_at = _finalize(out, new_enc, M + D, L, bool(compute_reuse))
    if invalidate_stale:
        # Surgical: only modes whose content fingerprint moved lose their
        # cached views — a no-op append (empty delta, "sum") drops
        # nothing and the old views keep serving the merged tensor.
        views_mod.invalidate_changed(at, new_at)
    return new_at


def append_delta(at: AltoTensor, coords, values, *, policy: str = "sum",
                 dims: Sequence[int] | None = None,
                 n_partitions: int | None = None,
                 compute_reuse: bool | None = None,
                 invalidate_stale: bool = True) -> AltoTensor:
    """Merge a COO delta batch into ``at`` on device.

    Bit-identical to `alto.merge_reference(at, coords, values, ...)` —
    the from-scratch host rebuild — with the delta linearized, merge-
    sorted, policy-masked, and re-finalized inside one jitted core.
    Extents grow automatically to cover the delta (``dims`` overrides,
    e.g. to pre-reserve headroom so the encoding stays put across many
    appends); ``n_partitions`` defaults to the resident tiling. The new
    tensor's meta counts ``at.nnz + len(values)`` entries — duplicates
    are accumulated ("sum") or masked ("last"), never compacted, keeping
    the merged size static for jit.
    """
    coords = np.asarray(coords, dtype=np.int32).reshape(-1, len(at.dims))
    new_dims = alto.grown_dims(at.dims, coords, dims)
    return _append(at, jnp.asarray(coords),
                   jnp.asarray(values, dtype=at.values.dtype).reshape(-1),
                   new_dims, "coords", policy, n_partitions, compute_reuse,
                   invalidate_stale)


def append_linearized(at: AltoTensor, delta_words, values,
                      dims: Sequence[int], *, policy: str = "sum",
                      n_partitions: int | None = None,
                      compute_reuse: bool | None = None,
                      invalidate_stale: bool = True) -> AltoTensor:
    """`append_delta` for a delta already linearized under
    ``make_encoding(dims)`` — the distributed ingest entry point, where
    linearization ran shard-local under `shard_map` (`dist.cpd.
    sharded_append_delta`). ``dims`` is explicit because the words alone
    don't carry extents; it must cover the resident dims.
    """
    new_dims = alto.grown_dims(at.dims, np.empty((0, len(at.dims))), dims)
    return _append(at, jnp.asarray(delta_words),
                   jnp.asarray(values, dtype=at.values.dtype).reshape(-1),
                   new_dims, "words", policy, n_partitions, compute_reuse,
                   invalidate_stale)


# ---------------------------------------------------------------------------
# Warm-start factor growth (drivers' ``warm_start=`` backing)
# ---------------------------------------------------------------------------

def grow_factors(warm, dims: Sequence[int], rank: int, *, seed: int = 0,
                 dtype=None, positive: bool = False):
    """Adapt a previous solve's factors to (possibly grown) ``dims``.

    ``warm`` is a `CpalsResult`/`CpaprResult`, a ``(lam, factors)``
    tuple, or a bare factor list. Existing rows are kept verbatim (the
    converged state IS the warm start); rows for newly-grown extents are
    drawn from the drivers' seeded init so the fill is deterministic.
    Returns ``(lam, factors)`` with ``lam=None`` when ``warm`` carried no
    weights. Shrinking an extent or changing the rank has no meaningful
    warm state to keep and raises. ``positive=True`` (CP-APR) clamps the
    grown factors positive and re-normalizes columns to unit sum, the
    form the multiplicative updates expect.
    """
    lam = getattr(warm, "lam", None)
    factors = getattr(warm, "factors", None)
    if factors is None:
        if isinstance(warm, tuple) and len(warm) == 2:
            lam, factors = warm
        else:
            factors = warm
    factors = list(factors)
    dims = tuple(int(d) for d in dims)
    if len(factors) != len(dims):
        raise ValueError(f"warm start has {len(factors)} factors for "
                         f"{len(dims)} modes")
    if dtype is None:
        dtype = factors[0].dtype
    fresh = None
    out = []
    for n, (A, I) in enumerate(zip(factors, dims)):
        A = jnp.asarray(A, dtype=dtype)
        if A.ndim != 2 or A.shape[1] != rank:
            raise ValueError(f"warm factor {n} has shape {A.shape}; "
                             f"expected (*, {rank})")
        if A.shape[0] > I:
            raise ValueError(f"mode {n} shrank: warm factor has "
                             f"{A.shape[0]} rows, dims say {I}")
        if A.shape[0] < I:
            if fresh is None:
                from repro.core import cpals  # lazy: drivers import us
                fresh = cpals.init_factors(dims, rank, seed=seed,
                                           dtype=dtype)
            grown = fresh[n][A.shape[0]:I]
            if positive:
                # Small positive mass: perturbs the converged model as
                # little as possible while keeping the MU domain open.
                grown = jnp.maximum(grown, 0.1) / max(1, I)
            A = jnp.concatenate([A, grown], axis=0)
        if positive:
            A = jnp.maximum(A, 1e-10)
            A = A / jnp.sum(A, axis=0, keepdims=True)
        out.append(A)
    if lam is not None:
        lam = jnp.asarray(lam, dtype=dtype)
    return lam, out
