"""Execution plans: resolve the paper's adaptive heuristics into kernels.

Paper §4.2/§4.3 (Table 1). Invariants: plans are frozen and hashable
(static jit arguments, compiled-executable cache keys); every decision is
made from static `AltoMeta`, never from traced data.

The paper selects a traversal (recursive vs output-oriented) and a Π
policy (PRE vs OTF) per tensor/mode at runtime. On the JAX/TPU target
every such decision must be *static* — jit control flow cannot branch on
data — so this module turns the heuristics plus the tensor's static
metadata (`AltoMeta`) into an :class:`ExecutionPlan`: a frozen, hashable
description of exactly which compiled kernel variant runs for every
(mode, rank) combination, with all block sizes resolved.

The plan answers four questions the call sites used to guess at:

  * **traversal** per mode — `heuristics.choose_traversal` (fiber reuse vs
    the 4-memory-op buffered accumulation cost, §4.2), then for
    output-oriented modes the one-hot-merge vs scratch-carry refinement
    (`heuristics.choose_oriented_variant`: modelled HBM traffic, gated on
    the carry kernel's resident-output VMEM feasibility);
  * **rank blocking** (`r_block`) and **nonzero blocking** (`block_m`) —
    chosen so the Pallas kernel's per-grid-step VMEM footprint fits the
    accelerator budget, from `AltoMeta` (temp_rows, dims, dtype) instead of
    the caller hand-picking tile sizes;
  * **backend** — "pallas" (interpret-mode on CPU, Mosaic on TPU) or
    "reference" (the pure-jnp traversals in `core.mttkrp`, retained as the
    plan's always-available oracle backend);
  * **placement** — a plan built with ``mesh=`` routes every row reduction
    through the sharded oriented merge in `repro.dist.cpd`: the row-sorted
    nonzero stream is cut into per-device contiguous shards, each device
    runs the single-device segment reduction locally, and boundary-run
    carries plus the final rows are combined by ``psum``. Mesh-bearing
    plans force the output-oriented family for every mode (either
    variant — one-hot merge or shard-local scratch carry; row-range
    partitioning needs the row-sorted stream; the recursive traversal's
    partition intervals overlap arbitrarily across devices) and divide the
    VMEM budget by the shard count — shard-local blocks are sized as if
    all shards ran concurrently on one core, which is exactly what the
    fake-host-device test configuration does, and on real multi-chip
    meshes it only makes tiles conservatively smaller.

Because `ExecutionPlan` is hashable (``jax.sharding.Mesh`` included) it can
travel as a static jit argument and doubles as the key of the
compiled-executable cache in `kernels.ops`.

Two refinements over the original analytic model:

  * **Φ-specific footprints** — the fused CP-APR Φ kernels run at FULL
    rank with the whole (I_mode, R) B operand resident per grid step
    (plus the gathered block rows, and under ALTO-OTF the whole other
    factors); `phi_oriented_vmem_bytes` / `phi_recursive_vmem_bytes`
    account for that and co-constrain `choose_block_m`, closing the
    VMEM model gap the ROADMAP flagged (B resident but unbudgeted).
  * **measured plans** — ``make_plan(..., tune="auto"|"force")`` swaps
    the analytic answer for a measured one: `core.autotune` times every
    feasible candidate (`candidate_mode_plans`, static choice first)
    through the compiled-executable cache and persists winners in a
    versioned on-disk plan store, so later processes get the measured
    plan back with zero timing runs.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core import heuristics
from repro.core import mttkrp as core_mttkrp
from repro.core.alto import AltoMeta, AltoTensor, OrientedView, delinearize

# Per-core VMEM on current TPU generations; the budget is what the kernel's
# per-grid-step working set must fit into (interpret mode ignores it but we
# size identically so CPU tests exercise the TPU tiling decisions).
VMEM_BYTES = 16 * 1024 * 1024

# Output-oriented kernel: the in-block one-hot segment matmul is
# (block_m, block_m), so block_m is capped independently of the budget.
MAX_BLOCK_M = 1024
MIN_BLOCK_M = 8


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """Resolved execution choices for one target mode."""
    mode: int
    traversal: heuristics.Traversal
    r_block: int        # rank tile (always divides the plan rank)
    block_m: int        # oriented-kernel nonzero block (power of two)
    temp_rows: int      # recursive Temp height (static VMEM bound)
    vmem_bytes: int     # estimated per-grid-step footprint (MTTKRP kernel)
    phi_vmem_bytes: int = 0   # fused Φ kernel footprint (full rank, B resident)


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Out-of-core chunking decision (all ints — hashable, jit-static).

    Present on a plan iff the padded oriented stream plus the resident
    working set overflows the configured device byte budget; the chunked
    executors in `kernels.ops` then stream block-aligned slices of the
    host-resident stream (`core.stream.HostStream`) through device
    memory with cross-chunk carry chains.
    """
    chunk_m: int          # elements per chunk (multiple of every block_m)
    n_chunks: int         # ceil(stream_len / chunk_m) — the executed grid
    device_bytes: int     # the budget the choice was made against
    stream_bytes: int     # in-core working set that overflowed it


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static per-(tensor, rank) kernel routing, hashable for jit/caching."""
    meta: AltoMeta
    rank: int
    backend: str                       # "pallas" | "reference"
    interpret: bool | None             # None = auto (non-TPU -> interpret)
    pi_policy: heuristics.PiPolicy
    modes: tuple[ModePlan, ...]
    # Multi-device placement: shard the oriented row reduction over the
    # first axis of this mesh (None = single device). Mesh is hashable, so
    # mesh-bearing plans remain valid static jit arguments / cache keys.
    mesh: jax.sharding.Mesh | None = None
    # Out-of-core: non-None routes every oriented mode through the
    # chunked executors (the plan forces the carry family then). Default
    # None keeps plans from older stores / callers valid unchanged.
    streaming: StreamPlan | None = None

    def mode_plan(self, mode: int) -> ModePlan:
        return self.modes[mode]

    def traversals(self) -> tuple[str, ...]:
        return tuple(m.traversal.value for m in self.modes)

    @property
    def mesh_axis(self) -> str | None:
        """Mesh axis the row-sorted stream is sharded over (first axis)."""
        return self.mesh.axis_names[0] if self.mesh is not None else None

    @property
    def n_shards(self) -> int:
        """Row-range shard count (1 without a mesh)."""
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.mesh.axis_names[0]])


# ---------------------------------------------------------------------------
# VMEM budgeting
# ---------------------------------------------------------------------------

def _chunk_rows(meta: AltoMeta) -> int:
    """Per-partition element count after build()'s padding to L·chunk."""
    L = meta.n_partitions
    return -(-max(meta.nnz, L) // L)

def recursive_vmem_bytes(meta: AltoMeta, mode: int, r_block: int,
                         dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM of the recursive (Temp + one-hot) kernel.

    words + values tiles, the (chunk, T) one-hot operand, the (chunk, rb)
    Khatri-Rao/contribution tile, the (T, rb) Temp output, and the resident
    factor tiles of the other modes.
    """
    chunk = _chunk_rows(meta)
    T = meta.temp_rows[mode]
    W = meta.enc.n_words
    words = chunk * W * 4
    values = chunk * dtype_bytes
    onehot = chunk * T * dtype_bytes
    contrib = chunk * r_block * dtype_bytes
    temp = T * r_block * dtype_bytes
    factors = sum(I for m, I in enumerate(meta.dims)
                  if m != mode) * r_block * dtype_bytes
    return words + values + onehot + contrib + temp + factors


def oriented_vmem_bytes(meta: AltoMeta, mode: int, block_m: int,
                        r_block: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM of the output-oriented segment kernel.

    Dominated by the (block_m, block_m) in-block segment one-hot; plus the
    sorted rows / words / values tiles, the contribution tile, the
    per-block segment-sum output, and the resident factor tiles.
    """
    W = meta.enc.n_words
    words = block_m * W * 4
    rows = block_m * 4
    values = block_m * dtype_bytes
    onehot = block_m * block_m * dtype_bytes
    contrib = 2 * block_m * r_block * dtype_bytes   # krp + segment sums
    factors = sum(I for m, I in enumerate(meta.dims)
                  if m != mode) * r_block * dtype_bytes
    return words + rows + values + onehot + contrib + factors


def oriented_carry_vmem_bytes(meta: AltoMeta, mode: int, block_m: int,
                              r_block: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM of the scratch-carry oriented kernel.

    No (block_m, block_m) one-hot — in-block segment sums are a VPU
    scatter — but the ``(I_mode, r_block)`` output tile stays resident
    across the whole sequential scan, plus the (1, r_block) carry
    scratch. Stream tiles, krp/contrib/segment-sum intermediates, and
    the resident factor tiles as in the one-hot kernel.
    """
    W = meta.enc.n_words
    words = block_m * W * 4
    rows = block_m * 4
    values = block_m * dtype_bytes
    contrib = 3 * block_m * r_block * dtype_bytes   # krp + contrib + seg sums
    out_resident = meta.dims[mode] * r_block * dtype_bytes
    carry = r_block * dtype_bytes
    factors = sum(I for m, I in enumerate(meta.dims)
                  if m != mode) * r_block * dtype_bytes
    return words + rows + values + contrib + out_resident + carry + factors


def phi_oriented_vmem_bytes(meta: AltoMeta, mode: int, block_m: int,
                            rank: int, dtype_bytes: int = 4,
                            pre_pi: bool = False) -> int:
    """Per-grid-step VMEM of the *oriented fused Φ* kernel — full rank.

    The Φ kernel has no rank tiling (the denominator ``<B[i_n,:], krp>``
    needs the full rank per element) and keeps the whole ``(I_mode, R)``
    B operand resident every grid step, plus the gathered ``(block_m, R)``
    B rows — the two terms the old MTTKRP-shaped model omitted (the
    ROADMAP-flagged VMEM model gap).  Term by term:

    * ``rows``/``words``/``values`` stream tiles;
    * the ``(block_m, block_m)`` in-block segment one-hot;
    * **resident B**: ``I_mode·R`` (whole factor, every step);
    * **gathered B rows**: ``block_m·R``;
    * krp + contrib intermediates: ``2·block_m·R``;
    * the per-block segment-sum output tile: ``block_m·R``;
    * Π operand: the streamed ``(block_m, R)`` Π tile under ALTO-PRE, or
      the *fully resident* other factors (``Σ_{m≠mode} I_m·R``) under
      ALTO-OTF (the kernel's BlockSpecs load them whole, not r_block-wide).
    """
    W = meta.enc.n_words
    words = block_m * W * 4
    rows = block_m * 4
    values = block_m * dtype_bytes
    onehot = block_m * block_m * dtype_bytes
    b_resident = meta.dims[mode] * rank * dtype_bytes
    b_rows = block_m * rank * dtype_bytes
    krp_contrib = 2 * block_m * rank * dtype_bytes
    out = block_m * rank * dtype_bytes
    if pre_pi:
        operands = block_m * rank * dtype_bytes
    else:
        operands = sum(I for m, I in enumerate(meta.dims)
                       if m != mode) * rank * dtype_bytes
    return (words + rows + values + onehot + b_resident + b_rows
            + krp_contrib + out + operands)


def phi_oriented_carry_vmem_bytes(meta: AltoMeta, mode: int, block_m: int,
                                  rank: int, dtype_bytes: int = 4,
                                  pre_pi: bool = False) -> int:
    """Per-grid-step VMEM of the *scratch-carry fused Φ* kernel.

    Same full-rank accounting as :func:`phi_oriented_vmem_bytes` with the
    (block_m, block_m) one-hot replaced by the carry pattern's resident
    terms: the whole ``(I_mode, R)`` output block (written in place every
    step) next to the already-resident ``(I_mode, R)`` B operand, one more
    (block_m, R) segment-sum intermediate, and the (1, R) carry scratch.
    """
    W = meta.enc.n_words
    words = block_m * W * 4
    rows = block_m * 4
    values = block_m * dtype_bytes
    b_resident = meta.dims[mode] * rank * dtype_bytes
    b_rows = block_m * rank * dtype_bytes
    krp_contrib = 2 * block_m * rank * dtype_bytes
    seg_sums = block_m * rank * dtype_bytes
    out_resident = meta.dims[mode] * rank * dtype_bytes
    carry = rank * dtype_bytes
    if pre_pi:
        operands = block_m * rank * dtype_bytes
    else:
        operands = sum(I for m, I in enumerate(meta.dims)
                       if m != mode) * rank * dtype_bytes
    return (words + rows + values + b_resident + b_rows + krp_contrib
            + seg_sums + out_resident + carry + operands)


def phi_recursive_vmem_bytes(meta: AltoMeta, mode: int, rank: int,
                             dtype_bytes: int = 4,
                             pre_pi: bool = False) -> int:
    """Per-grid-step VMEM of the *recursive fused Φ* kernel — full rank.

    Same accounting as :func:`phi_oriented_vmem_bytes` with the oriented
    stream tiles replaced by the partition chunk, the segment one-hot by
    the ``(chunk, T)`` Temp one-hot, and the output by the ``(T, R)``
    partition Temp.  Nothing here is tunable (chunk is fixed by the
    partition count, Φ runs full rank), so this footprint is advisory —
    it is reported in the plan and used by the per-shard budget checks,
    but cannot be shrunk by blocking.
    """
    chunk = _chunk_rows(meta)
    T = meta.temp_rows[mode]
    W = meta.enc.n_words
    words = chunk * W * 4
    values = chunk * dtype_bytes
    onehot = chunk * T * dtype_bytes
    b_resident = meta.dims[mode] * rank * dtype_bytes
    b_rows = chunk * rank * dtype_bytes
    krp_contrib = 2 * chunk * rank * dtype_bytes
    temp = T * rank * dtype_bytes
    if pre_pi:
        operands = chunk * rank * dtype_bytes
    else:
        operands = sum(I for m, I in enumerate(meta.dims)
                       if m != mode) * rank * dtype_bytes
    return (words + values + onehot + b_resident + b_rows + krp_contrib
            + temp + operands)


def _divisors_desc(n: int) -> list[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out[::-1]


def choose_rank_block(meta: AltoMeta, mode: int, rank: int,
                      dtype_bytes: int = 4,
                      vmem_limit: int = VMEM_BYTES) -> int:
    """Largest divisor of ``rank`` whose recursive footprint fits VMEM.

    Always returns a divisor, so `ops.mttkrp` never sees a partial rank
    tile; if even r_block=1 overflows (huge Temp intervals) the budget is
    advisory and 1 is returned — the kernel still compiles, just spills.
    """
    for rb in _divisors_desc(rank):
        if recursive_vmem_bytes(meta, mode, rb, dtype_bytes) <= vmem_limit:
            return rb
    return 1


def choose_rank_block_oriented(meta: AltoMeta, mode: int, rank: int,
                               dtype_bytes: int = 4,
                               vmem_limit: int = VMEM_BYTES) -> int:
    """Largest divisor of ``rank`` whose *oriented* footprint fits VMEM.

    Sized at the minimum nonzero block so the rank tile is constrained by
    the resident factor tiles (the term that actually scales with rank),
    not by the recursive kernel's Temp buffer — a mode routed oriented
    never runs that kernel. `choose_block_m` then shrinks the block to
    fit the chosen tile.
    """
    for rb in _divisors_desc(rank):
        if oriented_vmem_bytes(meta, mode, MIN_BLOCK_M, rb,
                               dtype_bytes) <= vmem_limit:
            return rb
    return 1


def choose_rank_block_carry(meta: AltoMeta, mode: int, rank: int,
                            dtype_bytes: int = 4,
                            vmem_limit: int = VMEM_BYTES) -> int:
    """Largest divisor of ``rank`` whose *carry* footprint fits VMEM.

    The carry kernel's resident ``(I_mode, r_block)`` output tile makes
    the rank tile the lever that actually bounds its footprint, so the
    tile is sized at the minimum nonzero block like the oriented sibling.
    """
    for rb in _divisors_desc(rank):
        if oriented_carry_vmem_bytes(meta, mode, MIN_BLOCK_M, rb,
                                     dtype_bytes) <= vmem_limit:
            return rb
    return 1


def carry_fits_vmem(meta: AltoMeta, mode: int, rank: int,
                    dtype_bytes: int = 4,
                    vmem_limit: int = VMEM_BYTES) -> bool:
    """True iff the scratch-carry kernel is feasible for this mode at all
    (smallest tiling: ``r_block=1``, ``MIN_BLOCK_M``).

    Unlike the other budgets this one is a hard *routing* gate, not
    advisory: the carry kernel's whole advantage is the VMEM-resident
    output tile, so when ``I_mode`` alone overflows the budget the
    traversal should route to the one-hot merge path instead of
    spilling — `heuristics.choose_oriented_variant` consumes this.
    """
    return oriented_carry_vmem_bytes(meta, mode, MIN_BLOCK_M, 1,
                                     dtype_bytes) <= vmem_limit


# ---------------------------------------------------------------------------
# Out-of-core (HBM) byte models and chunk-size selection
# ---------------------------------------------------------------------------
#
# The VMEM models above size one grid step; these size what the DEVICE as
# a whole must hold. In-core, that is the full padded oriented stream plus
# the chunk-independent residency (factors, output accumulator, Φ's B
# operand, the carry). When it overflows the configured device budget the
# plan goes streaming: only two chunks (double buffer) of the stream are
# in flight at a time. Every model is exact byte accounting —
# `tests/test_heuristics_boundaries.py` pins them term by term.

def stream_elem_bytes(meta: AltoMeta, dtype_bytes: int = 4) -> int:
    """Device bytes per streamed element: words + row + value."""
    return meta.enc.n_words * 4 + 4 + dtype_bytes


def streaming_resident_bytes(meta: AltoMeta, rank: int,
                             dtype_bytes: int = 4) -> int:
    """Chunk-independent device residency of the chunked executors.

    All factors (Σ I·R — the chunk kernels read every other mode's
    factor), the worst-mode (I_max, R) output accumulator, Φ's resident
    (I_max, R) B operand, and the (1,) + (1, R) carry pair.
    """
    factors = sum(meta.dims) * rank * dtype_bytes
    i_max = max(meta.dims)
    out_accum = i_max * rank * dtype_bytes
    b_operand = i_max * rank * dtype_bytes
    carry = 4 + rank * dtype_bytes
    return factors + out_accum + b_operand + carry


def incore_working_set_bytes(meta: AltoMeta, rank: int,
                             dtype_bytes: int = 4) -> int:
    """Device bytes the IN-CORE oriented path holds: the whole padded
    stream plus the chunk-independent residency. The quantity the
    streaming decision compares against the device budget."""
    return (heuristics.stream_len(meta) * stream_elem_bytes(meta,
                                                            dtype_bytes)
            + streaming_resident_bytes(meta, rank, dtype_bytes))


def chunk_hbm_bytes(meta: AltoMeta, chunk_m: int, rank: int,
                    dtype_bytes: int = 4) -> int:
    """Device bytes the chunked executors hold at chunk size ``chunk_m``:
    TWO in-flight chunks (the compute chunk and the prefetched next one)
    plus the chunk-independent residency."""
    return (2 * chunk_m * stream_elem_bytes(meta, dtype_bytes)
            + streaming_resident_bytes(meta, rank, dtype_bytes))


def needs_streaming(meta: AltoMeta, rank: int, device_bytes: int,
                    dtype_bytes: int = 4) -> bool:
    """True iff the in-core working set overflows ``device_bytes``."""
    return incore_working_set_bytes(meta, rank, dtype_bytes) > device_bytes


def chunk_count(meta: AltoMeta, chunk_m: int) -> int:
    """Chunks the executors run: ceil over the partition-padded stream.

    Independent of block_m — the block padding never adds a chunk,
    because chunk_m is a multiple of every block_m and the smallest
    block_m-multiple ≥ Mp is ≤ the smallest chunk_m-multiple ≥ Mp.
    """
    return -(-heuristics.stream_len(meta) // chunk_m)


def choose_chunk_m(meta: AltoMeta, rank: int, device_bytes: int,
                   align: int, dtype_bytes: int = 4) -> int:
    """Largest ``align``-multiple chunk whose double-buffered footprint
    fits ``device_bytes``, capped at the aligned stream length.

    ``align`` is the max block_m across the plan's modes (block_m are
    powers of two, so the max is a common multiple) — chunk boundaries
    then sit on block boundaries for every mode, the bitwise-parity
    precondition. If even one aligned chunk overflows, the budget is
    advisory and one ``align`` chunk is returned (same contract as the
    VMEM choosers: the executor still runs, the device just holds more
    than asked).
    """
    elem = stream_elem_bytes(meta, dtype_bytes)
    resident = streaming_resident_bytes(meta, rank, dtype_bytes)
    avail = device_bytes - resident
    per_chunk = max(0, avail) // (2 * elem)
    chunk = max(align, (per_chunk // align) * align)
    padded = -(-heuristics.stream_len(meta) // align) * align
    return min(chunk, padded)


def default_device_bytes() -> int | None:
    """Process-wide device byte budget: ``$REPRO_DEVICE_BYTES`` or None
    (None = assume device-resident, never stream)."""
    v = os.environ.get("REPRO_DEVICE_BYTES", "")
    return int(v) if v else None


def _mttkrp_vmem_model(traversal: heuristics.Traversal):
    """The MTTKRP footprint function the traversal actually runs."""
    if traversal is heuristics.Traversal.ORIENTED_CARRY:
        return oriented_carry_vmem_bytes
    return oriented_vmem_bytes


def _phi_vmem_model(traversal: heuristics.Traversal):
    """The fused-Φ footprint function the traversal actually runs."""
    if traversal is heuristics.Traversal.ORIENTED_CARRY:
        return phi_oriented_carry_vmem_bytes
    return phi_oriented_vmem_bytes


def choose_block_m(meta: AltoMeta, mode: int, r_block: int,
                   dtype_bytes: int = 4,
                   vmem_limit: int = VMEM_BYTES,
                   rank: int | None = None,
                   pre_pi: bool = False,
                   traversal: heuristics.Traversal =
                   heuristics.Traversal.OUTPUT_ORIENTED) -> int:
    """Largest power-of-two nonzero block for the oriented kernels.

    The oriented stream is padded to a multiple of block_m by `ops`, so the
    choice is free of divisibility constraints on nnz.  ``traversal``
    selects the footprint model being sized (one-hot merge vs scratch
    carry — the carry kernel swaps the (block_m, block_m) one-hot for a
    resident output tile).  When ``rank`` is given the block must also
    fit the *fused Φ* kernel's footprint for the same traversal
    (:func:`phi_oriented_vmem_bytes` / :func:`phi_oriented_carry_vmem_bytes`
    — full rank, resident B): the same ``ModePlan.block_m`` feeds both
    the MTTKRP and the Φ kernel, so the block is sized for whichever is
    hungrier.  The Φ constraint only applies while it is *satisfiable*
    (fits at ``MIN_BLOCK_M``): on a huge mode the resident ``I_mode·R``
    B term alone can exceed any budget, and shrinking the block cannot
    fix that — Φ spills regardless, so the unsatisfiable constraint must
    not drag the MTTKRP kernel down to the minimum block.  If even
    ``MIN_BLOCK_M`` overflows the budget is advisory and ``MIN_BLOCK_M``
    is returned (the kernel still compiles, just spills — same contract
    as `choose_rank_block`).
    """
    mttkrp_model = _mttkrp_vmem_model(traversal)
    phi_model = _phi_vmem_model(traversal)
    phi_binding = rank is not None and phi_constraint_active(
        meta, mode, rank, dtype_bytes, vmem_limit, pre_pi=pre_pi,
        traversal=traversal)

    def fits(bm: int) -> bool:
        if mttkrp_model(meta, mode, bm, r_block,
                        dtype_bytes) > vmem_limit:
            return False
        if phi_binding and phi_model(
                meta, mode, bm, rank, dtype_bytes,
                pre_pi=pre_pi) > vmem_limit:
            return False
        return True

    bm = MAX_BLOCK_M
    while bm > MIN_BLOCK_M and not fits(bm):
        bm //= 2
    return bm


def phi_constraint_active(meta: AltoMeta, mode: int, rank: int,
                          dtype_bytes: int = 4,
                          vmem_limit: int = VMEM_BYTES,
                          pre_pi: bool = False,
                          traversal: heuristics.Traversal =
                          heuristics.Traversal.OUTPUT_ORIENTED) -> bool:
    """True iff the fused-Φ footprint can fit the budget at all for this
    mode (at ``MIN_BLOCK_M``) — i.e. the Φ constraint is binding rather
    than vacuous.  An unsatisfiable Φ budget is advisory (the kernel
    spills at any block size) and must not throttle the MTTKRP tiling."""
    return _phi_vmem_model(traversal)(meta, mode, MIN_BLOCK_M, rank,
                                      dtype_bytes,
                                      pre_pi=pre_pi) <= vmem_limit


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def default_backend() -> str:
    """Pallas/Mosaic on TPU; pure-jnp reference elsewhere (the interpreted
    Pallas path stays available by passing backend="pallas" explicitly)."""
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _mode_plan(meta: AltoMeta, mode: int, rank: int,
               traversal: heuristics.Traversal, r_block: int, block_m: int,
               dtype_bytes: int, pre_pi: bool) -> ModePlan:
    """Assemble a ModePlan with both kernel footprints filled in."""
    if traversal is heuristics.Traversal.RECURSIVE:
        vm = recursive_vmem_bytes(meta, mode, r_block, dtype_bytes)
        phi_vm = phi_recursive_vmem_bytes(meta, mode, rank, dtype_bytes,
                                          pre_pi=pre_pi)
    else:
        vm = _mttkrp_vmem_model(traversal)(meta, mode, block_m, r_block,
                                           dtype_bytes)
        phi_vm = _phi_vmem_model(traversal)(meta, mode, block_m, rank,
                                            dtype_bytes, pre_pi=pre_pi)
    return ModePlan(mode=mode, traversal=traversal, r_block=r_block,
                    block_m=block_m, temp_rows=meta.temp_rows[mode],
                    vmem_bytes=vm, phi_vmem_bytes=phi_vm)


def static_mode_plan(meta: AltoMeta, mode: int, rank: int, *,
                     dtype_bytes: int = 4, vmem_limit: int = VMEM_BYTES,
                     force_oriented: bool = False,
                     force_carry: bool = False,
                     pre_pi: bool = False) -> ModePlan:
    """The analytic-model choice for one mode (the pre-autotune answer).

    The traversal resolves in two stages: the paper's fiber-reuse rule
    picks recursive vs output-oriented (`heuristics.choose_traversal`),
    then an output-oriented mode refines to the one-hot merge or the
    scratch-carry variant by modelled HBM traffic
    (`heuristics.choose_oriented_variant`), gated on the carry kernel's
    resident-output VMEM feasibility (:func:`carry_fits_vmem`).

    ``force_carry`` pins the scratch-carry traversal outright — streaming
    plans require it (the chunked executors ARE the carry scan; the
    carry VMEM gate turns advisory there, as out-of-core has no in-core
    fallback to route to).
    """
    if force_carry:
        traversal = heuristics.Traversal.ORIENTED_CARRY
    else:
        traversal = (heuristics.Traversal.OUTPUT_ORIENTED if force_oriented
                     else heuristics.choose_traversal(meta, mode))
    if not force_carry and heuristics.is_oriented(traversal):
        traversal = heuristics.choose_oriented_variant(
            meta, mode, rank, dtype_bytes,
            carry_feasible=carry_fits_vmem(meta, mode, rank, dtype_bytes,
                                           vmem_limit))
    # Budget the rank tile against the kernel that will actually run:
    # the recursive Temp model would throttle oriented modes (huge
    # partition intervals, or any mesh plan) for no VMEM benefit.
    if traversal is heuristics.Traversal.RECURSIVE:
        rb = choose_rank_block(meta, mode, rank, dtype_bytes, vmem_limit)
    elif traversal is heuristics.Traversal.ORIENTED_CARRY:
        rb = choose_rank_block_carry(meta, mode, rank, dtype_bytes,
                                     vmem_limit)
    else:
        rb = choose_rank_block_oriented(meta, mode, rank, dtype_bytes,
                                        vmem_limit)
    bm = choose_block_m(meta, mode, rb, dtype_bytes, vmem_limit,
                        rank=rank, pre_pi=pre_pi, traversal=traversal)
    return _mode_plan(meta, mode, rank, traversal, rb, bm, dtype_bytes,
                      pre_pi)


def candidate_mode_plans(meta: AltoMeta, mode: int, rank: int, *,
                         dtype_bytes: int = 4,
                         vmem_limit: int = VMEM_BYTES,
                         force_oriented: bool = False,
                         pre_pi: bool = False,
                         max_candidates: int | None = None
                         ) -> tuple[ModePlan, ...]:
    """The feasible tiling space for one mode, static choice FIRST.

    Enumerates traversal × ``r_block`` × ``block_m`` and prunes by the
    corrected per-kernel footprints: a candidate survives only if its
    MTTKRP footprint fits the budget AND its fused-Φ footprint
    (:func:`phi_oriented_vmem_bytes`, full-rank resident B) fits too —
    except that the static choice is always kept even when nothing fits
    (some plan must exist; the budget is advisory then, as everywhere).

    The static (analytic-model) choice is element 0 so a capped search
    (``max_candidates``) can never lose it — the measured winner is then
    *never worse than the static model under the measurement*, which is
    the autotuner's acceptance condition.
    """
    static = static_mode_plan(meta, mode, rank, dtype_bytes=dtype_bytes,
                              vmem_limit=vmem_limit,
                              force_oriented=force_oriented, pre_pi=pre_pi)
    out: list[ModePlan] = [static]
    seen = {(static.traversal, static.r_block, static.block_m)}

    def add(traversal, rb, bm):
        key = (traversal, rb, bm)
        if key in seen:
            return
        seen.add(key)
        out.append(_mode_plan(meta, mode, rank, traversal, rb, bm,
                              dtype_bytes, pre_pi))

    traversals = ((heuristics.Traversal.OUTPUT_ORIENTED,
                   heuristics.Traversal.ORIENTED_CARRY) if force_oriented
                  else heuristics.candidate_traversals(meta, mode))
    for traversal in traversals:
        if traversal is heuristics.Traversal.RECURSIVE:
            # block_m is dead for the recursive kernel; keep the static
            # block so candidates differ only in what the kernel reads.
            for rb in _divisors_desc(rank):
                if recursive_vmem_bytes(meta, mode, rb,
                                        dtype_bytes) <= vmem_limit:
                    add(traversal, rb, static.block_m)
        else:
            if (traversal is heuristics.Traversal.ORIENTED_CARRY
                    and not carry_fits_vmem(meta, mode, rank, dtype_bytes,
                                            vmem_limit)):
                continue    # hard gate: resident output cannot fit at all
            mttkrp_model = _mttkrp_vmem_model(traversal)
            phi_model = _phi_vmem_model(traversal)
            # Same binding-vs-vacuous rule as choose_block_m: an
            # unsatisfiable Φ budget must not hide the larger MTTKRP
            # blocks from the tuner.
            phi_binding = phi_constraint_active(meta, mode, rank,
                                                dtype_bytes, vmem_limit,
                                                pre_pi=pre_pi,
                                                traversal=traversal)
            for rb in _divisors_desc(rank):
                if mttkrp_model(meta, mode, MIN_BLOCK_M, rb,
                                dtype_bytes) > vmem_limit:
                    continue
                bm = MAX_BLOCK_M
                while bm >= MIN_BLOCK_M:
                    if (mttkrp_model(meta, mode, bm, rb,
                                     dtype_bytes) <= vmem_limit
                            and not (phi_binding and
                                     phi_model(
                                         meta, mode, bm, rank,
                                         dtype_bytes,
                                         pre_pi=pre_pi) > vmem_limit)):
                        add(traversal, rb, bm)
                    bm //= 2
    if max_candidates is not None and len(out) > max_candidates:
        out = out[:max_candidates]
    return tuple(out)


def make_plan(meta: AltoMeta, rank: int, *, backend: str | None = None,
              interpret: bool | None = None, dtype_bytes: int = 4,
              vmem_limit: int = VMEM_BYTES,
              fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
              mesh: jax.sharding.Mesh | None = None,
              device_bytes: int | None = None,
              tune: str = "off",
              tune_objective: str = "mttkrp",
              at: "AltoTensor | None" = None,
              search_budget: int | None = None,
              search_seconds: float | None = None,
              search_seed: int = 0,
              store_path=None) -> ExecutionPlan:
    """Resolve heuristics + static meta into a concrete execution plan.

    With ``mesh=`` the plan becomes mesh-bearing: every mode is forced to
    the output-oriented family (the sharded merge partitions the
    row-sorted stream into per-device row ranges; the recursive
    traversal's partition intervals overlap arbitrarily across devices —
    the one-hot-vs-carry refinement still applies per mode, and carry
    shards run the scratch-carry kernel locally under ``shard_map``)
    and the VMEM budget is divided by the shard count (see module
    docstring), so the shard-local Pallas tiles are sized for the
    per-device slice of the stream.

    ``tune`` selects between the analytic model and measured plans
    (`core.autotune`, persisted in the on-disk plan store):

    * ``"off"`` (default) — the static analytic plan, exactly as before;
    * ``"auto"`` — return the stored measured plan if the store has one
      for this (meta, rank, backend, shard count, jax version); else run
      the tuner if the tensor data ``at=`` was provided (and persist the
      winner); else fall back to the static plan;
    * ``"force"`` — like ``"auto"`` but never silently fall back: a store
      miss with no ``at=`` raises, so the caller knows it is NOT running
      a measured plan.
    * ``"search"`` — like ``"auto"`` but a store miss (with ``at=``)
      runs the *budgeted* GA + cost-model search (`core.search`)
      instead of the exhaustive tuner; ``search_budget`` caps the
      timing runs, ``search_seconds`` the measurement wall-clock, and
      ``search_seed`` pins the search's RNG (deterministic candidate
      schedule). Mesh plans fall back to the exhaustive tuner (the
      sharded timing protocol lives there).

    Streaming plans (``device_bytes`` overflow) tune through the search
    engine under every mode but ``"off"`` — ``StreamPlan.chunk_m`` is
    part of the search genome, and the store records/keys the winner
    per device budget.

    ``tune_objective`` names the kernel the measurement ranks by —
    ``"mttkrp"`` (CP-ALS, the default) or ``"phi"`` (CP-APR; `cp_apr`
    passes this) — and is part of the store key: the two objectives
    crown different winners and never overwrite each other.

    A store hit costs **zero timing runs** — the measured plan
    round-trips across processes through the store file
    (``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans.json``).
    """
    backend = backend or default_backend()
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    if tune not in ("off", "auto", "force", "search"):
        raise ValueError(f"unknown tune mode {tune!r}")
    if device_bytes is None:
        device_bytes = default_device_bytes()
    streaming_needed = (device_bytes is not None
                        and needs_streaming(meta, rank, device_bytes,
                                            dtype_bytes))
    if streaming_needed and mesh is not None:
        raise ValueError("out-of-core streaming does not compose with "
                         "mesh-sharded plans yet (shard first, then size "
                         "device_bytes per shard)")
    if tune != "off":
        from repro.core import autotune
        tuned = autotune.tuned_plan(
            meta, rank, backend=backend, interpret=interpret,
            dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
            fast_mem_bytes=fast_mem_bytes, mesh=mesh, at=at,
            require=(tune == "force"), objective=tune_objective,
            search=(tune == "search"),
            device_bytes=device_bytes if streaming_needed else None,
            search_budget_runs=search_budget,
            search_budget_s=search_seconds, search_seed=search_seed,
            store_path=store_path)
        if tuned is not None:
            return tuned
    n_shards = 1
    if mesh is not None:
        n_shards = int(mesh.shape[mesh.axis_names[0]])
        vmem_limit = max(1, vmem_limit // n_shards)
    pi_policy = heuristics.choose_pi_policy(
        meta, rank, value_bytes=dtype_bytes, fast_mem_bytes=fast_mem_bytes)
    modes = tuple(
        static_mode_plan(meta, n, rank, dtype_bytes=dtype_bytes,
                         vmem_limit=vmem_limit,
                         force_oriented=mesh is not None,
                         force_carry=streaming_needed,
                         pre_pi=pi_policy is heuristics.PiPolicy.PRE)
        for n in range(meta.enc.ndim))
    streaming = None
    if streaming_needed:
        align = max(m.block_m for m in modes)
        cm = choose_chunk_m(meta, rank, device_bytes, align, dtype_bytes)
        streaming = StreamPlan(
            chunk_m=cm, n_chunks=chunk_count(meta, cm),
            device_bytes=device_bytes,
            stream_bytes=incore_working_set_bytes(meta, rank, dtype_bytes))
    return ExecutionPlan(meta=meta, rank=rank, backend=backend,
                         interpret=interpret, pi_policy=pi_policy,
                         modes=modes, mesh=mesh, streaming=streaming)


def plan_for(at: AltoTensor, rank: int, **kwargs) -> ExecutionPlan:
    """`make_plan` from a built tensor; tensor data rides along so
    ``plan_for(at, rank, tune="auto")`` can run the measured tuner."""
    kwargs.setdefault("at", at)
    return make_plan(at.meta, rank, **kwargs)


def make_class_plan(sc, **kwargs) -> ExecutionPlan:
    """`make_plan` for a shape class (`core.shapeclass.ShapeClass`).

    The plan resolves against the class's canonical meta, so it is
    CLASS-keyed: every tenant the class admits executes (and, under
    ``tune=``, autotunes/stores — see `autotune.class_plan_key`) through
    this one plan. The canonical meta's ``temp_rows`` are the padded
    class dims, so the VMEM models size scratch for the worst member —
    conservative by construction, never undersized for any tenant.
    A tensor passed via ``at=`` must already carry the canonical meta
    (`shapeclass.canonicalize_tensor`) or the tuner will reject it.
    """
    from repro.core import shapeclass
    return make_plan(shapeclass.canonical_meta(sc), sc.rank, **kwargs)


def build_views(at: AltoTensor, plan: ExecutionPlan,
                route: str | None = None) -> dict[int, OrientedView]:
    """Oriented-traversal copies for exactly the modes the plan routes
    output-oriented — either variant, one-hot merge or scratch carry,
    both consume the same row-sorted view (preserves the single-copy
    property elsewhere).

    Routed through the unified view cache (`core.views`): built once per
    (tensor fingerprint, mode) per process and shared by every driver;
    ``route`` picks the device (`alto.oriented_view_device`, default) or
    host builder — bit-identical, so the cache ignores the route.
    """
    from repro.core import views as views_mod
    return views_mod.build_views(at, plan, route=route)


def resident_bytes(at: AltoTensor,
                   views: dict[int, OrientedView] | None = None) -> int:
    """Device-resident bytes a decomposition actually holds.

    `AltoTensor.storage_bytes` is the paper's Fig. 12 accounting — index
    + value words per *real* nonzero — which undercounts the working
    set: CP-ALS/CP-APR also hold the padded tail, the partition boxes,
    and one full oriented copy (rows/words/values/perm) per
    output-oriented mode. This sums the actual materialized arrays, so
    `bench_storage` can report the honest footprint next to the paper
    numbers.
    """
    def nbytes(a) -> int:
        return int(a.size) * a.dtype.itemsize

    from repro.core.stream import HostStream
    total = (nbytes(at.words) + nbytes(at.values)
             + nbytes(at.part_start) + nbytes(at.part_end))
    for v in (views or {}).values():
        if isinstance(v, HostStream):
            continue        # host-resident by design, not device bytes
        total += (nbytes(v.rows) + nbytes(v.words) + nbytes(v.values)
                  + nbytes(v.perm))
    return total


# ---------------------------------------------------------------------------
# Plan-directed execution (the single entry point the drivers use)
# ---------------------------------------------------------------------------

def execute_mttkrp(plan: ExecutionPlan, at: AltoTensor,
                   views: dict[int, OrientedView] | None,
                   factors, mode: int) -> jnp.ndarray:
    """MTTKRP for one mode through the plan's kernel choice.

    Falls back to the recursive traversal when the plan says oriented but
    no view was materialized (same contract as `mttkrp_adaptive`).
    Mesh-bearing plans route to the sharded oriented merge in
    `repro.dist.cpd` (shard-local reduction + psum carry merge).
    Streaming plans route to the out-of-core chunked executors
    (`kernels.ops`), which consume the host-resident stream
    (`core.stream.HostStream`) that `build_views` materialized in place
    of a device view.
    """
    faults.inject("plan.dispatch")
    if plan.mesh is not None:
        from repro.dist import cpd as dist_cpd
        return dist_cpd.sharded_mttkrp(plan, at, views, factors, mode)
    mp = plan.modes[mode]
    oriented = (heuristics.is_oriented(mp.traversal)
                and views is not None and mode in views)
    if plan.streaming is not None and oriented:
        from repro.kernels import ops
        if plan.backend == "pallas":
            return ops.mttkrp_oriented_chunked(
                views[mode], factors, chunk_m=plan.streaming.chunk_m,
                block_m=mp.block_m, r_block=mp.r_block,
                interpret=plan.interpret)
        return ops.mttkrp_oriented_chunked_reference(
            views[mode], factors, chunk_m=plan.streaming.chunk_m)
    if plan.backend == "pallas":
        from repro.kernels import ops
        if oriented:
            if mp.traversal is heuristics.Traversal.ORIENTED_CARRY:
                return ops.mttkrp_oriented_carry(views[mode], factors,
                                                 block_m=mp.block_m,
                                                 r_block=mp.r_block,
                                                 interpret=plan.interpret)
            return ops.mttkrp_oriented(views[mode], factors,
                                       block_m=mp.block_m,
                                       r_block=mp.r_block,
                                       interpret=plan.interpret)
        return ops.mttkrp(at, factors, mode, r_block=mp.r_block,
                          interpret=plan.interpret)
    # reference backend: both oriented variants are the same sorted
    # segment_sum — the carry is a kernel-level distinction.
    if oriented:
        return core_mttkrp.mttkrp_oriented(views[mode], factors)
    return core_mttkrp.mttkrp_recursive(at, factors, mode)


def execute_phi(plan: ExecutionPlan, at: AltoTensor,
                view: OrientedView | None, B: jnp.ndarray, mode: int,
                factors=None, pi: jnp.ndarray | None = None,
                eps: float = 1e-10, pre: bool | None = None) -> jnp.ndarray:
    """CP-APR Φ row reduction through the plan's kernel choice.

    Pass ``pi`` (view/ALTO-ordered Khatri-Rao rows) for ALTO-PRE or
    ``factors`` for ALTO-OTF — exactly one, as in `kernels.cpapr_phi`.

    Streaming plans take ``factors`` under BOTH Π policies (a full-stream
    Π is exactly the array streaming avoids; the chunked executor builds
    each chunk's Π rows on device under PRE) — ``pre`` then selects the
    policy explicitly, defaulting to the plan's. ``pre`` is ignored on
    in-core routes, where the pi-vs-factors operand already encodes it.
    """
    faults.inject("plan.dispatch")
    if (pi is None) == (factors is None):
        raise ValueError("pass exactly one of pi= / factors=")
    if plan.mesh is not None:
        from repro.dist import cpd as dist_cpd
        return dist_cpd.sharded_phi(plan, at, view, B, mode,
                                    factors=factors, pi=pi, eps=eps)
    mp = plan.modes[mode]
    oriented = (heuristics.is_oriented(mp.traversal)
                and view is not None)
    if plan.streaming is not None and oriented:
        from repro.kernels import ops
        if factors is None:
            raise ValueError("streaming Φ needs factors= — chunk Π rows "
                             "are built on device per chunk, never as a "
                             "full-stream pi= operand")
        pre_flag = (pre if pre is not None
                    else plan.pi_policy is heuristics.PiPolicy.PRE)
        if plan.backend == "pallas":
            return ops.cpapr_phi_oriented_chunked(
                view, B, factors, pre=pre_flag, eps=eps,
                chunk_m=plan.streaming.chunk_m, block_m=mp.block_m,
                interpret=plan.interpret)
        return ops.cpapr_phi_oriented_chunked_reference(
            view, B, factors, pre=pre_flag, eps=eps,
            chunk_m=plan.streaming.chunk_m)
    if plan.backend == "pallas":
        from repro.kernels import ops
        if oriented:
            if mp.traversal is heuristics.Traversal.ORIENTED_CARRY:
                return ops.cpapr_phi_oriented_carry(
                    view, B, factors=factors, pi=pi, eps=eps,
                    block_m=mp.block_m, interpret=plan.interpret)
            return ops.cpapr_phi_oriented(view, B, factors=factors, pi=pi,
                                          eps=eps, block_m=mp.block_m,
                                          interpret=plan.interpret)
        return ops.cpapr_phi(at, B, mode, factors=factors, pi=pi, eps=eps,
                             interpret=plan.interpret)
    # reference backend: pure-jnp traversals. Under ALTO-PRE the index
    # decode is dead work (the Pallas kernel skips it too): the oriented
    # view already materializes the target rows, so only the OTF path —
    # which rebuilds the Khatri-Rao rows — pays for a delinearize.
    words = view.words if oriented else at.words
    vals = view.values if oriented else at.values
    if pi is None:
        coords = delinearize(plan.meta.enc, words)
        krp = core_mttkrp.krp_rows(coords, factors, mode)
        rows = coords[:, mode]
    else:
        krp = pi
        rows = (view.rows if oriented
                else delinearize(plan.meta.enc, words)[:, mode])
    denom = jnp.maximum(jnp.sum(B[rows] * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp
    if oriented:
        return core_mttkrp.row_reduce_oriented(view, contrib)
    return core_mttkrp.row_reduce_recursive(at, mode, contrib)
