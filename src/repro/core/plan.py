"""Execution plans: resolve the paper's adaptive heuristics into kernels.

Paper §4.2/§4.3 (Table 1). Invariants: plans are frozen and hashable
(static jit arguments, compiled-executable cache keys); every decision is
made from static `AltoMeta`, never from traced data.

The paper selects a traversal (recursive vs output-oriented) and a Π
policy (PRE vs OTF) per tensor/mode at runtime. On the JAX/TPU target
every such decision must be *static* — jit control flow cannot branch on
data — so this module turns the heuristics plus the tensor's static
metadata (`AltoMeta`) into an :class:`ExecutionPlan`: a frozen, hashable
description of exactly which compiled kernel variant runs for every
(mode, rank) combination, with all block sizes resolved.

The plan answers four questions the call sites used to guess at:

  * **traversal** per mode — `heuristics.choose_traversal` (fiber reuse vs
    the 4-memory-op buffered accumulation cost, §4.2);
  * **rank blocking** (`r_block`) and **nonzero blocking** (`block_m`) —
    chosen so the Pallas kernel's per-grid-step VMEM footprint fits the
    accelerator budget, from `AltoMeta` (temp_rows, dims, dtype) instead of
    the caller hand-picking tile sizes;
  * **backend** — "pallas" (interpret-mode on CPU, Mosaic on TPU) or
    "reference" (the pure-jnp traversals in `core.mttkrp`, retained as the
    plan's always-available oracle backend);
  * **placement** — a plan built with ``mesh=`` routes every row reduction
    through the sharded oriented merge in `repro.dist.cpd`: the row-sorted
    nonzero stream is cut into per-device contiguous shards, each device
    runs the single-device segment reduction locally, and boundary-run
    carries plus the final rows are combined by ``psum``. Mesh-bearing
    plans force the output-oriented traversal for every mode (row-range
    partitioning needs the row-sorted stream; the recursive traversal's
    partition intervals overlap arbitrarily across devices) and divide the
    VMEM budget by the shard count — shard-local blocks are sized as if
    all shards ran concurrently on one core, which is exactly what the
    fake-host-device test configuration does, and on real multi-chip
    meshes it only makes tiles conservatively smaller.

Because `ExecutionPlan` is hashable (``jax.sharding.Mesh`` included) it can
travel as a static jit argument and doubles as the key of the
compiled-executable cache in `kernels.ops`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import heuristics
from repro.core import mttkrp as core_mttkrp
from repro.core.alto import AltoMeta, AltoTensor, OrientedView, delinearize

# Per-core VMEM on current TPU generations; the budget is what the kernel's
# per-grid-step working set must fit into (interpret mode ignores it but we
# size identically so CPU tests exercise the TPU tiling decisions).
VMEM_BYTES = 16 * 1024 * 1024

# Output-oriented kernel: the in-block one-hot segment matmul is
# (block_m, block_m), so block_m is capped independently of the budget.
MAX_BLOCK_M = 1024
MIN_BLOCK_M = 8


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """Resolved execution choices for one target mode."""
    mode: int
    traversal: heuristics.Traversal
    r_block: int        # rank tile (always divides the plan rank)
    block_m: int        # oriented-kernel nonzero block (power of two)
    temp_rows: int      # recursive Temp height (static VMEM bound)
    vmem_bytes: int     # estimated per-grid-step footprint of the choice


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Static per-(tensor, rank) kernel routing, hashable for jit/caching."""
    meta: AltoMeta
    rank: int
    backend: str                       # "pallas" | "reference"
    interpret: bool | None             # None = auto (non-TPU -> interpret)
    pi_policy: heuristics.PiPolicy
    modes: tuple[ModePlan, ...]
    # Multi-device placement: shard the oriented row reduction over the
    # first axis of this mesh (None = single device). Mesh is hashable, so
    # mesh-bearing plans remain valid static jit arguments / cache keys.
    mesh: jax.sharding.Mesh | None = None

    def mode_plan(self, mode: int) -> ModePlan:
        return self.modes[mode]

    def traversals(self) -> tuple[str, ...]:
        return tuple(m.traversal.value for m in self.modes)

    @property
    def mesh_axis(self) -> str | None:
        """Mesh axis the row-sorted stream is sharded over (first axis)."""
        return self.mesh.axis_names[0] if self.mesh is not None else None

    @property
    def n_shards(self) -> int:
        """Row-range shard count (1 without a mesh)."""
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.mesh.axis_names[0]])


# ---------------------------------------------------------------------------
# VMEM budgeting
# ---------------------------------------------------------------------------

def _chunk_rows(meta: AltoMeta) -> int:
    """Per-partition element count after build()'s padding to L·chunk."""
    L = meta.n_partitions
    return -(-max(meta.nnz, L) // L)

def recursive_vmem_bytes(meta: AltoMeta, mode: int, r_block: int,
                         dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM of the recursive (Temp + one-hot) kernel.

    words + values tiles, the (chunk, T) one-hot operand, the (chunk, rb)
    Khatri-Rao/contribution tile, the (T, rb) Temp output, and the resident
    factor tiles of the other modes.
    """
    chunk = _chunk_rows(meta)
    T = meta.temp_rows[mode]
    W = meta.enc.n_words
    words = chunk * W * 4
    values = chunk * dtype_bytes
    onehot = chunk * T * dtype_bytes
    contrib = chunk * r_block * dtype_bytes
    temp = T * r_block * dtype_bytes
    factors = sum(I for m, I in enumerate(meta.dims)
                  if m != mode) * r_block * dtype_bytes
    return words + values + onehot + contrib + temp + factors


def oriented_vmem_bytes(meta: AltoMeta, mode: int, block_m: int,
                        r_block: int, dtype_bytes: int = 4) -> int:
    """Per-grid-step VMEM of the output-oriented segment kernel.

    Dominated by the (block_m, block_m) in-block segment one-hot; plus the
    sorted rows / words / values tiles, the contribution tile, the
    per-block segment-sum output, and the resident factor tiles.
    """
    W = meta.enc.n_words
    words = block_m * W * 4
    rows = block_m * 4
    values = block_m * dtype_bytes
    onehot = block_m * block_m * dtype_bytes
    contrib = 2 * block_m * r_block * dtype_bytes   # krp + segment sums
    factors = sum(I for m, I in enumerate(meta.dims)
                  if m != mode) * r_block * dtype_bytes
    return words + rows + values + onehot + contrib + factors


def _divisors_desc(n: int) -> list[int]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return out[::-1]


def choose_rank_block(meta: AltoMeta, mode: int, rank: int,
                      dtype_bytes: int = 4,
                      vmem_limit: int = VMEM_BYTES) -> int:
    """Largest divisor of ``rank`` whose recursive footprint fits VMEM.

    Always returns a divisor, so `ops.mttkrp` never sees a partial rank
    tile; if even r_block=1 overflows (huge Temp intervals) the budget is
    advisory and 1 is returned — the kernel still compiles, just spills.
    """
    for rb in _divisors_desc(rank):
        if recursive_vmem_bytes(meta, mode, rb, dtype_bytes) <= vmem_limit:
            return rb
    return 1


def choose_rank_block_oriented(meta: AltoMeta, mode: int, rank: int,
                               dtype_bytes: int = 4,
                               vmem_limit: int = VMEM_BYTES) -> int:
    """Largest divisor of ``rank`` whose *oriented* footprint fits VMEM.

    Sized at the minimum nonzero block so the rank tile is constrained by
    the resident factor tiles (the term that actually scales with rank),
    not by the recursive kernel's Temp buffer — a mode routed oriented
    never runs that kernel. `choose_block_m` then shrinks the block to
    fit the chosen tile.
    """
    for rb in _divisors_desc(rank):
        if oriented_vmem_bytes(meta, mode, MIN_BLOCK_M, rb,
                               dtype_bytes) <= vmem_limit:
            return rb
    return 1


def choose_block_m(meta: AltoMeta, mode: int, r_block: int,
                   dtype_bytes: int = 4,
                   vmem_limit: int = VMEM_BYTES) -> int:
    """Largest power-of-two nonzero block for the oriented kernel.

    The oriented stream is padded to a multiple of block_m by `ops`, so the
    choice is free of divisibility constraints on nnz.
    """
    bm = MAX_BLOCK_M
    while bm > MIN_BLOCK_M and oriented_vmem_bytes(
            meta, mode, bm, r_block, dtype_bytes) > vmem_limit:
        bm //= 2
    return bm


# ---------------------------------------------------------------------------
# Plan construction
# ---------------------------------------------------------------------------

def default_backend() -> str:
    """Pallas/Mosaic on TPU; pure-jnp reference elsewhere (the interpreted
    Pallas path stays available by passing backend="pallas" explicitly)."""
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def make_plan(meta: AltoMeta, rank: int, *, backend: str | None = None,
              interpret: bool | None = None, dtype_bytes: int = 4,
              vmem_limit: int = VMEM_BYTES,
              fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
              mesh: jax.sharding.Mesh | None = None) -> ExecutionPlan:
    """Resolve heuristics + static meta into a concrete execution plan.

    With ``mesh=`` the plan becomes mesh-bearing: every mode is forced to
    the output-oriented traversal (the sharded merge partitions the
    row-sorted stream into per-device row ranges; the recursive
    traversal's partition intervals overlap arbitrarily across devices)
    and the VMEM budget is divided by the shard count (see module
    docstring), so the shard-local Pallas tiles are sized for the
    per-device slice of the stream.
    """
    backend = backend or default_backend()
    if backend not in ("pallas", "reference"):
        raise ValueError(f"unknown backend {backend!r}")
    n_shards = 1
    if mesh is not None:
        n_shards = int(mesh.shape[mesh.axis_names[0]])
        vmem_limit = max(1, vmem_limit // n_shards)
    modes = []
    for n in range(meta.enc.ndim):
        traversal = (heuristics.Traversal.OUTPUT_ORIENTED if mesh is not None
                     else heuristics.choose_traversal(meta, n))
        # Budget the rank tile against the kernel that will actually run:
        # the recursive Temp model would throttle oriented modes (huge
        # partition intervals, or any mesh plan) for no VMEM benefit.
        if traversal is heuristics.Traversal.RECURSIVE:
            rb = choose_rank_block(meta, n, rank, dtype_bytes, vmem_limit)
        else:
            rb = choose_rank_block_oriented(meta, n, rank, dtype_bytes,
                                            vmem_limit)
        bm = choose_block_m(meta, n, rb, dtype_bytes, vmem_limit)
        vm = (recursive_vmem_bytes(meta, n, rb, dtype_bytes)
              if traversal is heuristics.Traversal.RECURSIVE
              else oriented_vmem_bytes(meta, n, bm, rb, dtype_bytes))
        modes.append(ModePlan(mode=n, traversal=traversal, r_block=rb,
                              block_m=bm, temp_rows=meta.temp_rows[n],
                              vmem_bytes=vm))
    pi_policy = heuristics.choose_pi_policy(
        meta, rank, value_bytes=dtype_bytes, fast_mem_bytes=fast_mem_bytes)
    return ExecutionPlan(meta=meta, rank=rank, backend=backend,
                         interpret=interpret, pi_policy=pi_policy,
                         modes=tuple(modes), mesh=mesh)


def plan_for(at: AltoTensor, rank: int, **kwargs) -> ExecutionPlan:
    return make_plan(at.meta, rank, **kwargs)


def build_views(at: AltoTensor, plan: ExecutionPlan
                ) -> dict[int, OrientedView]:
    """Oriented-traversal copies for exactly the modes the plan routes
    output-oriented (preserves the single-copy property elsewhere)."""
    from repro.core.alto import oriented_view
    return {m.mode: oriented_view(at, m.mode) for m in plan.modes
            if m.traversal is heuristics.Traversal.OUTPUT_ORIENTED}


# ---------------------------------------------------------------------------
# Plan-directed execution (the single entry point the drivers use)
# ---------------------------------------------------------------------------

def execute_mttkrp(plan: ExecutionPlan, at: AltoTensor,
                   views: dict[int, OrientedView] | None,
                   factors, mode: int) -> jnp.ndarray:
    """MTTKRP for one mode through the plan's kernel choice.

    Falls back to the recursive traversal when the plan says oriented but
    no view was materialized (same contract as `mttkrp_adaptive`).
    Mesh-bearing plans route to the sharded oriented merge in
    `repro.dist.cpd` (shard-local reduction + psum carry merge).
    """
    if plan.mesh is not None:
        from repro.dist import cpd as dist_cpd
        return dist_cpd.sharded_mttkrp(plan, at, views, factors, mode)
    mp = plan.modes[mode]
    oriented = (mp.traversal is heuristics.Traversal.OUTPUT_ORIENTED
                and views is not None and mode in views)
    if plan.backend == "pallas":
        from repro.kernels import ops
        if oriented:
            return ops.mttkrp_oriented(views[mode], factors,
                                       block_m=mp.block_m,
                                       r_block=mp.r_block,
                                       interpret=plan.interpret)
        return ops.mttkrp(at, factors, mode, r_block=mp.r_block,
                          interpret=plan.interpret)
    if oriented:
        return core_mttkrp.mttkrp_oriented(views[mode], factors)
    return core_mttkrp.mttkrp_recursive(at, factors, mode)


def execute_phi(plan: ExecutionPlan, at: AltoTensor,
                view: OrientedView | None, B: jnp.ndarray, mode: int,
                factors=None, pi: jnp.ndarray | None = None,
                eps: float = 1e-10) -> jnp.ndarray:
    """CP-APR Φ row reduction through the plan's kernel choice.

    Pass ``pi`` (view/ALTO-ordered Khatri-Rao rows) for ALTO-PRE or
    ``factors`` for ALTO-OTF — exactly one, as in `kernels.cpapr_phi`.
    """
    if (pi is None) == (factors is None):
        raise ValueError("pass exactly one of pi= / factors=")
    if plan.mesh is not None:
        from repro.dist import cpd as dist_cpd
        return dist_cpd.sharded_phi(plan, at, view, B, mode,
                                    factors=factors, pi=pi, eps=eps)
    mp = plan.modes[mode]
    oriented = (mp.traversal is heuristics.Traversal.OUTPUT_ORIENTED
                and view is not None)
    if plan.backend == "pallas":
        from repro.kernels import ops
        if oriented:
            return ops.cpapr_phi_oriented(view, B, factors=factors, pi=pi,
                                          eps=eps, block_m=mp.block_m,
                                          interpret=plan.interpret)
        return ops.cpapr_phi(at, B, mode, factors=factors, pi=pi, eps=eps,
                             interpret=plan.interpret)
    # reference backend: pure-jnp traversals. Under ALTO-PRE the index
    # decode is dead work (the Pallas kernel skips it too): the oriented
    # view already materializes the target rows, so only the OTF path —
    # which rebuilds the Khatri-Rao rows — pays for a delinearize.
    words = view.words if oriented else at.words
    vals = view.values if oriented else at.values
    if pi is None:
        coords = delinearize(plan.meta.enc, words)
        krp = core_mttkrp.krp_rows(coords, factors, mode)
        rows = coords[:, mode]
    else:
        krp = pi
        rows = (view.rows if oriented
                else delinearize(plan.meta.enc, words)[:, mode])
    denom = jnp.maximum(jnp.sum(B[rows] * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp
    if oriented:
        return core_mttkrp.row_reduce_oriented(view, contrib)
    return core_mttkrp.row_reduce_recursive(at, mode, contrib)
