"""CP-APR multiplicative updates on ALTO tensors (paper Alg. 2 / Alg. 5).

Poisson tensor decomposition for non-negative count data. The Φ (model
update) kernel — >99% of runtime per the paper §5.3 — runs through the
generic ALTO row-reduction engine with the paper's two adaptive choices:

  * traversal: recursive (Temp + pull reduction) vs output-oriented
    (sorted segment reduction), per fiber reuse (§4.2);
  * Π policy: ALTO-PRE (precompute the (M, R) Khatri-Rao rows once per
    outer iteration) vs ALTO-OTF (recompute per inner iteration), per the
    memory heuristic (§4.3).

The inner multiplicative-update loop (Alg. 2 lines 7-14) is a lax.scan with
freeze-on-convergence masking so the whole mode update jits.

Mesh-bearing plans (``plan.make_plan(..., mesh=)``) shard the Φ row
reduction over the mesh via `repro.dist.cpd.sharded_phi` — same driver
code, per-device oriented segment reduction plus psum carry merge.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import health as health_mod
from repro.core import heuristics
from repro.core import ingest as ingest_mod
from repro.core import plan as plan_mod
from repro.core.alto import AltoTensor, OrientedView, delinearize
from repro.core.mttkrp import krp_rows


@dataclasses.dataclass(frozen=True)
class CpaprParams:
    """Algorithmic parameters of Alg. 2 (defaults follow the paper / ttb)."""
    k_max: int = 50          # max outer iterations
    l_max: int = 10          # max inner iterations (paper uses 10)
    tau: float = 1e-4        # KKT convergence tolerance
    kappa: float = 1e-2      # inadmissible-zero avoidance adjustment
    kappa_tol: float = 1e-10 # potential inadmissible zero threshold
    eps_div: float = 1e-10   # minimum divisor


@dataclasses.dataclass
class CpaprResult:
    lam: jnp.ndarray
    factors: list[jnp.ndarray]
    kkt_violations: list[float]    # per outer iteration (max over modes)
    log_likelihoods: list[float]
    n_outer: int
    n_inner_total: int
    pi_policy: str
    traversals: list[str]
    plan: plan_mod.ExecutionPlan | None = None
    # Guard outcome when the solve ran with guard=True (core.health).
    health: health_mod.HealthReport | None = None


def init_factors(dims: Sequence[int], rank: int, seed: int = 0,
                 total: float = 1.0, dtype=jnp.float32):
    """Random positive factors, columns 1-normalized; λ carries the mass."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims))
    factors = []
    for k, I in zip(keys, dims):
        A = jax.random.uniform(k, (I, rank), dtype=dtype, minval=0.1,
                               maxval=1.1)
        factors.append(A / jnp.sum(A, axis=0, keepdims=True))
    lam = jnp.full((rank,), total / rank, dtype=dtype)
    return lam, factors


def _phi(rows, vals, krp, B, eps):
    """Per-nonzero Φ contribution: (v / max(<B[i],krp>, ε)) · krp."""
    denom = jnp.maximum(jnp.sum(B[rows] * krp, axis=-1), eps)
    return (vals / denom)[:, None] * krp


def _mode_update(at: AltoTensor, view: OrientedView | None, mode: int,
                 lam, factors, phi_prev, first_outer: bool,
                 pre_pi: bool, p: CpaprParams,
                 plan: plan_mod.ExecutionPlan):
    """One full Alg. 2 mode update (lines 4-15), jit-able."""
    A = factors[mode]
    # Line 4: inadmissible-zero adjustment (skipped on the first outer iter).
    if first_outer:
        S = jnp.zeros_like(A)
    else:
        S = jnp.where((A < p.kappa_tol) & (phi_prev > 1.0), p.kappa, 0.0)
    B0 = (A + S) * lam[None, :]                       # line 5: B = (A+S)Λ

    if pre_pi:
        # Line 6 (Π, M×R rows) in the element order the plan's traversal
        # will consume (oriented modes read the view-permuted stream).
        oriented = (view is not None
                    and heuristics.is_oriented(
                        plan.modes[mode].traversal))
        words = view.words if oriented else at.words
        coords = delinearize(at.meta.enc, words)
        pi = krp_rows(coords, factors, mode)

    def phi_of(B):                                    # lines 8-9
        return plan_mod.execute_phi(
            plan, at, view, B, mode,
            factors=None if pre_pi else factors,
            pi=pi if pre_pi else None, eps=p.eps_div)

    def inner(carry, _):
        B, done, n_inner = carry
        Phi = phi_of(B)                               # line 8
        kkt = jnp.max(jnp.abs(jnp.minimum(B, 1.0 - Phi)))  # line 9
        now_done = done | (kkt < p.tau)
        B_new = jnp.where(now_done, B, B * Phi)       # line 13 (frozen after
        n_inner = n_inner + jnp.where(now_done, 0, 1)  # convergence)
        return (B_new, now_done, n_inner), (Phi, kkt)

    (B, done, n_inner), (phis, kkts) = jax.lax.scan(
        inner, (B0, jnp.asarray(False), jnp.asarray(0, jnp.int32)),
        None, length=p.l_max)
    Phi_last = phis[-1]

    lam_new = jnp.sum(B, axis=0)                      # line 15: λ = eᵀB
    lam_new = jnp.where(lam_new > 0, lam_new, 1.0)
    A_new = B / lam_new[None, :]
    # Mode converged iff no inner update was applied.
    mode_converged = n_inner == 0
    kkt_first = kkts[0]
    return A_new, lam_new, Phi_last, mode_converged, n_inner, kkt_first


def _mode_update_streaming(at: AltoTensor, view, mode: int,
                           lam, factors, phi_prev, first_outer: bool,
                           pre_pi: bool, p: CpaprParams,
                           plan: plan_mod.ExecutionPlan):
    """Out-of-core twin of `_mode_update`: host inner loop, chunked Φ.

    A streaming plan's Φ is a host loop over chunks (`kernels.ops`), so
    the jitted `lax.scan` inner loop is replaced by a python loop with
    the IDENTICAL semantics: Φ is computed from the current B, the KKT
    check freezes B on convergence, and the loop breaks where the scan
    would only recompute Φ of a frozen B (the same value — the masked
    scan runs `l_max` steps, the break just skips the no-op tail). Under
    ALTO-PRE there is no full-stream Π precompute — the chunked executor
    rebuilds each chunk's Π rows on device (`execute_phi(pre=True)`),
    elementwise-identical, so the result stays bitwise (see
    `docs/out-of-core.md` for the cost-semantics shift).
    """
    A = factors[mode]
    if first_outer:
        S = jnp.zeros_like(A)
    else:
        S = jnp.where((A < p.kappa_tol) & (phi_prev > 1.0), p.kappa, 0.0)
    B = (A + S) * lam[None, :]

    Phi = None
    n_inner = 0
    kkt_first = None
    for _ in range(p.l_max):
        Phi = plan_mod.execute_phi(plan, at, view, B, mode,
                                   factors=factors, eps=p.eps_div,
                                   pre=pre_pi)
        kkt = jnp.max(jnp.abs(jnp.minimum(B, 1.0 - Phi)))
        if kkt_first is None:
            kkt_first = kkt
        if bool(kkt < p.tau):
            break               # frozen: further steps recompute this Phi
        B = B * Phi
        n_inner += 1

    lam_new = jnp.sum(B, axis=0)
    lam_new = jnp.where(lam_new > 0, lam_new, 1.0)
    A_new = B / lam_new[None, :]
    return (A_new, lam_new, Phi, n_inner == 0,
            jnp.asarray(n_inner, jnp.int32), kkt_first)


def log_likelihood(at: AltoTensor, lam, factors, eps=1e-10):
    """Poisson log-likelihood Σ x·log(m) − Σ m (columns 1-normalized)."""
    coords = delinearize(at.meta.enc, at.words)
    prod = jnp.broadcast_to(lam[None, :], (coords.shape[0], lam.shape[0]))
    for m, A in enumerate(factors):
        prod = prod * A[coords[:, m]]
    model = jnp.maximum(jnp.sum(prod, axis=-1), eps)
    ll = jnp.sum(at.values * jnp.log(model))          # padding: v=0 rows
    return ll - jnp.sum(lam)


def cp_apr(at: AltoTensor, rank: int, params: CpaprParams | None = None,
           seed: int = 0, pi_policy: str | None = None,
           views: dict[int, OrientedView] | None = None,
           track_ll: bool = False,
           plan: plan_mod.ExecutionPlan | None = None,
           tune: str = "off", warm_start=None,
           guard: bool = False) -> CpaprResult:
    """CP-APR MU driver (Alg. 2). `pi_policy`: None=adaptive|'pre'|'otf'.

    ``warm_start`` seeds (λ, factors) from a previous solve — a
    `CpaprResult`, ``(lam, factors)``, or a factor list — clamped
    positive and column-renormalized, with rows for newly-grown extents
    filled small-positive (`ingest.grow_factors(positive=True)`); after
    `ingest.append_delta` the MU loop resumes near the converged state.

    All kernel routing (traversal per mode, Π policy, jnp vs Pallas) comes
    from ``plan``; the default plan resolves the paper heuristics with the
    reference backend on CPU and the Pallas backend on TPU. Oriented
    views come from the process-wide cache (`core.views` via
    `plan.build_views`): device-built by default, shared with CP-ALS and
    the autotuner — a tensor decomposed by both drivers materializes
    each mode's view once. ``tune``
    ("off"|"auto"|"force"|"search") swaps the analytic plan for a measured one
    from the autotuner's persistent store (`core.autotune`), timing
    candidates here if the store misses — the tensor data is in hand.
    CP-APR tunes against the fused Φ kernel (objective="phi"), its >99%
    bottleneck, under a store key distinct from CP-ALS's MTTKRP plans.
    """
    p = params or CpaprParams()
    N = len(at.dims)
    if at.meta.nnz == 0:
        # Degenerate tenant input: the zero model maximizes the Poisson
        # likelihood of an all-zero tensor (λ → 0). Return a well-defined
        # converged result instead of iterating on NaNs.
        dtype = at.values.dtype
        return CpaprResult(
            lam=jnp.zeros((rank,), dtype),
            factors=[jnp.zeros((I, rank), dtype) for I in at.dims],
            kkt_violations=[0.0], log_likelihoods=[], n_outer=0,
            n_inner_total=0, pi_policy=pi_policy or "otf",
            traversals=["oriented"] * N,
            plan=plan)
    total = float(jnp.sum(at.values))
    if warm_start is not None:
        lam, factors = ingest_mod.grow_factors(
            warm_start, at.dims, rank, seed=seed, dtype=at.values.dtype,
            positive=True)
        if lam is None:
            lam = jnp.full((rank,), total / rank, dtype=at.values.dtype)
    else:
        lam, factors = init_factors(at.dims, rank, seed=seed, total=total,
                                    dtype=at.values.dtype)

    if plan is None:
        plan = plan_mod.make_plan(at.meta, rank, tune=tune,
                                  tune_objective="phi", at=at)
    elif plan.rank != rank:
        raise ValueError(f"plan was built for rank {plan.rank}, "
                         f"cp_apr called with rank {rank}")
    if pi_policy is None:
        pi_policy = plan.pi_policy.value
    pre_pi = pi_policy == "pre"

    if views is None:
        views = plan_mod.build_views(at, plan)
    traversals = [plan.modes[n].traversal.value
                  if (n in views
                      and heuristics.is_oriented(plan.modes[n].traversal))
                  else "recursive" for n in range(N)]

    if plan.streaming is not None:
        # Out-of-core: the chunked Φ executor is a host loop over
        # per-chunk jitted calls, and a HostStream is not a jit operand.
        update = _mode_update_streaming
    else:
        update = jax.jit(_mode_update,
                         static_argnames=("mode", "first_outer", "pre_pi",
                                          "p", "plan"))

    phi_prev = [jnp.zeros_like(A) for A in factors]
    report = health_mod.HealthReport() if guard else None
    kkt_hist: list[float] = []
    ll_hist: list[float] = []
    n_inner_total = 0
    outer = 0
    for outer in range(1, p.k_max + 1):
        # Last good state for the guard's rollback (references only —
        # the arrays are immutable, nothing is copied).
        good = (lam, list(factors), list(phi_prev))
        all_converged = True
        kkt_max = 0.0
        for n in range(N):
            A, lam, phi_n, conv, n_inner, kkt = update(
                at, views.get(n), n, lam, factors, phi_prev[n],
                first_outer=(outer == 1), pre_pi=pre_pi, p=p, plan=plan)
            pd = faults.fire("cpapr.nan")
            if pd is not None:
                A = A.at[0, 0].set(pd.get("value", float("nan")))
            factors = list(factors)
            factors[n] = A
            phi_prev[n] = phi_n
            n_inner_total += int(n_inner)
            all_converged &= bool(conv)
            kkt_max = max(kkt_max, float(kkt))
        if guard:
            report.checks += 1
            if not np.isfinite(kkt_max) or not health_mod.all_finite(
                    [lam, *factors]):
                report.violations += 1
                report.rolled_back = True
                report.reason = (f"non-finite mode update at outer "
                                 f"iteration {outer}")
                lam, factors, phi_prev = good
                outer -= 1
                break
        kkt_hist.append(kkt_max)
        if track_ll:
            ll_hist.append(float(log_likelihood(at, lam, factors)))
        if all_converged:                              # lines 17-19
            break
    return CpaprResult(lam=lam, factors=factors, kkt_violations=kkt_hist,
                       log_likelihoods=ll_hist, n_outer=outer,
                       n_inner_total=n_inner_total, pi_policy=pi_policy,
                       traversals=traversals, plan=plan, health=report)
