"""Measured-candidate plan autotuner with a persistent on-disk plan store.

The paper's §4.3 dynamic adaptation picks algorithms from *static* tensor
characteristics; ReLATE (PAPERS.md) shows the next order of performance
comes from replacing those hand heuristics with measured/learned selection
over the same candidate space. This module is that measurement layer for
the plan stack:

* **candidate space** — `core.plan.candidate_mode_plans` enumerates the
  feasible (traversal × r_block × block_m) tilings per mode, pruned by
  the corrected per-kernel VMEM footprints (including the fused Φ
  kernel's full-rank resident B — the model the static heuristics got
  wrong, see `plan.phi_oriented_vmem_bytes`). The static analytic choice
  is always candidate 0, so the measured winner can never be worse than
  the static model *under the measurement*.
* **timing protocol** — every candidate is materialized as a full
  `ExecutionPlan` and timed through `plan.execute_mttkrp` /
  `plan.execute_phi` wrapped in one jitted executable per candidate,
  registered in the compiled-executable cache in `kernels.ops` (key: the
  hashable candidate plan itself). `ops.median_time` takes the median of
  k blocking calls after warmup runs that absorb compilation. On CPU the
  Pallas kernels run under the interpreter, so timings are a *proxy*
  ranking (documented in docs/known-issues.md); on TPU the same protocol
  times real Mosaic executables.
* **plan store** — winners persist in a versioned JSON file
  (``$REPRO_PLAN_CACHE`` or ``~/.cache/repro/plans.json``), keyed on a
  stable hash of (meta fingerprint, rank, backend, device platform,
  shard count, dtype/vmem budget, jax version, store version). A second
  process calling ``make_plan(..., tune="auto"|"force")`` gets the
  identical measured plan back with **zero timing runs**
  (`ops.timing_runs` proves it). Corrupted or stale-version store files
  are ignored, never fatal — the tuner just re-measures.

Mesh-bearing tuning times the *actual sharded executables* (the
candidate plan routes `execute_mttkrp` through `dist.cpd`), with the
candidate space sized against the per-shard budget exactly as
`make_plan(mesh=...)` sizes static plans.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core import heuristics
from repro.core import mttkrp as core_mttkrp
from repro.core import plan as plan_mod
from repro.core.alto import AltoMeta, AltoTensor, delinearize

# v2: the ORIENTED_CARRY traversal joined the candidate space. Bumping the
# store version makes every pre-carry store load as empty (stale winners,
# measured without the carry candidates, must not mask the new traversal).
# v3: streaming plans joined the store (records carry a ``streaming``
# chunk block, keys a ``dev=`` component) and records carry measurement
# ``samples`` that train the search cost model (`core.search`). Pre-search
# v2 stores load as empty — never clobbered until the first new write.
PLAN_STORE_VERSION = 3
PLAN_CACHE_ENV = "REPRO_PLAN_CACHE"
DEFAULT_STORE = "~/.cache/repro/plans.json"

DEFAULT_WARMUP = 1
DEFAULT_ITERS = 3
DEFAULT_MAX_CANDIDATES = 24


# ---------------------------------------------------------------------------
# Store keys: stable fingerprints of everything a measurement depends on
# ---------------------------------------------------------------------------

def meta_fingerprint(meta: AltoMeta) -> str:
    """Canonical string of every AltoMeta field a plan decision reads.

    The encoding's bit assignment is a pure function of ``dims`` but is
    fingerprinted anyway (``bit_mode``) so an encoder change invalidates
    stored plans instead of silently mismatching them.
    """
    enc = meta.enc
    return ";".join([
        "dims=" + ",".join(map(str, enc.dims)),
        "bitmode=" + ",".join(map(str, enc.bit_mode)),
        f"nnz={meta.nnz}",
        f"L={meta.n_partitions}",
        "temp=" + ",".join(map(str, meta.temp_rows)),
        "reuse=" + ",".join(repr(float(r)) for r in meta.fiber_reuse),
    ])


def plan_key(meta: AltoMeta, rank: int, backend: str, *,
             n_shards: int = 1, dtype_bytes: int = 4,
             vmem_limit: int = plan_mod.VMEM_BYTES,
             fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
             objective: str = "mttkrp",
             platform: str | None = None,
             device_bytes: int | None = None) -> str:
    """Stable store key: sha256 over everything a measurement depends on.

    ``platform`` (``jax.default_backend()``) is part of the key so
    CPU-interpret proxy timings never masquerade as TPU measurements,
    and ``jax.__version__`` so a toolchain upgrade re-measures.
    ``objective`` keeps mttkrp- and Φ-tuned plans apart (their winners
    differ), and ``fast_mem_bytes`` pins the Π-policy decision baked
    into the stored plan. ``device_bytes`` is the out-of-core budget a
    *streaming* plan was sized against (None for in-core plans — the
    same tensor tuned in core and tuned against a chunking budget are
    different measurements and must never share a record).
    """
    platform = platform or jax.default_backend()
    blob = "|".join([
        f"store_v{PLAN_STORE_VERSION}",
        meta_fingerprint(meta),
        f"rank={rank}",
        f"backend={backend}",
        f"platform={platform}",
        f"shards={n_shards}",
        f"dtype_bytes={dtype_bytes}",
        f"vmem={vmem_limit}",
        f"fast_mem={fast_mem_bytes}",
        f"objective={objective}",
        f"dev={device_bytes}",
        f"jax={jax.__version__}",
    ])
    return hashlib.sha256(blob.encode()).hexdigest()[:32]


def class_plan_key(sc, backend: str, **kwargs) -> str:
    """Store key for a shape class (`core.shapeclass.ShapeClass`).

    Delegates to `plan_key` over the class's canonical meta — a pure
    function of the class, with no data-dependent fields — so every
    tenant the class admits resolves to the SAME store entry: the class
    is measured once, then every subsequent tenant's dispatch is a
    zero-timing-run store hit (the serving layer's warm start).
    """
    from repro.core import shapeclass
    return plan_key(shapeclass.canonical_meta(sc), sc.rank, backend,
                    **kwargs)


# ---------------------------------------------------------------------------
# The on-disk store (versioned JSON; corrupt/stale files are ignored)
# ---------------------------------------------------------------------------

def store_path(override=None) -> pathlib.Path:
    """Resolve the plan-store file: explicit arg > $REPRO_PLAN_CACHE >
    ~/.cache/repro/plans.json."""
    if override is not None:
        return pathlib.Path(override).expanduser()
    env = os.environ.get(PLAN_CACHE_ENV)
    if env:
        return pathlib.Path(env).expanduser()
    return pathlib.Path(DEFAULT_STORE).expanduser()


def load_store(path=None) -> dict:
    """The store's ``plans`` mapping. Missing, unreadable, corrupted, or
    stale-version files all load as empty — a bad cache can cost a
    re-measurement, never a crash."""
    try:
        faults.inject("autotune.store")    # corrupt/unreadable store file
        raw = json.loads(store_path(path).read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(raw, dict) or raw.get("version") != PLAN_STORE_VERSION:
        return {}
    plans = raw.get("plans")
    return plans if isinstance(plans, dict) else {}


def save_store(plans: dict, path=None) -> pathlib.Path:
    """Atomically write the store (tmp file + rename, survives a crash
    mid-write as either the old or the new file, never a torn one)."""
    target = store_path(path)
    target.parent.mkdir(parents=True, exist_ok=True)
    payload = {"version": PLAN_STORE_VERSION, "jax": jax.__version__,
               "plans": plans}
    fd, tmp = tempfile.mkstemp(dir=str(target.parent),
                               prefix=target.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, target)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return target


def evict(key: str, path=None) -> bool:
    """Drop one stored plan (the evict-and-retune recovery rung).

    A stored plan that fails at *dispatch* — tiling from another
    device generation, a record that deserializes but whose kernel no
    longer builds — would otherwise fail every future process that
    trusts the store. The serving runtime evicts the key and falls back
    to an untuned static plan for the request in hand; the next tuned
    solve re-measures and re-populates. Returns True iff present.
    """
    plans = load_store(path)
    if key not in plans:
        return False
    del plans[key]
    save_store(plans, path)
    return True


def serialize_plan(plan: plan_mod.ExecutionPlan) -> dict:
    """JSON record of a plan. ``meta`` itself is NOT stored — the store
    key already pins it, and deserialization re-attaches the caller's
    meta/mesh — only a human-readable summary (dims, nnz) rides along."""
    return {
        "rank": plan.rank,
        "backend": plan.backend,
        "pi_policy": plan.pi_policy.value,
        "n_shards": plan.n_shards,
        "modes": [{
            "mode": m.mode,
            "traversal": m.traversal.value,
            "r_block": m.r_block,
            "block_m": m.block_m,
            "temp_rows": m.temp_rows,
            "vmem_bytes": m.vmem_bytes,
            "phi_vmem_bytes": m.phi_vmem_bytes,
        } for m in plan.modes],
        "streaming": None if plan.streaming is None else {
            "chunk_m": plan.streaming.chunk_m,
            "n_chunks": plan.streaming.n_chunks,
            "device_bytes": plan.streaming.device_bytes,
            "stream_bytes": plan.streaming.stream_bytes,
        },
        "dims": list(plan.meta.dims),
        "nnz": plan.meta.nnz,
    }


def deserialize_plan(record: dict, meta: AltoMeta, *,
                     mesh=None, interpret: bool | None = None
                     ) -> plan_mod.ExecutionPlan:
    """Rebuild an ExecutionPlan from a store record + the caller's meta.

    Raises KeyError/ValueError on malformed records — `lookup` treats
    those as a store miss.
    """
    modes = tuple(plan_mod.ModePlan(
        mode=int(m["mode"]),
        traversal=heuristics.Traversal(m["traversal"]),
        r_block=int(m["r_block"]),
        block_m=int(m["block_m"]),
        temp_rows=int(m["temp_rows"]),
        vmem_bytes=int(m["vmem_bytes"]),
        phi_vmem_bytes=int(m["phi_vmem_bytes"]),
    ) for m in record["modes"])
    if len(modes) != meta.enc.ndim:
        raise ValueError("record mode count does not match meta")
    rank = int(record["rank"])
    for m in modes:
        if m.r_block <= 0 or rank % m.r_block:
            raise ValueError(f"stored r_block {m.r_block} does not divide "
                             f"rank {rank}")
    streaming = None
    s = record.get("streaming")
    if s is not None:
        if mesh is not None:
            raise ValueError("streaming records do not compose with mesh")
        chunk_m = int(s["chunk_m"])
        align = max(m.block_m for m in modes)
        if chunk_m <= 0 or chunk_m % align:
            raise ValueError(f"stored chunk_m {chunk_m} is not a multiple "
                             f"of the plan's max block_m {align}")
        # n_chunks is a pure function of (meta, chunk_m): recompute
        # rather than trust the record, so a stale count can't desync
        # the executed grid from the stream.
        streaming = plan_mod.StreamPlan(
            chunk_m=chunk_m,
            n_chunks=plan_mod.chunk_count(meta, chunk_m),
            device_bytes=int(s["device_bytes"]),
            stream_bytes=int(s["stream_bytes"]))
    return plan_mod.ExecutionPlan(
        meta=meta, rank=rank, backend=str(record["backend"]),
        interpret=interpret,
        pi_policy=heuristics.PiPolicy(record["pi_policy"]),
        modes=modes, mesh=mesh, streaming=streaming)


def lookup(meta: AltoMeta, rank: int, *, backend: str,
           dtype_bytes: int = 4, vmem_limit: int = plan_mod.VMEM_BYTES,
           fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
           objective: str = "mttkrp",
           mesh=None, interpret: bool | None = None,
           device_bytes: int | None = None,
           path=None) -> plan_mod.ExecutionPlan | None:
    """Stored measured plan for this configuration, or None. Zero timing
    runs either way. ``device_bytes`` selects the streaming record for
    that out-of-core budget (None = the in-core record)."""
    n_shards = 1 if mesh is None else int(mesh.shape[mesh.axis_names[0]])
    key = plan_key(meta, rank, backend, n_shards=n_shards,
                   dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
                   fast_mem_bytes=fast_mem_bytes, objective=objective,
                   device_bytes=device_bytes)
    record = load_store(path).get(key)
    if record is None:
        return None
    try:
        return deserialize_plan(record, meta, mesh=mesh,
                                interpret=interpret)
    except (KeyError, ValueError, TypeError):
        return None       # malformed entry == miss; tuner will overwrite


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CandidateTiming:
    """One measured candidate for one mode."""
    mode: int
    traversal: str
    r_block: int
    block_m: int
    median_s: float
    is_static: bool      # True iff this is the analytic-model choice


@dataclasses.dataclass(frozen=True)
class ModeReport:
    mode: int
    candidates: tuple[CandidateTiming, ...]

    @property
    def best(self) -> CandidateTiming:
        return min(self.candidates, key=lambda c: c.median_s)

    @property
    def static(self) -> CandidateTiming:
        return next(c for c in self.candidates if c.is_static)


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """Per-mode candidate timings + where the winner was persisted."""
    modes: tuple[ModeReport, ...]
    key: str
    store: str          # path the plan was persisted to ("" if not)
    objective: str


def _candidate_plan(meta, rank, backend, interpret, pi_policy, mode,
                    candidate, base_modes, mesh):
    """A full ExecutionPlan with ``candidate`` swapped in at ``mode`` —
    hashable, so it doubles as the timing executable's cache key."""
    modes = list(base_modes)
    modes[mode] = candidate
    return plan_mod.ExecutionPlan(meta=meta, rank=rank, backend=backend,
                                  interpret=interpret, pi_policy=pi_policy,
                                  modes=tuple(modes), mesh=mesh)


def _time_mttkrp(cand_plan, at, views, factors, mode, warmup, iters):
    from repro.kernels import ops

    def build():
        def run(at, views, factors):
            return plan_mod.execute_mttkrp(cand_plan, at, views, factors,
                                           mode)
        return jax.jit(run)

    fn = ops._cached_executable(("tune_mttkrp", cand_plan, mode), build)
    return ops.median_time(fn, at, views, factors,
                           warmup=warmup, iters=iters)


def _time_phi(cand_plan, at, view, B, factors, pi, mode, warmup, iters,
              eps=1e-10):
    from repro.kernels import ops
    pre_pi = pi is not None

    def build():
        def run(at, view, B, factors, pi):
            return plan_mod.execute_phi(
                cand_plan, at, view, B, mode,
                factors=None if pre_pi else factors,
                pi=pi, eps=eps)
        return jax.jit(run)

    fn = ops._cached_executable(("tune_phi", cand_plan, mode, pre_pi, eps),
                                build)
    return ops.median_time(fn, at, view, B, factors, pi,
                           warmup=warmup, iters=iters)


def tune_plan(at: AltoTensor, rank: int, *, backend: str | None = None,
              interpret: bool | None = None, dtype_bytes: int = 4,
              vmem_limit: int = plan_mod.VMEM_BYTES,
              fast_mem_bytes: int = heuristics.DEFAULT_FAST_MEM_BYTES,
              mesh=None, objective: str = "mttkrp",
              warmup: int = DEFAULT_WARMUP, iters: int = DEFAULT_ITERS,
              max_candidates: int | None = None,
              seed: int = 0, persist: bool = True,
              store_path=None) -> tuple[plan_mod.ExecutionPlan, TuneReport]:
    """Measure the feasible tiling space and return the winning plan.

    ``objective`` picks the timed kernel: ``"mttkrp"`` (CP-ALS's
    bottleneck, the default) or ``"phi"`` (CP-APR's fused model update;
    r_block is dead there, so candidates collapse to traversal ×
    block_m). Factors are synthetic (seeded), so timings depend only on
    the static meta the store key fingerprints.

    Returns ``(plan, report)``; the report carries every candidate's
    median so callers (bench_autotune, tests) can verify the winner is
    never slower than the static-model choice under the measurement —
    guaranteed by construction since the static choice is candidate 0
    and the winner is the argmin.
    """
    if objective not in ("mttkrp", "phi"):
        raise ValueError(f"unknown objective {objective!r}")
    if max_candidates is None:
        max_candidates = DEFAULT_MAX_CANDIDATES   # late-bound: patchable
    meta = at.meta
    backend = backend or plan_mod.default_backend()
    n_shards = 1 if mesh is None else int(mesh.shape[mesh.axis_names[0]])
    budget = max(1, vmem_limit // n_shards)
    pi_policy = heuristics.choose_pi_policy(
        meta, rank, value_bytes=dtype_bytes, fast_mem_bytes=fast_mem_bytes)
    pre_pi = pi_policy is heuristics.PiPolicy.PRE

    rng = np.random.default_rng(seed)
    factors = [jnp.asarray(rng.standard_normal((I, rank))
                           .astype(np.float32)) for I in meta.dims]
    # Static baseline plan: candidate plans swap ONE mode at a time so
    # the timed executable differs from the baseline only in that mode.
    base_modes = tuple(
        plan_mod.static_mode_plan(meta, n, rank, dtype_bytes=dtype_bytes,
                                  vmem_limit=budget,
                                  force_oriented=mesh is not None,
                                  pre_pi=pre_pi)
        for n in range(meta.enc.ndim))

    winners, reports = [], []
    for n in range(meta.enc.ndim):
        cands = plan_mod.candidate_mode_plans(
            meta, n, rank, dtype_bytes=dtype_bytes, vmem_limit=budget,
            force_oriented=mesh is not None, pre_pi=pre_pi,
            max_candidates=max_candidates)
        if backend == "reference":
            # The pure-jnp traversals have no tiling knobs, and both
            # oriented variants run the same sorted segment_sum: one
            # candidate per traversal *family*, everything else times
            # identically.
            dedupe_key = lambda c: (                             # noqa: E731
                "oriented" if heuristics.is_oriented(c.traversal)
                else c.traversal,)
        elif objective == "phi":
            # The fused Φ kernel has no rank tiling: candidates that
            # differ only in r_block time identically, keep the first
            # (largest fitting r_block, or the static choice).
            dedupe_key = lambda c: (c.traversal, c.block_m)      # noqa: E731
        else:
            dedupe_key = None
        if dedupe_key is not None:
            seen, deduped = set(), []
            for c in cands:
                k = dedupe_key(c)
                if k not in seen:
                    seen.add(k)
                    deduped.append(c)
            cands = tuple(deduped)
        needs_view = (mesh is not None) or any(
            heuristics.is_oriented(c.traversal) for c in cands)
        # Shared view cache: the tuner's timing views are the very views
        # the driver will consume afterwards — built once per (tensor,
        # mode), on device by default (core.views routing).
        from repro.core import views as views_mod
        view = views_mod.get_view(at, n) if needs_view else None
        views = {n: view} if view is not None else {}
        if objective == "phi":
            B = jnp.abs(factors[n]) + jnp.float32(0.1)
            # ALTO-PRE Π rows must be in the element order the timed
            # traversal consumes (same rule as cpapr._mode_update).
            pi_alto = pi_view = None
            if pre_pi:
                pi_alto = core_mttkrp.krp_rows(
                    delinearize(meta.enc, at.words), factors, n)
                if view is not None:
                    pi_view = core_mttkrp.krp_rows(
                        delinearize(meta.enc, view.words), factors, n)
        timings = []
        for i, mp in enumerate(cands):
            cand_plan = _candidate_plan(meta, rank, backend, interpret,
                                        pi_policy, n, mp, base_modes, mesh)
            if objective == "phi":
                oriented = (view is not None
                            and heuristics.is_oriented(mp.traversal))
                pi = (pi_view if oriented else pi_alto) if pre_pi else None
                t = _time_phi(cand_plan, at, view, B, factors, pi, n,
                              warmup, iters)
            else:
                t = _time_mttkrp(cand_plan, at, views, factors, n,
                                 warmup, iters)
            timings.append(CandidateTiming(
                mode=n, traversal=mp.traversal.value, r_block=mp.r_block,
                block_m=mp.block_m, median_s=float(t), is_static=(i == 0)))
        best_i = min(range(len(cands)), key=lambda i: timings[i].median_s)
        winners.append(cands[best_i])
        reports.append(ModeReport(mode=n, candidates=tuple(timings)))

    plan = plan_mod.ExecutionPlan(meta=meta, rank=rank, backend=backend,
                                  interpret=interpret, pi_policy=pi_policy,
                                  modes=tuple(winners), mesh=mesh)
    key = plan_key(meta, rank, backend, n_shards=n_shards,
                   dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
                   fast_mem_bytes=fast_mem_bytes, objective=objective)
    stored = ""
    if persist:
        from repro.core import search as search_mod
        record = serialize_plan(plan)
        record["tuned"] = {
            "mode": "exhaustive",
            "platform": jax.default_backend(),
            "objective": objective,
            "warmup": warmup,
            "iters": iters,
            "modes": [{
                "mode": r.mode,
                "best_us": r.best.median_s * 1e6,
                "static_us": r.static.median_s * 1e6,
                "n_candidates": len(r.candidates),
            } for r in reports],
        }
        # Every exhaustive measurement doubles as a training sample for
        # the search cost model (`core.search`): exhaustive runs warm
        # the model that later budgeted searches rank candidates with.
        samples = []
        for r in reports:
            for c in r.candidates:
                samples.append({
                    "f": [round(f, 6) for f in search_mod.gene_features(
                        meta, rank, r.mode,
                        heuristics.Traversal(c.traversal), c.r_block,
                        c.block_m, objective=objective,
                        dtype_bytes=dtype_bytes)],
                    "s": c.median_s,
                })
        record["samples"] = samples[:search_mod.MAX_RECORD_SAMPLES]
        plans = load_store(store_path)
        plans[key] = record
        stored = str(save_store(plans, store_path))
    return plan, TuneReport(modes=tuple(reports), key=key, store=stored,
                            objective=objective)


# ---------------------------------------------------------------------------
# make_plan's entry point (tune="auto"|"force")
# ---------------------------------------------------------------------------

def tuned_plan(meta: AltoMeta, rank: int, *, backend: str,
               interpret: bool | None, dtype_bytes: int, vmem_limit: int,
               fast_mem_bytes: int, mesh, at: AltoTensor | None,
               require: bool, objective: str = "mttkrp",
               search: bool = False, device_bytes: int | None = None,
               search_budget_runs: int | None = None,
               search_budget_s: float | None = None,
               search_seed: int = 0,
               store_path=None) -> plan_mod.ExecutionPlan | None:
    """Store lookup, else measured tuning; ``None`` tells `make_plan` to
    fall back to the static analytic plan (tune="auto" with no data).

    ``search=True`` (``tune="search"``) routes the measurement through
    the budgeted GA + cost-model engine (`core.search`) instead of the
    exhaustive tuner. ``device_bytes`` non-None marks a *streaming*
    plan: those always tune through the search engine (the exhaustive
    tuner's jitted timing closures cannot take a host-resident stream,
    and chunk_m is part of the search genome, not the exhaustive
    space) and are stored under a device-budget-keyed record. Mesh
    plans keep the exhaustive path — the sharded timing protocol lives
    there (streaming+mesh is rejected upstream by `make_plan`).
    """
    hit = lookup(meta, rank, backend=backend, dtype_bytes=dtype_bytes,
                 vmem_limit=vmem_limit, fast_mem_bytes=fast_mem_bytes,
                 objective=objective, mesh=mesh, interpret=interpret,
                 device_bytes=device_bytes, path=store_path)
    if hit is not None:
        return hit
    if at is not None:
        if at.meta != meta:
            raise ValueError("tune: at.meta does not match the meta the "
                             "plan is being built for")
        if (search or device_bytes is not None) and mesh is None:
            from repro.core import search as search_mod
            plan, _ = search_mod.search_plan(
                at, rank, backend=backend, interpret=interpret,
                dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
                fast_mem_bytes=fast_mem_bytes, objective=objective,
                device_bytes=device_bytes,
                budget_runs=search_budget_runs,
                budget_s=search_budget_s, seed=search_seed,
                store_path=store_path)
            return plan
        plan, _ = tune_plan(at, rank, backend=backend, interpret=interpret,
                            dtype_bytes=dtype_bytes, vmem_limit=vmem_limit,
                            fast_mem_bytes=fast_mem_bytes, mesh=mesh,
                            objective=objective, store_path=store_path)
        return plan
    if require:
        raise ValueError(
            "tune='force': no stored measured plan for this tensor and no "
            "tensor data to measure — pass the built tensor (at=..., or "
            "use plan_for / the drivers' tune= kwarg) or pre-populate the "
            f"plan store ({store_path or store_path_hint()})")
    return None


def store_path_hint() -> str:
    return os.environ.get(PLAN_CACHE_ENV) or DEFAULT_STORE
