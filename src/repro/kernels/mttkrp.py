"""Pallas TPU kernel: partitioned MTTKRP over ALTO tensors (paper Alg. 4).

One grid step processes one balanced ALTO partition (and one rank tile) and
produces that partition's dense ``Temp`` accumulator — the VMEM-resident
local buffer of the paper's recursive traversal. The pull-based reduction
(Alg. 4 lines 14-18) merges partials outside the kernel (see ops.py).

TPU adaptation of the CPU algorithm:
  * delinearization is the static shift/or chain (VPU) fused ahead of the
    FLOP work, so index decode overlaps the value stream;
  * factor-row gather uses jnp.take on the VMEM-resident factor tile;
  * scatter-add into Temp is expressed as a ONE-HOT MATMUL
    (``onehot(local_rows).T @ contrib``), putting the irregular update on
    the MXU systolic array instead of emulating atomics — TPUs have no
    atomics, and this is the highest-throughput conflict resolution for
    bounded-interval partitions (the ALTO interval bound is what keeps the
    one-hot operand VMEM-sized);
  * the mode intervals give a *static* Temp height, so the kernel's VMEM
    footprint is known at compile time.

VMEM budget per grid step (f32): block_m·(W/8 + 1 + T) + T·r_block +
sum_m I_m·r_block words — callers pick block_m / r_block so this fits 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import AltoEncoding
from repro.kernels.delinearize import _delinearize_kernel  # noqa: F401


def _decode(enc: AltoEncoding, words):
    import numpy as np
    cols = [jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
            for _ in range(enc.ndim)]
    for r in enc.runs:
        chunk = (words[..., r.word] >> np.uint32(r.dst_shift)) \
            & np.uint32(r.mask)
        cols[r.mode] = cols[r.mode] | (chunk << np.uint32(r.src_shift))
    return [c.astype(jnp.int32) for c in cols]


def _mttkrp_partial_kernel(enc: AltoEncoding, mode: int, temp_rows: int,
                           words_ref, vals_ref, start_ref, *refs):
    """Grid step: one (partition, rank-tile). Emits Temp_l (1, T, r_block)."""
    factor_refs = refs[:-1]
    out_ref = refs[-1]
    words = words_ref[...]                    # (chunk, W)
    vals = vals_ref[...]                      # (chunk,)
    coords = _decode(enc, words)              # N × (chunk,)

    krp = None                                # Khatri-Rao rows, (chunk, rb)
    fi = 0
    for m in range(enc.ndim):
        if m == mode:
            continue
        rows = jnp.take(factor_refs[fi][...], coords[m], axis=0)
        krp = rows if krp is None else krp * rows
        fi += 1
    contrib = vals[:, None] * krp             # (chunk, rb)

    local = coords[mode] - start_ref[0, mode]  # in [0, temp_rows)
    onehot = (local[:, None] == jax.lax.iota(jnp.int32, temp_rows)[None, :]
              ).astype(contrib.dtype)          # (chunk, T)
    # Scatter-add on the MXU: Temp = onehotᵀ · contrib.
    out_ref[0] = jax.lax.dot_general(
        onehot, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def mttkrp_partials_pallas(enc: AltoEncoding, mode: int, temp_rows: int,
                           words: jnp.ndarray, values: jnp.ndarray,
                           part_start: jnp.ndarray, factors,
                           r_block: int | None = None,
                           interpret: bool = True) -> jnp.ndarray:
    """Per-partition Temp buffers: (L, temp_rows, R)."""
    L = part_start.shape[0]
    Mp, W = words.shape
    chunk = Mp // L
    R = factors[0].shape[1]
    rb = r_block or R
    if R % rb:
        raise ValueError(f"rank {R} not a multiple of r_block {rb}")
    others = [f for m, f in enumerate(factors) if m != mode]

    in_specs = [
        pl.BlockSpec((chunk, W), lambda l, r: (l, 0)),        # words
        pl.BlockSpec((chunk,), lambda l, r: (l,)),            # values
        pl.BlockSpec((1, len(factors)), lambda l, r: (l, 0)),  # part_start
    ] + [
        pl.BlockSpec((f.shape[0], rb), lambda l, r: (0, r)) for f in others
    ]
    return pl.pallas_call(
        functools.partial(_mttkrp_partial_kernel, enc, mode, temp_rows),
        grid=(L, R // rb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, temp_rows, rb), lambda l, r: (l, 0, r)),
        out_shape=jax.ShapeDtypeStruct((L, temp_rows, R), factors[0].dtype),
        interpret=interpret,
    )(words, values, part_start, *others)
