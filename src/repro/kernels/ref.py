"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import AltoEncoding


def ref_delinearize(enc: AltoEncoding, words: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.delinearize: per-bit scatter, no run compression."""
    cols = [jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
            for _ in range(enc.ndim)]
    for b in range(enc.total_bits):
        m = enc.bit_mode[b]
        bit = (words[..., b // 32] >> np.uint32(b % 32)) & np.uint32(1)
        cols[m] = cols[m] | (bit << np.uint32(enc.bit_pos[b]))
    return jnp.stack(cols, axis=-1).astype(jnp.int32)


def _krp(coords, factors, mode):
    out = None
    for m, A in enumerate(factors):
        if m == mode:
            continue
        rows = A[coords[..., m]]
        out = rows if out is None else out * rows
    return out


def ref_mttkrp_partials(enc: AltoEncoding, mode: int, temp_rows: int,
                        words, values, part_start, factors) -> jnp.ndarray:
    """Oracle for kernels.mttkrp: scatter-add based per-partition Temp."""
    L = part_start.shape[0]
    Mp = words.shape[0]
    chunk = Mp // L
    coords = ref_delinearize(enc, words)
    contrib = values[:, None] * _krp(coords, factors, mode)
    R = contrib.shape[-1]
    local = (coords[:, mode].reshape(L, chunk)
             - part_start[:, mode][:, None])
    c = contrib.reshape(L, chunk, R)

    def one(loc, con):
        return jnp.zeros((temp_rows, R), dtype=con.dtype).at[loc].add(con)

    return jax.vmap(one)(local, c)


def ref_phi_partials(enc: AltoEncoding, mode: int, temp_rows: int,
                     eps: float, words, values, part_start, B,
                     factors=None, pi=None) -> jnp.ndarray:
    """Oracle for kernels.cpapr_phi."""
    L = part_start.shape[0]
    Mp = words.shape[0]
    chunk = Mp // L
    coords = ref_delinearize(enc, words)
    krp = pi if pi is not None else _krp(coords, factors, mode)
    rows = coords[:, mode]
    denom = jnp.maximum(jnp.sum(B[rows] * krp, axis=-1), eps)
    contrib = (values / denom)[:, None] * krp
    R = contrib.shape[-1]
    local = (rows.reshape(L, chunk) - part_start[:, mode][:, None])
    c = contrib.reshape(L, chunk, R)

    def one(loc, con):
        return jnp.zeros((temp_rows, R), dtype=con.dtype).at[loc].add(con)

    return jax.vmap(one)(local, c)


def ref_pull_reduction(partials: jnp.ndarray, part_start_mode: jnp.ndarray,
                       out_dim: int) -> jnp.ndarray:
    """Oracle for the pull-based reduction (Alg. 4 lines 14-18)."""
    L, T, R = partials.shape
    rows = part_start_mode[:, None] + jnp.arange(T)[None, :]
    rows = jnp.minimum(rows, out_dim - 1)
    return jnp.zeros((out_dim, R), partials.dtype).at[rows].add(partials)
