"""Pallas TPU kernel: ALTO delinearization (bit-level scatter, paper Fig. 6b).

Streams the packed multi-word u32 linearized index from HBM through VMEM
tiles and emits int32 coordinates. Pure VPU elementwise work (shifts / ands /
ors over a static run plan), so the kernel is strictly memory-bound — the
point of the paper's compact index is that this stream is 2-4x smaller than
the COO coordinate stream it replaces, and the decode overlaps the loads.

Grid: 1-D over nonzero blocks. BlockSpec keeps a (block_m, n_words) u32 tile
and a (block_m, N) i32 output tile resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.encoding import AltoEncoding

DEFAULT_BLOCK_M = 1024


def _delinearize_kernel(enc: AltoEncoding, words_ref, coords_ref):
    words = words_ref[...]                       # (block_m, n_words) u32
    cols = [jnp.zeros(words.shape[:-1], dtype=jnp.uint32)
            for _ in range(enc.ndim)]
    for r in enc.runs:                            # static run plan
        chunk = (words[..., r.word] >> np.uint32(r.dst_shift)) \
            & np.uint32(r.mask)
        cols[r.mode] = cols[r.mode] | (chunk << np.uint32(r.src_shift))
    coords_ref[...] = jnp.stack(cols, axis=-1).astype(jnp.int32)


def delinearize_pallas(enc: AltoEncoding, words: jnp.ndarray,
                       block_m: int = DEFAULT_BLOCK_M,
                       interpret: bool = True) -> jnp.ndarray:
    """(M, n_words) u32 -> (M, N) int32. M must be an exact multiple of
    block_m, validated like every other kernel — callers pad through the
    shared `ops.pad_sorted_stream` rule (the `ops.delinearize` wrapper
    does, slicing the tail back off) instead of this kernel silently
    shrinking the block to fit."""
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"M={M} not a multiple of block_m={block_m}")
    grid = (M // block_m,)
    return pl.pallas_call(
        functools.partial(_delinearize_kernel, enc),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_m, enc.ndim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, enc.ndim), jnp.int32),
        interpret=interpret,
    )(words)
