"""Jit'd public wrappers around the Pallas kernels.

On the CPU test host every kernel runs with interpret=True (the Pallas
interpreter executes the kernel body in Python); on TPU the same call sites
compile to Mosaic. `interpret=None` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.alto import AltoTensor
from repro.core.encoding import AltoEncoding
from repro.kernels import cpapr_phi as _phi
from repro.kernels import delinearize as _delin
from repro.kernels import mttkrp as _mttkrp


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def delinearize(enc: AltoEncoding, words: jnp.ndarray,
                block_m: int = _delin.DEFAULT_BLOCK_M,
                interpret: bool | None = None) -> jnp.ndarray:
    """ALTO index words -> int32 coordinates (bit-scatter kernel)."""
    M = words.shape[0]
    bm = min(block_m, M)
    while M % bm:
        bm -= 1
    fn = jax.jit(functools.partial(
        _delin.delinearize_pallas, enc, block_m=bm,
        interpret=_auto_interpret(interpret)))
    return fn(words)


def pull_reduction(partials: jnp.ndarray, part_start_mode: jnp.ndarray,
                   out_dim: int) -> jnp.ndarray:
    """Merge per-partition Temp buffers (Alg. 4 lines 14-18)."""
    L, T, R = partials.shape
    rows = part_start_mode[:, None] + jnp.arange(T)[None, :]
    rows = jnp.minimum(rows, out_dim - 1)
    out = jnp.zeros((out_dim, R), partials.dtype)
    return out.at[rows].add(partials)


def mttkrp(at: AltoTensor, factors, mode: int,
           r_block: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Full MTTKRP: Pallas partials kernel + pull reduction."""
    meta = at.meta

    @jax.jit
    def run(words, values, part_start, factors):
        partials = _mttkrp.mttkrp_partials_pallas(
            meta.enc, mode, meta.temp_rows[mode], words, values, part_start,
            factors, r_block=r_block, interpret=_auto_interpret(interpret))
        return pull_reduction(partials, part_start[:, mode],
                              meta.dims[mode])

    return run(at.words, at.values, at.part_start, list(factors))


def cpapr_phi(at: AltoTensor, B: jnp.ndarray, mode: int,
              factors=None, pi: jnp.ndarray | None = None,
              eps: float = 1e-10,
              interpret: bool | None = None) -> jnp.ndarray:
    """Full fused Φ update: Pallas partials kernel + pull reduction."""
    meta = at.meta

    @jax.jit
    def run(words, values, part_start, B, factors, pi):
        partials = _phi.phi_partials_pallas(
            meta.enc, mode, meta.temp_rows[mode], eps, words, values,
            part_start, B, factors=factors, pi=pi,
            interpret=_auto_interpret(interpret))
        return pull_reduction(partials, part_start[:, mode],
                              meta.dims[mode])

    return run(at.words, at.values, at.part_start, B,
               list(factors) if factors is not None else None, pi)
