"""Jit'd public wrappers around the Pallas kernels, with executable caching.

Paper §4.2/§4.3 kernel entry points. Invariants: oriented entry points
consume a *row-sorted* stream (ascending target-mode row, `ops` pads it to
the block multiple with zero-valued copies of the last element); every
cache key is built from static, hashable metadata only (`AltoMeta`, mode,
tiling, interpret flag), never from traced values; `segment_merge` must
reproduce the kernels' run-rank segmentation bit-for-bit (both call
`mttkrp_oriented.run_rank_segments`) — that is the carry-merge correctness
condition.

On the CPU test host every kernel runs with interpret=True (the Pallas
interpreter traces the kernel body into regular XLA); on TPU the same call
sites compile to Mosaic. `interpret=None` auto-detects.

Every wrapper resolves to a **cached jitted executable** keyed on the
tensor's static metadata (`AltoMeta` is frozen/hashable) plus the static
kernel parameters (mode, block sizes, interpret flag). Before this cache
each call built a fresh closure and `jax.jit` object, so XLA re-traced and
re-compiled the kernel on *every* invocation — per sweep, per mode, per
iteration. Now the first call per (meta, mode, tiling) compiles once and
subsequent calls hit jit's C++ fast path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import faults
from repro.core import stream as _stream
from repro.core.alto import AltoTensor, OrientedView
from repro.core.alto import delinearize as _delin_jnp
from repro.core.encoding import AltoEncoding
from repro.core.mttkrp import krp_rows as _krp_rows
from repro.kernels import cpapr_phi as _phi
from repro.kernels import delinearize as _delin
from repro.kernels import mttkrp as _mttkrp
from repro.kernels import mttkrp_oriented as _oriented


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Compiled-executable cache
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict[tuple, Callable] = {}
# One lock for every module-global mutated here (the executable cache and
# the timing counter below): concurrent autotuners / serving drivers were
# racing dict insertions and losing counter increments.
_OPS_LOCK = threading.Lock()


def _cached_executable(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Return the jitted executable for ``key``, building it on first use.

    Thread-safe: the whole check-build-insert runs under the module lock.
    ``build`` only constructs the `jax.jit` wrapper (tracing/compilation
    happens lazily at the first call, outside the lock), so holding the
    lock across it is cheap and keeps the one-entry-per-key contract.
    """
    with _OPS_LOCK:
        fn = _EXEC_CACHE.get(key)
        if fn is None:
            fn = _EXEC_CACHE[key] = build()
        return fn


def cache_size() -> int:
    with _OPS_LOCK:
        return len(_EXEC_CACHE)


def cache_clear() -> None:
    with _OPS_LOCK:
        _EXEC_CACHE.clear()


# ---------------------------------------------------------------------------
# Timing hook (the autotuner's measurement primitive)
# ---------------------------------------------------------------------------

_TIMING_RUNS = 0


def timing_runs() -> int:
    """Number of `median_time` measurements taken in this process.

    `core.autotune` uses this to prove plan-store hits are measurement
    free: loading a persisted plan must leave the counter untouched.
    """
    with _OPS_LOCK:
        return _TIMING_RUNS


def timing_stats(fn: Callable, *args, warmup: int = 1,
                 iters: int = 3) -> tuple[float, float]:
    """(median, IQR) wall-clock seconds of a blocking call, after warmup.

    The autotuner's timing hook on the cached executables: ``fn`` is one
    of the public wrappers above (or any callable ending in a jitted
    call), so the warmup runs absorb compilation + the executable-cache
    fill and the timed iterations hit jit's C++ fast path. Warmup calls
    are run but never timed — they cannot enter the sample at all, so a
    slow first (compiling) call can't skew the statistics. The median is
    the true sample median (middle-pair average for even ``iters``, not
    the upper-middle element), robust against one descheduled run; the
    IQR (Q3 − Q1, nearest-rank quartiles) is the measurement's own
    spread estimate — search fitness comparisons can treat two medians
    closer than their IQRs as a tie instead of crowning noise.

    One call == one measurement for the `timing_runs` counter contract,
    regardless of ``warmup``/``iters``.
    """
    global _TIMING_RUNS
    # Unsynchronized `+= 1` loses updates under concurrent autotuning,
    # which silently breaks the "store hits are measurement-free" proof
    # (a lost increment can mask a real measurement).
    with _OPS_LOCK:
        _TIMING_RUNS += 1
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    n = len(times)
    if n % 2:
        median = times[n // 2]
    else:
        median = 0.5 * (times[n // 2 - 1] + times[n // 2])
    # Nearest-rank quartiles: exact enough for the small n the tuner
    # uses, and degenerate (IQR=0) at n=1 as it should be.
    q1 = times[n // 4]
    q3 = times[min(n - 1, (3 * n) // 4)]
    return median, max(0.0, q3 - q1)


def median_time(fn: Callable, *args, warmup: int = 1,
                iters: int = 3) -> float:
    """Median wall-clock seconds of a blocking call (see `timing_stats`;
    this is the stats' median alone, one counted measurement either way).
    """
    return timing_stats(fn, *args, warmup=warmup, iters=iters)[0]


# ---------------------------------------------------------------------------
# Reductions shared by the kernels (jnp, fused into the cached executables)
# ---------------------------------------------------------------------------

def pull_reduction(partials: jnp.ndarray, part_start_mode: jnp.ndarray,
                   out_dim: int) -> jnp.ndarray:
    """Merge per-partition Temp buffers (Alg. 4 lines 14-18)."""
    L, T, R = partials.shape
    rows = part_start_mode[:, None] + jnp.arange(T)[None, :]
    rows = jnp.minimum(rows, out_dim - 1)
    out = jnp.zeros((out_dim, R), partials.dtype)
    return out.at[rows].add(partials)


def segment_merge(partials: jnp.ndarray, rows: jnp.ndarray,
                  out_dim: int) -> jnp.ndarray:
    """Scatter per-block segment sums to global rows (boundary carry merge).

    ``partials`` is (n_blocks, block_m, R) from the oriented kernel; slot j
    of block b holds the sum of the block's j-th distinct-row run. The
    global row of that run is recovered from the sorted ``rows`` stream
    with the same run-rank prefix scan the kernel used. A row whose run
    spans a block boundary appears as the last segment of one block and
    the first of the next — both scatter to the same output row, which is
    exactly the carry merge ("atomics only at partition boundaries").
    Unused slots carry zero sums and scatter harmlessly to row 0.

    This is the shardable half of the oriented reduction: the scatter-add
    is associative and ``rows`` carries *global* row ids, so applying it to
    each device's contiguous slice of the sorted stream and ``psum``-ing
    the dense outputs yields exactly the single-device result — a run that
    spans a device boundary becomes one partial sum per device, merged by
    the psum the same way in-block boundary carries are merged here.
    `repro.dist.cpd` relies on this to shard CP-ALS/CP-APR row reductions.
    """
    nb, bm, R = partials.shape
    rows_b = rows.reshape(nb, bm)
    seg = _oriented.run_rank_segments(rows_b)              # (nb, bm)
    seg_rows = jnp.zeros((nb, bm), jnp.int32).at[
        jnp.arange(nb)[:, None], seg].set(rows_b)
    out = jnp.zeros((out_dim, R), partials.dtype)
    return out.at[seg_rows.reshape(-1)].add(partials.reshape(nb * bm, R))


def pad_sorted_stream(rows, words, values, mult: int, pi=None):
    """Pad the sorted stream to a multiple of ``mult`` elements.

    The single implementation of the padding rule the carry merge relies
    on (`mttkrp_oriented`'s block grid, `dist.cpd`'s shard cut, the
    `delinearize` wrapper's word-only stream): the final row/words are
    replicated (stream stays sorted, padding joins the final segment)
    with zero values, so padded elements contribute nothing to any
    reduction. ``rows``/``values``/``pi`` may each be None (padding is
    skipped for absent operands — `delinearize` pads words alone).
    An nnz=0 stream has no final row to replicate; it pads with zero
    rows/words instead (still sorted, still value-0), so degenerate
    tenant inputs flow through the same rule instead of crashing on the
    empty ``words[-1:]`` slice. Returns ``(rows, words, values, pi)``.
    """
    M = words.shape[0]
    # An empty stream pads up to one full block (0 is trivially a
    # multiple of mult, but a zero-length stream gives every downstream
    # block grid zero steps).
    pad = mult if M == 0 else (-M) % mult
    if pad == 0:
        return rows, words, values, pi
    if M == 0:
        pad_rows = (None if rows is None
                    else jnp.zeros((pad,), rows.dtype))
        pad_words = jnp.zeros((pad, words.shape[1]), words.dtype)
    else:
        pad_rows = (None if rows is None
                    else jnp.broadcast_to(rows[-1:], (pad,)))
        pad_words = jnp.broadcast_to(words[-1:], (pad, words.shape[1]))
    if rows is not None:
        rows = jnp.concatenate([rows, pad_rows])
    words = jnp.concatenate([words, pad_words])
    if values is not None:
        values = jnp.concatenate(
            [values, jnp.zeros((pad,), values.dtype)])
    if pi is not None:
        pi = jnp.concatenate([pi, jnp.zeros((pad, pi.shape[1]), pi.dtype)])
    return rows, words, values, pi


# ---------------------------------------------------------------------------
# Public kernel entry points
# ---------------------------------------------------------------------------

def delinearize(enc: AltoEncoding, words: jnp.ndarray,
                block_m: int = _delin.DEFAULT_BLOCK_M,
                interpret: bool | None = None) -> jnp.ndarray:
    """ALTO index words -> int32 coordinates (bit-scatter kernel).

    The word stream is padded to the block multiple through the shared
    `pad_sorted_stream` rule (replicated final element — the same rule
    every oriented kernel relies on) and the padded tail is sliced off
    the coordinate output, so the kernel always sees full blocks at the
    caller's requested ``block_m`` instead of silently shrinking it.
    """
    interp = _auto_interpret(interpret)

    def build():
        def run(words):
            _, padded, _, _ = pad_sorted_stream(None, words, None, block_m)
            coords = _delin.delinearize_pallas(enc, padded, block_m=block_m,
                                               interpret=interp)
            return coords[:words.shape[0]]
        return jax.jit(run)

    fn = _cached_executable(("delin", enc, block_m, interp), build)
    return fn(words)


def mttkrp(at: AltoTensor, factors, mode: int,
           r_block: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Recursive-traversal MTTKRP: Pallas partials kernel + pull reduction."""
    meta = at.meta
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    faults.inject("ops.exec")

    def build():
        def run(words, values, part_start, factors):
            partials = _mttkrp.mttkrp_partials_pallas(
                meta.enc, mode, meta.temp_rows[mode], words, values,
                part_start, factors, r_block=rb, interpret=interp)
            return pull_reduction(partials, part_start[:, mode],
                                  meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(("mttkrp_rec", meta, mode, rb, interp), build)
    return fn(at.words, at.values, at.part_start, list(factors))


def mttkrp_oriented(view: OrientedView, factors,
                    block_m: int = _oriented.DEFAULT_BLOCK_M,
                    r_block: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Output-oriented MTTKRP: Pallas segment kernel + boundary merge."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    faults.inject("ops.exec")

    def build():
        def run(rows, words, values, factors):
            rows, words, values, _ = pad_sorted_stream(rows, words, values,
                                                       block_m)
            partials = _oriented.mttkrp_oriented_partials_pallas(
                meta.enc, mode, rows, words, values, factors,
                block_m=block_m, r_block=rb, interpret=interp)
            return segment_merge(partials, rows, meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("mttkrp_ori", meta, mode, block_m, rb, interp), build)
    return fn(view.rows, view.words, view.values, list(factors))


def mttkrp_oriented_carry(view: OrientedView, factors,
                          block_m: int = _oriented.DEFAULT_BLOCK_M,
                          r_block: int | None = None,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Scratch-carry oriented MTTKRP: sequential Pallas scan, no merge.

    The kernel writes the final ``(I_n, R)`` rows directly (resident
    output tile + inter-block carry scratch), so this path materializes
    no ``(n_blocks, block_m, R)`` partials and runs no `segment_merge` —
    the carry-merge work happens inside the scan. Bit-identical to
    `mttkrp_oriented` at the same tiling.
    """
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    faults.inject("ops.exec")

    def build():
        def run(rows, words, values, factors):
            rows, words, values, _ = pad_sorted_stream(rows, words, values,
                                                       block_m)
            return _oriented.mttkrp_oriented_carry_pallas(
                meta.enc, mode, rows, words, values, factors,
                block_m=block_m, r_block=rb, interpret=interp)
        return jax.jit(run)

    fn = _cached_executable(
        ("mttkrp_carry", meta, mode, block_m, rb, interp), build)
    return fn(view.rows, view.words, view.values, list(factors))


def cpapr_phi(at: AltoTensor, B: jnp.ndarray, mode: int,
              factors=None, pi: jnp.ndarray | None = None,
              eps: float = 1e-10,
              interpret: bool | None = None) -> jnp.ndarray:
    """Recursive-traversal fused Φ: Pallas partials kernel + pull reduction."""
    meta = at.meta
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    faults.inject("ops.exec")

    def build():
        def run(words, values, part_start, B, factors, pi):
            partials = _phi.phi_partials_pallas(
                meta.enc, mode, meta.temp_rows[mode], eps, words, values,
                part_start, B, factors=factors, pi=pi, interpret=interp)
            return pull_reduction(partials, part_start[:, mode],
                                  meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_rec", meta, mode, eps, pre_pi, interp), build)
    return fn(at.words, at.values, at.part_start, B,
              list(factors) if factors is not None else None, pi)


def cpapr_phi_oriented(view: OrientedView, B: jnp.ndarray,
                       factors=None, pi: jnp.ndarray | None = None,
                       eps: float = 1e-10,
                       block_m: int = _oriented.DEFAULT_BLOCK_M,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Output-oriented fused Φ: Pallas segment kernel + boundary merge."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    faults.inject("ops.exec")

    def build():
        def run(rows, words, values, B, factors, pi):
            rows, words, values, pi = pad_sorted_stream(rows, words, values,
                                                        block_m, pi=pi)
            partials = _oriented.phi_oriented_partials_pallas(
                meta.enc, mode, eps, rows, words, values, B,
                factors=factors, pi=pi, block_m=block_m, interpret=interp)
            return segment_merge(partials, rows, meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_ori", meta, mode, eps, pre_pi, block_m, interp), build)
    return fn(view.rows, view.words, view.values, B,
              list(factors) if factors is not None else None, pi)


def cpapr_phi_oriented_carry(view: OrientedView, B: jnp.ndarray,
                             factors=None, pi: jnp.ndarray | None = None,
                             eps: float = 1e-10,
                             block_m: int = _oriented.DEFAULT_BLOCK_M,
                             interpret: bool | None = None) -> jnp.ndarray:
    """Scratch-carry fused Φ: sequential Pallas scan, no merge pass."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    faults.inject("ops.exec")

    def build():
        def run(rows, words, values, B, factors, pi):
            rows, words, values, pi = pad_sorted_stream(rows, words, values,
                                                        block_m, pi=pi)
            return _oriented.phi_oriented_carry_pallas(
                meta.enc, mode, eps, rows, words, values, B,
                factors=factors, pi=pi, block_m=block_m, interpret=interp)
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_carry", meta, mode, eps, pre_pi, block_m, interp), build)
    return fn(view.rows, view.words, view.values, B,
              list(factors) if factors is not None else None, pi)


# ---------------------------------------------------------------------------
# Out-of-core chunked executors (host stream -> device, cross-chunk carry)
# ---------------------------------------------------------------------------
#
# The host loop that drives the chunk kernels in `mttkrp_oriented`: a
# `core.stream.HostStream` is sliced at block_m-aligned chunk boundaries
# and each chunk flows through ONE cached per-chunk-shape jitted
# executable, threading (out, carry_row, carry_val) from chunk to chunk.
# Double buffering: the NEXT chunk's `device_put` is dispatched before the
# current chunk's compute (async on accelerator backends, so copy overlaps
# compute; on the CPU test host it is a plain copy — `docs/known-issues.md`
# carries the timing caveat). At most two chunk lengths exist per stream
# (the full chunk_m and one shorter tail), so the executable cache holds
# at most 2 entries per (meta, mode, tiling) — not one per chunk.

_CHUNK_STATS = {"chunks": 0, "prefetches": 0}


def chunk_stats() -> dict[str, int]:
    """Chunk-executor counters: chunks executed, prefetch puts issued.

    `tests/test_outofcore.py` uses the delta to pin "modeled chunk count
    == executed grid"; `bench_outofcore` reports overlap efficiency."""
    with _OPS_LOCK:
        return dict(_CHUNK_STATS)


def chunk_stats_clear() -> None:
    with _OPS_LOCK:
        for k in _CHUNK_STATS:
            _CHUNK_STATS[k] = 0


def _chunk_bounds(padded_len: int, chunk_m: int) -> list[tuple[int, int]]:
    """Chunk slice bounds over the padded stream (last may be shorter)."""
    return [(s, min(s + chunk_m, padded_len))
            for s in range(0, padded_len, chunk_m)]


def _bump(counter: str, n: int = 1) -> None:
    with _OPS_LOCK:
        _CHUNK_STATS[counter] += n


def mttkrp_oriented_chunked(view, factors, *, chunk_m: int,
                            block_m: int = _oriented.DEFAULT_BLOCK_M,
                            r_block: int | None = None,
                            interpret: bool | None = None) -> jnp.ndarray:
    """Out-of-core scratch-carry MTTKRP: host stream -> (I_n, R).

    ``view`` is a `core.stream.HostStream` (or an in-core `OrientedView`,
    adapted on the fly). Bitwise-identical to `mttkrp_oriented_carry` at
    equal tiling: chunk boundaries sit on block boundaries of the same
    padded stream and the open run rides the carry chain across them.
    """
    hs = _stream.ensure_host(view)
    meta, mode = hs.meta, hs.mode
    interp = _auto_interpret(interpret)
    R = factors[0].shape[1]
    rb = r_block or R
    if chunk_m % block_m:
        raise ValueError(f"chunk_m {chunk_m} not a multiple of "
                         f"block_m {block_m}")
    bounds = _chunk_bounds(hs.padded_len(block_m), chunk_m)
    I_n = meta.dims[mode]
    dtype = factors[0].dtype
    factors = [jnp.asarray(f) for f in factors]
    out = jnp.zeros((I_n, R), dtype)
    crow = jnp.full((1,), -1, jnp.int32)
    cval = jnp.zeros((1, R), dtype)

    nxt = _stream.put_chunk(hs, *bounds[0])
    for i, (s, e) in enumerate(bounds):
        faults.inject("ops.chunk_oom")
        cur = nxt
        if i + 1 < len(bounds):                # prefetch ahead of compute
            nxt = _stream.put_chunk(hs, *bounds[i + 1])
            _bump("prefetches")
        final = i == len(bounds) - 1

        def build(chunk_len=e - s, final=final):
            def run(rows, words, values, factors, out, crow, cval):
                return _oriented.mttkrp_oriented_carry_chunk_pallas(
                    meta.enc, mode, rows, words, values, factors,
                    out, crow, cval, block_m=block_m, r_block=rb,
                    final=final, interpret=interp)
            return jax.jit(run)

        fn = _cached_executable(
            ("mttkrp_chunk", meta, mode, e - s, block_m, rb, final, interp),
            build)
        out, crow, cval = fn(*cur, factors, out, crow, cval)
        _bump("chunks")
    return out


def mttkrp_oriented_chunked_reference(view, factors, *,
                                      chunk_m: int) -> jnp.ndarray:
    """Reference-backend chunked MTTKRP: per-chunk jnp scatter-add.

    Same host loop and `device_put` prefetch as the Pallas executor, but
    each chunk is a plain delinearize + Khatri-Rao + ``at[].add``. Not
    bitwise against the in-core reference `segment_sum` (different
    reduction association); agrees to float tolerance.
    """
    hs = _stream.ensure_host(view)
    meta, mode = hs.meta, hs.mode
    R = factors[0].shape[1]
    bounds = _chunk_bounds(hs.padded_len(1), chunk_m)
    dtype = factors[0].dtype
    factors = [jnp.asarray(f) for f in factors]
    out = jnp.zeros((meta.dims[mode], R), dtype)

    nxt = _stream.put_chunk(hs, *bounds[0])
    for i, (s, e) in enumerate(bounds):
        faults.inject("ops.chunk_oom")
        cur = nxt
        if i + 1 < len(bounds):
            nxt = _stream.put_chunk(hs, *bounds[i + 1])
            _bump("prefetches")

        def build(chunk_len=e - s):
            def run(rows, words, values, factors, out):
                coords = _delin_jnp(meta.enc, words)
                krp = _krp_rows(coords, factors, mode)
                return out.at[rows].add(values[:, None] * krp)
            return jax.jit(run)

        fn = _cached_executable(
            ("mttkrp_ref_chunk", meta, mode, e - s), build)
        out = fn(*cur, factors, out)
        _bump("chunks")
    return out


def cpapr_phi_oriented_chunked(view, B: jnp.ndarray, factors, *,
                               pre: bool, eps: float = 1e-10,
                               chunk_m: int,
                               block_m: int = _oriented.DEFAULT_BLOCK_M,
                               interpret: bool | None = None
                               ) -> jnp.ndarray:
    """Out-of-core scratch-carry fused Φ: host stream -> (I_n, R).

    Streaming takes ``factors`` under BOTH Π policies — a precomputed
    full-stream Π is exactly the O(nnz·R) array streaming exists to
    avoid. Under ``pre=True`` each chunk's Π rows are built on device
    inside the per-chunk executable and fed to the ALTO-PRE kernel
    (elementwise-identical to slicing a precomputed Π, so parity with
    the in-core PRE path stays bitwise for CP-APR's non-negative
    factors); ``pre=False`` is plain ALTO-OTF per chunk. The policy's
    cost meaning shifts under streaming: PRE's once-per-outer-iteration
    precompute becomes a per-chunk recompute (`docs/out-of-core.md`).
    """
    hs = _stream.ensure_host(view)
    meta, mode = hs.meta, hs.mode
    interp = _auto_interpret(interpret)
    if chunk_m % block_m:
        raise ValueError(f"chunk_m {chunk_m} not a multiple of "
                         f"block_m {block_m}")
    bounds = _chunk_bounds(hs.padded_len(block_m), chunk_m)
    I_n, R = B.shape
    B = jnp.asarray(B)
    factors = [jnp.asarray(f) for f in factors]
    out = jnp.zeros((I_n, R), B.dtype)
    crow = jnp.full((1,), -1, jnp.int32)
    cval = jnp.zeros((1, R), B.dtype)

    nxt = _stream.put_chunk(hs, *bounds[0])
    for i, (s, e) in enumerate(bounds):
        faults.inject("ops.chunk_oom")
        cur = nxt
        if i + 1 < len(bounds):
            nxt = _stream.put_chunk(hs, *bounds[i + 1])
            _bump("prefetches")
        final = i == len(bounds) - 1

        def build(chunk_len=e - s, final=final):
            def run(rows, words, values, B, factors, out, crow, cval):
                if pre:
                    coords = _delin_jnp(meta.enc, words)
                    pi = _krp_rows(coords, factors, mode)
                    return _oriented.phi_oriented_carry_chunk_pallas(
                        meta.enc, mode, eps, rows, words, values, B,
                        out, crow, cval, pi=pi, block_m=block_m,
                        final=final, interpret=interp)
                return _oriented.phi_oriented_carry_chunk_pallas(
                    meta.enc, mode, eps, rows, words, values, B,
                    out, crow, cval, factors=factors, block_m=block_m,
                    final=final, interpret=interp)
            return jax.jit(run)

        fn = _cached_executable(
            ("phi_chunk", meta, mode, eps, pre, e - s, block_m, final,
             interp), build)
        out, crow, cval = fn(*cur, B, factors, out, crow, cval)
        _bump("chunks")
    return out


def cpapr_phi_oriented_chunked_reference(view, B: jnp.ndarray, factors, *,
                                         pre: bool, eps: float = 1e-10,
                                         chunk_m: int) -> jnp.ndarray:
    """Reference-backend chunked Φ: per-chunk jnp row reduction.

    Tolerance-level (not bitwise) against the in-core reference path,
    like its MTTKRP sibling.
    """
    hs = _stream.ensure_host(view)
    meta, mode = hs.meta, hs.mode
    bounds = _chunk_bounds(hs.padded_len(1), chunk_m)
    I_n, R = B.shape
    B = jnp.asarray(B)
    factors = [jnp.asarray(f) for f in factors]
    out = jnp.zeros((I_n, R), B.dtype)

    nxt = _stream.put_chunk(hs, *bounds[0])
    for i, (s, e) in enumerate(bounds):
        faults.inject("ops.chunk_oom")
        cur = nxt
        if i + 1 < len(bounds):
            nxt = _stream.put_chunk(hs, *bounds[i + 1])
            _bump("prefetches")

        def build(chunk_len=e - s):
            def run(rows, words, values, B, factors, out):
                coords = _delin_jnp(meta.enc, words)
                krp = _krp_rows(coords, factors, mode)
                denom = jnp.maximum(jnp.sum(B[rows] * krp, axis=-1), eps)
                contrib = (values / denom)[:, None] * krp
                return out.at[rows].add(contrib)
            return jax.jit(run)

        fn = _cached_executable(
            ("phi_ref_chunk", meta, mode, eps, e - s), build)
        out = fn(*cur, B, factors, out)
        _bump("chunks")
    return out
