"""Jit'd public wrappers around the Pallas kernels, with executable caching.

Paper §4.2/§4.3 kernel entry points. Invariants: oriented entry points
consume a *row-sorted* stream (ascending target-mode row, `ops` pads it to
the block multiple with zero-valued copies of the last element); every
cache key is built from static, hashable metadata only (`AltoMeta`, mode,
tiling, interpret flag), never from traced values; `segment_merge` must
reproduce the kernels' run-rank segmentation bit-for-bit (both call
`mttkrp_oriented.run_rank_segments`) — that is the carry-merge correctness
condition.

On the CPU test host every kernel runs with interpret=True (the Pallas
interpreter traces the kernel body into regular XLA); on TPU the same call
sites compile to Mosaic. `interpret=None` auto-detects.

Every wrapper resolves to a **cached jitted executable** keyed on the
tensor's static metadata (`AltoMeta` is frozen/hashable) plus the static
kernel parameters (mode, block sizes, interpret flag). Before this cache
each call built a fresh closure and `jax.jit` object, so XLA re-traced and
re-compiled the kernel on *every* invocation — per sweep, per mode, per
iteration. Now the first call per (meta, mode, tiling) compiles once and
subsequent calls hit jit's C++ fast path.
"""
from __future__ import annotations

import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.alto import AltoTensor, OrientedView
from repro.core.encoding import AltoEncoding
from repro.kernels import cpapr_phi as _phi
from repro.kernels import delinearize as _delin
from repro.kernels import mttkrp as _mttkrp
from repro.kernels import mttkrp_oriented as _oriented


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Compiled-executable cache
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict[tuple, Callable] = {}
# One lock for every module-global mutated here (the executable cache and
# the timing counter below): concurrent autotuners / serving drivers were
# racing dict insertions and losing counter increments.
_OPS_LOCK = threading.Lock()


def _cached_executable(key: tuple, build: Callable[[], Callable]) -> Callable:
    """Return the jitted executable for ``key``, building it on first use.

    Thread-safe: the whole check-build-insert runs under the module lock.
    ``build`` only constructs the `jax.jit` wrapper (tracing/compilation
    happens lazily at the first call, outside the lock), so holding the
    lock across it is cheap and keeps the one-entry-per-key contract.
    """
    with _OPS_LOCK:
        fn = _EXEC_CACHE.get(key)
        if fn is None:
            fn = _EXEC_CACHE[key] = build()
        return fn


def cache_size() -> int:
    with _OPS_LOCK:
        return len(_EXEC_CACHE)


def cache_clear() -> None:
    with _OPS_LOCK:
        _EXEC_CACHE.clear()


# ---------------------------------------------------------------------------
# Timing hook (the autotuner's measurement primitive)
# ---------------------------------------------------------------------------

_TIMING_RUNS = 0


def timing_runs() -> int:
    """Number of `median_time` measurements taken in this process.

    `core.autotune` uses this to prove plan-store hits are measurement
    free: loading a persisted plan must leave the counter untouched.
    """
    with _OPS_LOCK:
        return _TIMING_RUNS


def median_time(fn: Callable, *args, warmup: int = 1,
                iters: int = 3) -> float:
    """Median wall-clock seconds of a blocking call, after warmup.

    The autotuner's timing hook on the cached executables: ``fn`` is one
    of the public wrappers above (or any callable ending in a jitted
    call), so the warmup runs absorb compilation + the executable-cache
    fill and the timed iterations hit jit's C++ fast path. Median of
    ``iters`` (not best-of) so one descheduled run cannot crown a wrong
    candidate on a noisy host.
    """
    global _TIMING_RUNS
    # Unsynchronized `+= 1` loses updates under concurrent autotuning,
    # which silently breaks the "store hits are measurement-free" proof
    # (a lost increment can mask a real measurement).
    with _OPS_LOCK:
        _TIMING_RUNS += 1
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


# ---------------------------------------------------------------------------
# Reductions shared by the kernels (jnp, fused into the cached executables)
# ---------------------------------------------------------------------------

def pull_reduction(partials: jnp.ndarray, part_start_mode: jnp.ndarray,
                   out_dim: int) -> jnp.ndarray:
    """Merge per-partition Temp buffers (Alg. 4 lines 14-18)."""
    L, T, R = partials.shape
    rows = part_start_mode[:, None] + jnp.arange(T)[None, :]
    rows = jnp.minimum(rows, out_dim - 1)
    out = jnp.zeros((out_dim, R), partials.dtype)
    return out.at[rows].add(partials)


def segment_merge(partials: jnp.ndarray, rows: jnp.ndarray,
                  out_dim: int) -> jnp.ndarray:
    """Scatter per-block segment sums to global rows (boundary carry merge).

    ``partials`` is (n_blocks, block_m, R) from the oriented kernel; slot j
    of block b holds the sum of the block's j-th distinct-row run. The
    global row of that run is recovered from the sorted ``rows`` stream
    with the same run-rank prefix scan the kernel used. A row whose run
    spans a block boundary appears as the last segment of one block and
    the first of the next — both scatter to the same output row, which is
    exactly the carry merge ("atomics only at partition boundaries").
    Unused slots carry zero sums and scatter harmlessly to row 0.

    This is the shardable half of the oriented reduction: the scatter-add
    is associative and ``rows`` carries *global* row ids, so applying it to
    each device's contiguous slice of the sorted stream and ``psum``-ing
    the dense outputs yields exactly the single-device result — a run that
    spans a device boundary becomes one partial sum per device, merged by
    the psum the same way in-block boundary carries are merged here.
    `repro.dist.cpd` relies on this to shard CP-ALS/CP-APR row reductions.
    """
    nb, bm, R = partials.shape
    rows_b = rows.reshape(nb, bm)
    seg = _oriented.run_rank_segments(rows_b)              # (nb, bm)
    seg_rows = jnp.zeros((nb, bm), jnp.int32).at[
        jnp.arange(nb)[:, None], seg].set(rows_b)
    out = jnp.zeros((out_dim, R), partials.dtype)
    return out.at[seg_rows.reshape(-1)].add(partials.reshape(nb * bm, R))


def pad_sorted_stream(rows, words, values, mult: int, pi=None):
    """Pad the sorted stream to a multiple of ``mult`` elements.

    The single implementation of the padding rule the carry merge relies
    on (`mttkrp_oriented`'s block grid, `dist.cpd`'s shard cut, the
    `delinearize` wrapper's word-only stream): the final row/words are
    replicated (stream stays sorted, padding joins the final segment)
    with zero values, so padded elements contribute nothing to any
    reduction. ``rows``/``values``/``pi`` may each be None (padding is
    skipped for absent operands — `delinearize` pads words alone).
    An nnz=0 stream has no final row to replicate; it pads with zero
    rows/words instead (still sorted, still value-0), so degenerate
    tenant inputs flow through the same rule instead of crashing on the
    empty ``words[-1:]`` slice. Returns ``(rows, words, values, pi)``.
    """
    M = words.shape[0]
    # An empty stream pads up to one full block (0 is trivially a
    # multiple of mult, but a zero-length stream gives every downstream
    # block grid zero steps).
    pad = mult if M == 0 else (-M) % mult
    if pad == 0:
        return rows, words, values, pi
    if M == 0:
        pad_rows = (None if rows is None
                    else jnp.zeros((pad,), rows.dtype))
        pad_words = jnp.zeros((pad, words.shape[1]), words.dtype)
    else:
        pad_rows = (None if rows is None
                    else jnp.broadcast_to(rows[-1:], (pad,)))
        pad_words = jnp.broadcast_to(words[-1:], (pad, words.shape[1]))
    if rows is not None:
        rows = jnp.concatenate([rows, pad_rows])
    words = jnp.concatenate([words, pad_words])
    if values is not None:
        values = jnp.concatenate(
            [values, jnp.zeros((pad,), values.dtype)])
    if pi is not None:
        pi = jnp.concatenate([pi, jnp.zeros((pad, pi.shape[1]), pi.dtype)])
    return rows, words, values, pi


# ---------------------------------------------------------------------------
# Public kernel entry points
# ---------------------------------------------------------------------------

def delinearize(enc: AltoEncoding, words: jnp.ndarray,
                block_m: int = _delin.DEFAULT_BLOCK_M,
                interpret: bool | None = None) -> jnp.ndarray:
    """ALTO index words -> int32 coordinates (bit-scatter kernel).

    The word stream is padded to the block multiple through the shared
    `pad_sorted_stream` rule (replicated final element — the same rule
    every oriented kernel relies on) and the padded tail is sliced off
    the coordinate output, so the kernel always sees full blocks at the
    caller's requested ``block_m`` instead of silently shrinking it.
    """
    interp = _auto_interpret(interpret)

    def build():
        def run(words):
            _, padded, _, _ = pad_sorted_stream(None, words, None, block_m)
            coords = _delin.delinearize_pallas(enc, padded, block_m=block_m,
                                               interpret=interp)
            return coords[:words.shape[0]]
        return jax.jit(run)

    fn = _cached_executable(("delin", enc, block_m, interp), build)
    return fn(words)


def mttkrp(at: AltoTensor, factors, mode: int,
           r_block: int | None = None,
           interpret: bool | None = None) -> jnp.ndarray:
    """Recursive-traversal MTTKRP: Pallas partials kernel + pull reduction."""
    meta = at.meta
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    def build():
        def run(words, values, part_start, factors):
            partials = _mttkrp.mttkrp_partials_pallas(
                meta.enc, mode, meta.temp_rows[mode], words, values,
                part_start, factors, r_block=rb, interpret=interp)
            return pull_reduction(partials, part_start[:, mode],
                                  meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(("mttkrp_rec", meta, mode, rb, interp), build)
    return fn(at.words, at.values, at.part_start, list(factors))


def mttkrp_oriented(view: OrientedView, factors,
                    block_m: int = _oriented.DEFAULT_BLOCK_M,
                    r_block: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Output-oriented MTTKRP: Pallas segment kernel + boundary merge."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    def build():
        def run(rows, words, values, factors):
            rows, words, values, _ = pad_sorted_stream(rows, words, values,
                                                       block_m)
            partials = _oriented.mttkrp_oriented_partials_pallas(
                meta.enc, mode, rows, words, values, factors,
                block_m=block_m, r_block=rb, interpret=interp)
            return segment_merge(partials, rows, meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("mttkrp_ori", meta, mode, block_m, rb, interp), build)
    return fn(view.rows, view.words, view.values, list(factors))


def mttkrp_oriented_carry(view: OrientedView, factors,
                          block_m: int = _oriented.DEFAULT_BLOCK_M,
                          r_block: int | None = None,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Scratch-carry oriented MTTKRP: sequential Pallas scan, no merge.

    The kernel writes the final ``(I_n, R)`` rows directly (resident
    output tile + inter-block carry scratch), so this path materializes
    no ``(n_blocks, block_m, R)`` partials and runs no `segment_merge` —
    the carry-merge work happens inside the scan. Bit-identical to
    `mttkrp_oriented` at the same tiling.
    """
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    rb = r_block or factors[mode].shape[1]

    def build():
        def run(rows, words, values, factors):
            rows, words, values, _ = pad_sorted_stream(rows, words, values,
                                                       block_m)
            return _oriented.mttkrp_oriented_carry_pallas(
                meta.enc, mode, rows, words, values, factors,
                block_m=block_m, r_block=rb, interpret=interp)
        return jax.jit(run)

    fn = _cached_executable(
        ("mttkrp_carry", meta, mode, block_m, rb, interp), build)
    return fn(view.rows, view.words, view.values, list(factors))


def cpapr_phi(at: AltoTensor, B: jnp.ndarray, mode: int,
              factors=None, pi: jnp.ndarray | None = None,
              eps: float = 1e-10,
              interpret: bool | None = None) -> jnp.ndarray:
    """Recursive-traversal fused Φ: Pallas partials kernel + pull reduction."""
    meta = at.meta
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    def build():
        def run(words, values, part_start, B, factors, pi):
            partials = _phi.phi_partials_pallas(
                meta.enc, mode, meta.temp_rows[mode], eps, words, values,
                part_start, B, factors=factors, pi=pi, interpret=interp)
            return pull_reduction(partials, part_start[:, mode],
                                  meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_rec", meta, mode, eps, pre_pi, interp), build)
    return fn(at.words, at.values, at.part_start, B,
              list(factors) if factors is not None else None, pi)


def cpapr_phi_oriented(view: OrientedView, B: jnp.ndarray,
                       factors=None, pi: jnp.ndarray | None = None,
                       eps: float = 1e-10,
                       block_m: int = _oriented.DEFAULT_BLOCK_M,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Output-oriented fused Φ: Pallas segment kernel + boundary merge."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    def build():
        def run(rows, words, values, B, factors, pi):
            rows, words, values, pi = pad_sorted_stream(rows, words, values,
                                                        block_m, pi=pi)
            partials = _oriented.phi_oriented_partials_pallas(
                meta.enc, mode, eps, rows, words, values, B,
                factors=factors, pi=pi, block_m=block_m, interpret=interp)
            return segment_merge(partials, rows, meta.dims[mode])
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_ori", meta, mode, eps, pre_pi, block_m, interp), build)
    return fn(view.rows, view.words, view.values, B,
              list(factors) if factors is not None else None, pi)


def cpapr_phi_oriented_carry(view: OrientedView, B: jnp.ndarray,
                             factors=None, pi: jnp.ndarray | None = None,
                             eps: float = 1e-10,
                             block_m: int = _oriented.DEFAULT_BLOCK_M,
                             interpret: bool | None = None) -> jnp.ndarray:
    """Scratch-carry fused Φ: sequential Pallas scan, no merge pass."""
    meta = view.meta
    mode = view.mode
    interp = _auto_interpret(interpret)
    pre_pi = pi is not None

    def build():
        def run(rows, words, values, B, factors, pi):
            rows, words, values, pi = pad_sorted_stream(rows, words, values,
                                                        block_m, pi=pi)
            return _oriented.phi_oriented_carry_pallas(
                meta.enc, mode, eps, rows, words, values, B,
                factors=factors, pi=pi, block_m=block_m, interpret=interp)
        return jax.jit(run)

    fn = _cached_executable(
        ("phi_carry", meta, mode, eps, pre_pi, block_m, interp), build)
    return fn(view.rows, view.words, view.values, B,
              list(factors) if factors is not None else None, pi)
