"""Pallas TPU kernel: output-oriented MTTKRP / Φ segment reduction.

The complement of the recursive one-hot-MXU kernel in `kernels/mttkrp.py`
(paper §4.2, Fig. 8 right): nonzeros arrive permuted into ascending order
of the target-mode row (`core.alto.oriented_view`), so conflict-free
updates become a *sorted segment reduction*. This mirrors the conflict-free
segment-reduction designs of ALTO (arXiv:2102.10245) and Dynasor
(arXiv:2309.09131), adapted to the TPU's no-atomics execution model:

  * the sorted row stream is cut into `block_m`-element blocks (one grid
    step each — a blocked scan over the sorted rows);
  * within a block, segment ids are the run-rank of each row
    (``cumsum(rows[i] != rows[i-1])``, a VPU prefix scan), and the segment
    sums are formed by ONE one-hot matmul on the MXU —
    ``onehot(seg).T @ contrib`` — exactly like the recursive kernel's Temp
    scatter but indexed by run rank instead of partition-interval offset,
    so the operand is (block_m, block_m) regardless of the mode length;
  * a row whose run crosses a block boundary yields one partial sum in
    each adjacent block; the boundary carry is merged outside the kernel
    by `ops.segment_merge`, which scatters every block's segment sums to
    their global rows (at most one shared row per boundary — the paper's
    "atomics only at partition boundaries", pull-based).

The Φ variant fuses the CP-APR model-update arithmetic (B-row gather,
denominator dot, Poisson elementwise update — paper Alg. 5) ahead of the
same segment reduction, for both Π policies (ALTO-PRE / ALTO-OTF).

VMEM per grid step (f32): block_m·(W + 2 + 2·r_block + block_m) +
Σ_{m≠mode} I_m·r_block words — `core.plan.choose_block_m` sizes block_m so
this fits the 16 MB budget (divided by the shard count for mesh-bearing
plans, see `core.plan`).

**Scratch-carry variant** (`*_carry_pallas`, paper §4.2's output-oriented
reduction taken to its conclusion): the one-hot kernel above pays an
O(block_m²) MXU matmul per block and materializes `(n_blocks, block_m, R)`
partials to HBM that `ops.segment_merge` immediately re-scatters — an
intermediate 10-100× larger than the final `(I_n, R)` output. ALTO
(arXiv:2102.10245) and Dynasor (arXiv:2309.09131) instead carry partial
sums *through* the sorted-stream scan. The carry kernels do exactly that
on a **sequential 1-D block grid**:

  * in-block segment sums come from a VPU scatter-add over the run-rank
    ids (`zeros.at[seg].add(contrib)`) — no (block_m, block_m) one-hot;
  * the `(I_n, r_block)` output tile stays VMEM-resident across the whole
    scan (constant out index_map; `input_output_aliases` seeds it from a
    zero buffer), and every *closed* run's total is scattered straight
    into it — no partials buffer, no host-side merge pass;
  * the block's final run is *open* (it may continue into the next
    block): its partial sum rides a `(1, r_block)` VMEM scratch plus an
    SMEM row id to the next grid step, where it either merges into the
    first run or is flushed. Boundary carries therefore survive only at
    *shard* boundaries, merged by the existing psum path in `dist.cpd`.

Carry-vs-one-hot parity is bit-exact: within-block sums accumulate in the
same element order, and the carry chain re-associates cross-block partials
only by IEEE-commutative swaps (x+y == y+x bitwise), which
`tests/test_oriented_carry.py` pins on adversarial run layouts.

Invariants: the input stream is row-sorted with length an exact multiple
of block_m (callers pad — `ops` / `dist.cpd`); row ids are global, and the
carry-merge correctness condition is that `ops.segment_merge` reproduces
`run_rank_segments` bit-for-bit — which also makes the per-block partials
safe to compute on shard-local slices and combine by psum
(`repro.dist.cpd`); all tiling comes from static, hashable plan metadata.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.encoding import AltoEncoding
from repro.kernels.mttkrp import _decode

DEFAULT_BLOCK_M = 256


def run_rank_segments(rows):
    """Run-rank segment ids along the last axis of a sorted row array.

    Shared between the kernels and `ops.segment_merge`: the merge's
    scatter map must reproduce this segmentation bit-for-bit, so there is
    exactly one implementation.
    """
    block_m = rows.shape[-1]
    idx = jax.lax.iota(jnp.int32, block_m)
    prev = jnp.roll(rows, 1, axis=-1)
    is_new = jnp.where(idx == 0, 0, (rows != prev).astype(jnp.int32))
    return jnp.cumsum(is_new, axis=-1)


def _block_segments(rows):
    """Kernel-side: segment ids + lane iota of a (block_m,) row vector."""
    return run_rank_segments(rows), jax.lax.iota(jnp.int32, rows.shape[0])


def _segment_matmul(seg, idx, contrib):
    """Per-segment sums via one one-hot matmul: (block_m, r_block)."""
    onehot = (seg[:, None] == idx[None, :]).astype(contrib.dtype)
    return jax.lax.dot_general(
        onehot, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(contrib.dtype)


def _mttkrp_oriented_kernel(enc: AltoEncoding, mode: int,
                            rows_ref, words_ref, vals_ref, *refs):
    """Grid step: one (nonzero block, rank tile) -> in-block segment sums."""
    factor_refs = refs[:-1]
    out_ref = refs[-1]
    rows = rows_ref[...]                      # (block_m,) ascending
    words = words_ref[...]                    # (block_m, W)
    vals = vals_ref[...]                      # (block_m,)
    coords = _decode(enc, words)              # N × (block_m,)

    krp = None                                # Khatri-Rao rows (block_m, rb)
    fi = 0
    for m in range(enc.ndim):
        if m == mode:
            continue
        gathered = jnp.take(factor_refs[fi][...], coords[m], axis=0)
        krp = gathered if krp is None else krp * gathered
        fi += 1
    contrib = vals[:, None] * krp             # (block_m, rb)

    seg, idx = _block_segments(rows)
    out_ref[0] = _segment_matmul(seg, idx, contrib)


def mttkrp_oriented_partials_pallas(enc: AltoEncoding, mode: int,
                                    rows: jnp.ndarray, words: jnp.ndarray,
                                    values: jnp.ndarray, factors,
                                    block_m: int = DEFAULT_BLOCK_M,
                                    r_block: int | None = None,
                                    interpret: bool = True) -> jnp.ndarray:
    """Per-block segment sums: (n_blocks, block_m, R).

    ``rows``/``words``/``values`` must be in oriented (row-sorted) order
    with length a multiple of ``block_m`` (ops pads). Segment slot j of
    block b holds the sum of the j-th distinct-row run inside that block;
    `ops.segment_merge` scatters the slots to global rows and thereby
    merges boundary carries.
    """
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"nnz {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    R = factors[0].shape[1]
    rb = r_block or R
    if R % rb:
        raise ValueError(f"rank {R} not a multiple of r_block {rb}")
    others = [f for m, f in enumerate(factors) if m != mode]

    in_specs = [
        pl.BlockSpec((block_m,), lambda b, r: (b,)),           # rows
        pl.BlockSpec((block_m, W), lambda b, r: (b, 0)),       # words
        pl.BlockSpec((block_m,), lambda b, r: (b,)),           # values
    ] + [
        pl.BlockSpec((f.shape[0], rb), lambda b, r: (0, r)) for f in others
    ]
    return pl.pallas_call(
        functools.partial(_mttkrp_oriented_kernel, enc, mode),
        grid=(n_blocks, R // rb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_m, rb), lambda b, r: (b, 0, r)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_m, R),
                                       factors[0].dtype),
        interpret=interpret,
    )(rows, words, values, *others)


def _phi_oriented_kernel(enc: AltoEncoding, mode: int, eps: float,
                         pre_pi: bool,
                         rows_ref, words_ref, vals_ref, b_ref, *refs):
    """Grid step: fused Φ update + in-block segment sums (full rank)."""
    out_ref = refs[-1]
    rows = rows_ref[...]
    vals = vals_ref[...]

    if pre_pi:
        krp = refs[0][...]                    # Π rows (block_m, R)
    else:
        # OTF only: the index decode is dead work under ALTO-PRE.
        coords = _decode(enc, words_ref[...])
        krp = None
        fi = 0
        for m in range(enc.ndim):
            if m == mode:
                continue
            gathered = jnp.take(refs[fi][...], coords[m], axis=0)
            krp = gathered if krp is None else krp * gathered
            fi += 1

    b_rows = jnp.take(b_ref[...], rows, axis=0)        # (block_m, R)
    denom = jnp.maximum(jnp.sum(b_rows * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp

    seg, idx = _block_segments(rows)
    out_ref[0] = _segment_matmul(seg, idx, contrib)


def phi_oriented_partials_pallas(enc: AltoEncoding, mode: int, eps: float,
                                 rows: jnp.ndarray, words: jnp.ndarray,
                                 values: jnp.ndarray, B: jnp.ndarray,
                                 factors=None, pi: jnp.ndarray | None = None,
                                 block_m: int = DEFAULT_BLOCK_M,
                                 interpret: bool = True) -> jnp.ndarray:
    """Per-block Φ segment sums: (n_blocks, block_m, R).

    Pass ``pi`` (oriented-order Khatri-Rao rows) for ALTO-PRE or
    ``factors`` for ALTO-OTF (exactly one). No rank tiling — the
    denominator ``<B[i_n,:], krp>`` needs the full rank per element.
    """
    pre_pi = pi is not None
    if pre_pi == (factors is not None):
        raise ValueError("pass exactly one of pi= / factors=")
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"nnz {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    R = B.shape[1]

    in_specs = [
        pl.BlockSpec((block_m,), lambda b: (b,)),              # rows
        pl.BlockSpec((block_m, W), lambda b: (b, 0)),          # words
        pl.BlockSpec((block_m,), lambda b: (b,)),              # values
        pl.BlockSpec(B.shape, lambda b: (0, 0)),               # B
    ]
    args = [rows, words, values, B]
    if pre_pi:
        in_specs.append(pl.BlockSpec((block_m, R), lambda b: (b, 0)))
        args.append(pi)
    else:
        others = [f for m, f in enumerate(factors) if m != mode]
        in_specs += [pl.BlockSpec(f.shape, lambda b: (0, 0)) for f in others]
        args += others

    return pl.pallas_call(
        functools.partial(_phi_oriented_kernel, enc, mode, eps, pre_pi),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_m, R), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_m, R), B.dtype),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Scratch-carry sequential-grid variant (no partials buffer, no host merge)
# ---------------------------------------------------------------------------

def _carry_step(b, n_blocks, rows, contrib, out_ref, crow_ref, cval_ref,
                carry_in=None, final=True, carry_out=None):
    """One grid step of the scratch-carry scan, shared by MTTKRP and Φ.

    ``b`` is the position along the sequential block axis. In-block
    segment sums are formed by a scatter-add over the run-rank ids (the
    accumulation visits elements in stream order, matching the one-hot
    matmul bit-for-bit); closed runs land in the resident ``out_ref``
    block, the open final run replaces the carry scratch. The carry from
    the previous step either merges into this block's first run (same
    row) or is flushed — commutative re-association only, so the chain
    reproduces `ops.segment_merge`'s block-ordered adds bitwise.

    Out-of-core extension (`core.plan` streaming): the scan can start
    and stop mid-stream. ``carry_in`` is ``None`` for a fresh scan
    (empty carry: row −1, zero value) or ``(row_ref, val_ref)`` holding
    the open run handed in from the previous chunk; ``final`` is
    statically False for non-final chunks, which suppresses the
    stream-closing flush — the last block's open run exits through
    ``carry_out`` ``(row_ref, val_ref)`` instead. A non-final last block
    scatters the same masked zero to row 0 the in-core kernel's
    non-last blocks do, so the chunked op sequence is identical
    add-for-add to the in-core scan and parity stays bitwise.
    """
    block_m = rows.shape[0]

    @pl.when(b == 0)
    def _():
        if carry_in is None:                   # fresh scan: empty carry
            crow_ref[0] = -1
            cval_ref[...] = jnp.zeros(cval_ref.shape, cval_ref.dtype)
        else:                                  # resume the previous chunk
            crow_ref[0] = carry_in[0][0]
            cval_ref[...] = carry_in[1][...]

    prev_row = crow_ref[0]
    prev_val = cval_ref[0]

    seg, idx = _block_segments(rows)
    seg_sums = jnp.zeros(contrib.shape, contrib.dtype).at[seg].add(contrib)
    seg_rows = jnp.zeros((block_m,), jnp.int32).at[seg].set(rows)
    n_segs = seg[block_m - 1] + 1

    zero = jnp.zeros_like(prev_val)
    merge = prev_row == rows[0]                # open run continues here
    seg_sums = seg_sums.at[0].add(jnp.where(merge, prev_val, zero))
    flush = jnp.logical_and(prev_row >= 0, jnp.logical_not(merge))
    flush_row = jnp.where(flush, prev_row, 0)
    flush_val = jnp.where(flush, prev_val, zero)

    new_val = jax.lax.dynamic_index_in_dim(seg_sums, n_segs - 1, 0,
                                           keepdims=False)
    if final:
        last = b == n_blocks - 1
        fin_row = jnp.where(last, rows[block_m - 1], 0)  # close the stream
        fin_val = jnp.where(last, new_val, zero)
    else:
        # The stream continues into the next chunk: every block behaves
        # like an in-core non-last block (masked zero to row 0).
        fin_row = jnp.zeros((), jnp.int32)
        fin_val = zero

    # Closed runs + (up to) two carry flushes, one combined scatter-add
    # into the resident output; masked slots add 0.0 to row 0, harmless.
    closed = idx < n_segs - 1
    srows = jnp.concatenate([jnp.where(closed, seg_rows, 0),
                             flush_row[None], fin_row[None]])
    svals = jnp.concatenate(
        [jnp.where(closed[:, None], seg_sums, jnp.zeros_like(seg_sums)),
         flush_val[None], fin_val[None]])
    out_ref[...] = out_ref[...].at[srows].add(svals)

    crow_ref[0] = rows[block_m - 1]
    cval_ref[0] = new_val
    if carry_out is not None:
        carry_out[0][0] = rows[block_m - 1]
        carry_out[1][0] = new_val


def _mttkrp_carry_kernel(enc: AltoEncoding, mode: int,
                         rows_ref, words_ref, vals_ref, *refs):
    """Grid step: (rank tile r, sorted block b) -> resident (I_n, rb)."""
    factor_refs = refs[:-4]
    out_ref, crow_ref, cval_ref = refs[-3], refs[-2], refs[-1]
    # refs[-4] is the zero init buffer aliased onto out_ref — never read.
    rows = rows_ref[...]
    words = words_ref[...]
    vals = vals_ref[...]
    coords = _decode(enc, words)

    krp = None
    fi = 0
    for m in range(enc.ndim):
        if m == mode:
            continue
        gathered = jnp.take(factor_refs[fi][...], coords[m], axis=0)
        krp = gathered if krp is None else krp * gathered
        fi += 1
    contrib = vals[:, None] * krp              # (block_m, rb)

    _carry_step(pl.program_id(1), pl.num_programs(1), rows, contrib,
                out_ref, crow_ref, cval_ref)


def mttkrp_oriented_carry_pallas(enc: AltoEncoding, mode: int,
                                 rows: jnp.ndarray, words: jnp.ndarray,
                                 values: jnp.ndarray, factors,
                                 block_m: int = DEFAULT_BLOCK_M,
                                 r_block: int | None = None,
                                 interpret: bool = True) -> jnp.ndarray:
    """Scratch-carry oriented MTTKRP: sorted stream -> (I_n, R) directly.

    Same input contract as `mttkrp_oriented_partials_pallas`, but the
    result is the final row-reduced MTTKRP — there is no partials buffer
    and callers must NOT run `ops.segment_merge` on this path. The grid
    is (rank tiles, blocks) with the block axis innermost, so each rank
    tile is one sequential scan and the carry scratch resets at its
    first step.
    """
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"nnz {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    R = factors[0].shape[1]
    rb = r_block or R
    if R % rb:
        raise ValueError(f"rank {R} not a multiple of r_block {rb}")
    I_n = enc.dims[mode]
    dtype = factors[0].dtype
    others = [f for m, f in enumerate(factors) if m != mode]

    in_specs = [
        pl.BlockSpec((block_m,), lambda r, b: (b,)),           # rows
        pl.BlockSpec((block_m, W), lambda r, b: (b, 0)),       # words
        pl.BlockSpec((block_m,), lambda r, b: (b,)),           # values
    ] + [
        pl.BlockSpec((f.shape[0], rb), lambda r, b: (0, r)) for f in others
    ] + [
        pl.BlockSpec((I_n, rb), lambda r, b: (0, r)),          # zero init
    ]
    return pl.pallas_call(
        functools.partial(_mttkrp_carry_kernel, enc, mode),
        grid=(R // rb, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((I_n, rb), lambda r, b: (0, r)),
        out_shape=jax.ShapeDtypeStruct((I_n, R), dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, rb), dtype)],
        input_output_aliases={3 + len(others): 0},
        interpret=interpret,
    )(rows, words, values, *others, jnp.zeros((I_n, R), dtype))


def _phi_carry_kernel(enc: AltoEncoding, mode: int, eps: float,
                      pre_pi: bool,
                      rows_ref, words_ref, vals_ref, b_ref, *refs):
    """Grid step: fused Φ update + carry scan, full rank, resident out."""
    out_ref, crow_ref, cval_ref = refs[-3], refs[-2], refs[-1]
    operand_refs = refs[:-4]                   # Π tile or other factors
    rows = rows_ref[...]
    vals = vals_ref[...]

    if pre_pi:
        krp = operand_refs[0][...]             # Π rows (block_m, R)
    else:
        coords = _decode(enc, words_ref[...])
        krp = None
        fi = 0
        for m in range(enc.ndim):
            if m == mode:
                continue
            gathered = jnp.take(operand_refs[fi][...], coords[m], axis=0)
            krp = gathered if krp is None else krp * gathered
            fi += 1

    b_rows = jnp.take(b_ref[...], rows, axis=0)        # (block_m, R)
    denom = jnp.maximum(jnp.sum(b_rows * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp

    _carry_step(pl.program_id(0), pl.num_programs(0), rows, contrib,
                out_ref, crow_ref, cval_ref)


def phi_oriented_carry_pallas(enc: AltoEncoding, mode: int, eps: float,
                              rows: jnp.ndarray, words: jnp.ndarray,
                              values: jnp.ndarray, B: jnp.ndarray,
                              factors=None, pi: jnp.ndarray | None = None,
                              block_m: int = DEFAULT_BLOCK_M,
                              interpret: bool = True) -> jnp.ndarray:
    """Scratch-carry fused Φ: sorted stream -> (I_n, R) directly.

    Same operand contract as `phi_oriented_partials_pallas` (pass exactly
    one of ``pi``/``factors``; no rank tiling — the denominator needs the
    full rank), but the result is the final row-reduced Φ with no
    partials buffer and no merge pass.
    """
    pre_pi = pi is not None
    if pre_pi == (factors is not None):
        raise ValueError("pass exactly one of pi= / factors=")
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"nnz {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    I_n, R = B.shape

    in_specs = [
        pl.BlockSpec((block_m,), lambda b: (b,)),              # rows
        pl.BlockSpec((block_m, W), lambda b: (b, 0)),          # words
        pl.BlockSpec((block_m,), lambda b: (b,)),              # values
        pl.BlockSpec(B.shape, lambda b: (0, 0)),               # B resident
    ]
    args = [rows, words, values, B]
    if pre_pi:
        in_specs.append(pl.BlockSpec((block_m, R), lambda b: (b, 0)))
        args.append(pi)
    else:
        others = [f for m, f in enumerate(factors) if m != mode]
        in_specs += [pl.BlockSpec(f.shape, lambda b: (0, 0)) for f in others]
        args += others
    init_idx = len(args)
    in_specs.append(pl.BlockSpec((I_n, R), lambda b: (0, 0)))  # zero init
    args.append(jnp.zeros((I_n, R), B.dtype))

    return pl.pallas_call(
        functools.partial(_phi_carry_kernel, enc, mode, eps, pre_pi),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((I_n, R), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((I_n, R), B.dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, R), B.dtype)],
        input_output_aliases={init_idx: 0},
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Out-of-core chunk kernels: the carry scan sliced mid-stream
# ---------------------------------------------------------------------------
#
# One chunk = a block_m-multiple slice of the padded sorted stream. The
# kernel is the carry scan above with three contract changes (all through
# `_carry_step`'s carry_in/final/carry_out hooks):
#
#   * the output accumulator arrives as an INPUT (`out_init`, aliased onto
#     the output) holding the previous chunks' accumulation — chunk 0 gets
#     zeros, later chunks get the running (I_n, R);
#   * the carry scratch is seeded from the previous chunk's carry-out
#     (row −1 + zeros for chunk 0) instead of reset at b == 0;
#   * a non-final chunk suppresses the stream-closing flush and emits its
#     open run as (cout_row, cout_val) outputs for the next chunk.
#
# Because chunk boundaries sit on block boundaries of the SAME padded
# stream, every block performs the identical combined scatter-add in the
# identical order — chunked-vs-in-core parity is bitwise, not approximate
# (`tests/test_outofcore.py` pins it on adversarial run layouts).

def _mttkrp_carry_chunk_kernel(enc: AltoEncoding, mode: int, final: bool,
                               rows_ref, words_ref, vals_ref,
                               cin_row_ref, cin_val_ref, *refs):
    """Grid step: (rank tile r, chunk block b) -> resident (I_n, rb)."""
    factor_refs = refs[:-6]
    out_ref = refs[-5]
    cout_row_ref, cout_val_ref = refs[-4], refs[-3]
    crow_ref, cval_ref = refs[-2], refs[-1]
    # refs[-6] is the out accumulator aliased onto out_ref — never read.
    rows = rows_ref[...]
    words = words_ref[...]
    vals = vals_ref[...]
    coords = _decode(enc, words)

    krp = None
    fi = 0
    for m in range(enc.ndim):
        if m == mode:
            continue
        gathered = jnp.take(factor_refs[fi][...], coords[m], axis=0)
        krp = gathered if krp is None else krp * gathered
        fi += 1
    contrib = vals[:, None] * krp              # (block_m, rb)

    _carry_step(pl.program_id(1), pl.num_programs(1), rows, contrib,
                out_ref, crow_ref, cval_ref,
                carry_in=(cin_row_ref, cin_val_ref), final=final,
                carry_out=(cout_row_ref, cout_val_ref))


def mttkrp_oriented_carry_chunk_pallas(enc: AltoEncoding, mode: int,
                                       rows: jnp.ndarray,
                                       words: jnp.ndarray,
                                       values: jnp.ndarray, factors,
                                       out: jnp.ndarray,
                                       carry_row: jnp.ndarray,
                                       carry_val: jnp.ndarray,
                                       block_m: int = DEFAULT_BLOCK_M,
                                       r_block: int | None = None,
                                       final: bool = True,
                                       interpret: bool = True):
    """One chunk of the scratch-carry MTTKRP scan.

    ``rows/words/values`` are one block_m-multiple slice of the padded
    sorted stream; ``out`` is the running (I_n, R) accumulator (zeros
    for the first chunk); ``carry_row``/``carry_val`` — shapes (1,)
    int32 / (1, R) — are the previous chunk's open run (row −1 + zeros
    for the first). ``final`` statically marks the stream's last chunk
    (only there does the open run flush into ``out``). Returns the
    updated ``(out, carry_row, carry_val)``.
    """
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"chunk {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    R = factors[0].shape[1]
    rb = r_block or R
    if R % rb:
        raise ValueError(f"rank {R} not a multiple of r_block {rb}")
    I_n = enc.dims[mode]
    dtype = factors[0].dtype
    others = [f for m, f in enumerate(factors) if m != mode]

    in_specs = [
        pl.BlockSpec((block_m,), lambda r, b: (b,)),           # rows
        pl.BlockSpec((block_m, W), lambda r, b: (b, 0)),       # words
        pl.BlockSpec((block_m,), lambda r, b: (b,)),           # values
        pl.BlockSpec((1,), lambda r, b: (0,)),                 # carry row in
        pl.BlockSpec((1, rb), lambda r, b: (0, r)),            # carry val in
    ] + [
        pl.BlockSpec((f.shape[0], rb), lambda r, b: (0, r)) for f in others
    ] + [
        pl.BlockSpec((I_n, rb), lambda r, b: (0, r)),          # out accum in
    ]
    return pl.pallas_call(
        functools.partial(_mttkrp_carry_chunk_kernel, enc, mode, final),
        grid=(R // rb, n_blocks),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((I_n, rb), lambda r, b: (0, r)),
                   pl.BlockSpec((1,), lambda r, b: (0,)),
                   pl.BlockSpec((1, rb), lambda r, b: (0, r))],
        out_shape=[jax.ShapeDtypeStruct((I_n, R), dtype),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1, R), dtype)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, rb), dtype)],
        input_output_aliases={5 + len(others): 0},
        interpret=interpret,
    )(rows, words, values, carry_row, carry_val, *others, out)


def _phi_carry_chunk_kernel(enc: AltoEncoding, mode: int, eps: float,
                            pre_pi: bool, final: bool,
                            rows_ref, words_ref, vals_ref, b_ref,
                            cin_row_ref, cin_val_ref, *refs):
    """Grid step: fused Φ + chunked carry scan, full rank."""
    operand_refs = refs[:-6]                   # Π tile or other factors
    out_ref = refs[-5]
    cout_row_ref, cout_val_ref = refs[-4], refs[-3]
    crow_ref, cval_ref = refs[-2], refs[-1]
    rows = rows_ref[...]
    vals = vals_ref[...]

    if pre_pi:
        krp = operand_refs[0][...]             # Π rows (block_m, R)
    else:
        coords = _decode(enc, words_ref[...])
        krp = None
        fi = 0
        for m in range(enc.ndim):
            if m == mode:
                continue
            gathered = jnp.take(operand_refs[fi][...], coords[m], axis=0)
            krp = gathered if krp is None else krp * gathered
            fi += 1

    b_rows = jnp.take(b_ref[...], rows, axis=0)        # (block_m, R)
    denom = jnp.maximum(jnp.sum(b_rows * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp

    _carry_step(pl.program_id(0), pl.num_programs(0), rows, contrib,
                out_ref, crow_ref, cval_ref,
                carry_in=(cin_row_ref, cin_val_ref), final=final,
                carry_out=(cout_row_ref, cout_val_ref))


def phi_oriented_carry_chunk_pallas(enc: AltoEncoding, mode: int,
                                    eps: float,
                                    rows: jnp.ndarray, words: jnp.ndarray,
                                    values: jnp.ndarray, B: jnp.ndarray,
                                    out: jnp.ndarray,
                                    carry_row: jnp.ndarray,
                                    carry_val: jnp.ndarray,
                                    factors=None,
                                    pi: jnp.ndarray | None = None,
                                    block_m: int = DEFAULT_BLOCK_M,
                                    final: bool = True,
                                    interpret: bool = True):
    """One chunk of the scratch-carry fused Φ scan (full rank).

    Operand contract as `phi_oriented_carry_pallas` (exactly one of
    ``pi``/``factors``; under ALTO-PRE ``pi`` holds THIS CHUNK's Π rows);
    chunk contract as `mttkrp_oriented_carry_chunk_pallas`. Returns the
    updated ``(out, carry_row, carry_val)``.
    """
    pre_pi = pi is not None
    if pre_pi == (factors is not None):
        raise ValueError("pass exactly one of pi= / factors=")
    M, W = words.shape
    if M % block_m:
        raise ValueError(f"chunk {M} not a multiple of block_m {block_m}")
    n_blocks = M // block_m
    I_n, R = B.shape

    in_specs = [
        pl.BlockSpec((block_m,), lambda b: (b,)),              # rows
        pl.BlockSpec((block_m, W), lambda b: (b, 0)),          # words
        pl.BlockSpec((block_m,), lambda b: (b,)),              # values
        pl.BlockSpec(B.shape, lambda b: (0, 0)),               # B resident
        pl.BlockSpec((1,), lambda b: (0,)),                    # carry row in
        pl.BlockSpec((1, R), lambda b: (0, 0)),                # carry val in
    ]
    args = [rows, words, values, B, carry_row, carry_val]
    if pre_pi:
        in_specs.append(pl.BlockSpec((block_m, R), lambda b: (b, 0)))
        args.append(pi)
    else:
        others = [f for m, f in enumerate(factors) if m != mode]
        in_specs += [pl.BlockSpec(f.shape, lambda b: (0, 0)) for f in others]
        args += others
    init_idx = len(args)
    in_specs.append(pl.BlockSpec((I_n, R), lambda b: (0, 0)))  # out accum
    args.append(out)

    return pl.pallas_call(
        functools.partial(_phi_carry_chunk_kernel, enc, mode, eps, pre_pi,
                          final),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((I_n, R), lambda b: (0, 0)),
                   pl.BlockSpec((1,), lambda b: (0,)),
                   pl.BlockSpec((1, R), lambda b: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((I_n, R), B.dtype),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((1, R), B.dtype)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((1, R), B.dtype)],
        input_output_aliases={init_idx: 0},
        interpret=interpret,
    )(*args)
