"""Pallas TPU kernel: fused CP-APR Φ model update (paper Alg. 5).

Per grid step (one balanced ALTO partition) the kernel fuses, entirely in
VMEM: delinearization → Khatri-Rao row formation (ALTO-OTF) or Π row load
(ALTO-PRE) → B-row gather → denominator dot → elementwise Poisson update →
one-hot-matmul scatter into the partition Temp. This is the kernel the
paper reports >99% of CP-APR time in (§5.3); fusing it removes the (M, R)
intermediate round-trips to HBM that dominate the CPU profile.

No rank tiling here: the denominator ``<B[i_n,:], krp>`` needs the full rank
per element, and R is small in CPD workloads (paper uses R=16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.encoding import AltoEncoding
from repro.kernels.mttkrp import _decode


def _phi_partial_kernel(enc: AltoEncoding, mode: int, temp_rows: int,
                        eps: float, pre_pi: bool,
                        words_ref, vals_ref, start_ref, b_ref, *refs):
    out_ref = refs[-1]
    words = words_ref[...]
    vals = vals_ref[...]
    coords = _decode(enc, words)

    if pre_pi:
        krp = refs[0][...]                       # Π rows (chunk, R)
    else:
        krp = None
        fi = 0
        for m in range(enc.ndim):
            if m == mode:
                continue
            rows = jnp.take(refs[fi][...], coords[m], axis=0)
            krp = rows if krp is None else krp * rows
            fi += 1

    b_rows = jnp.take(b_ref[...], coords[mode], axis=0)   # (chunk, R)
    denom = jnp.maximum(jnp.sum(b_rows * krp, axis=-1), eps)
    contrib = (vals / denom)[:, None] * krp

    local = coords[mode] - start_ref[0, mode]
    onehot = (local[:, None] == jax.lax.iota(jnp.int32, temp_rows)[None, :]
              ).astype(contrib.dtype)
    out_ref[0] = jax.lax.dot_general(
        onehot, contrib, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def phi_partials_pallas(enc: AltoEncoding, mode: int, temp_rows: int,
                        eps: float, words: jnp.ndarray, values: jnp.ndarray,
                        part_start: jnp.ndarray, B: jnp.ndarray,
                        factors=None, pi: jnp.ndarray | None = None,
                        interpret: bool = True) -> jnp.ndarray:
    """Per-partition Φ partials: (L, temp_rows, R).

    Pass ``pi`` for ALTO-PRE or ``factors`` for ALTO-OTF (exactly one).
    """
    pre_pi = pi is not None
    if pre_pi == (factors is not None):
        raise ValueError("pass exactly one of pi= / factors=")
    L = part_start.shape[0]
    Mp, W = words.shape
    chunk = Mp // L
    R = B.shape[1]
    N = len(part_start[0]) if hasattr(part_start, "__len__") else None
    N = part_start.shape[1]

    in_specs = [
        pl.BlockSpec((chunk, W), lambda l: (l, 0)),
        pl.BlockSpec((chunk,), lambda l: (l,)),
        pl.BlockSpec((1, N), lambda l: (l, 0)),
        pl.BlockSpec(B.shape, lambda l: (0, 0)),
    ]
    args = [words, values, part_start, B]
    if pre_pi:
        in_specs.append(pl.BlockSpec((chunk, R), lambda l: (l, 0)))
        args.append(pi)
    else:
        others = [f for m, f in enumerate(factors) if m != mode]
        in_specs += [pl.BlockSpec(f.shape, lambda l: (0, 0)) for f in others]
        args += others

    return pl.pallas_call(
        functools.partial(_phi_partial_kernel, enc, mode, temp_rows, eps,
                          pre_pi),
        grid=(L,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, temp_rows, R), lambda l: (l, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, temp_rows, R), B.dtype),
        interpret=interpret,
    )(*args)
