"""GPipe pipeline parallelism over the model stack (dist seam #2).

The depth dimension of `models.model` is a stack of `n_repeats` block
groups; pipeline parallelism cuts that stack into ``n_stages`` contiguous
stages, one per device on the mesh's first axis, and streams microbatches
through them:

* `to_pipeline_params` reshapes each ``blocks_<pos>`` parameter stack
  from ``(n_repeats, ...)`` to ``(n_stages, n_repeats // n_stages, ...)``
  — the leading axis is what `shard_map` shards, so every device holds
  only its stage's layers;
* `pipeline_forward` runs the classic GPipe schedule inside one
  `shard_map`: for ``n_microbatches + n_stages − 1`` ticks, every device
  applies its stage to its current microbatch activation, then the
  activations rotate one stage forward with ``ppermute``. Stage 0 injects
  microbatch ``t`` at tick ``t``; the last stage emits microbatch
  ``t − (n_stages − 1)``. Bubble-tick outputs are computed on zeros and
  masked out (gather via ``where`` + final ``psum``), so they contribute
  nothing to values or gradients;
* `pipeline_loss` is the training entry: same schedule under
  ``jax.grad``. ``ppermute`` transposes to the inverse rotation, so
  backward runs the symmetric reverse schedule automatically — no hand
  written backward pipeline.

Equivalence invariant: stage ``s`` applies repeats ``[s·per, (s+1)·per)``
in the same inner order as `model.forward_hidden`'s scan (pattern position
inner, repeat outer), and embedding / final norm / unembed stay replicated
outside the shard_map — so logits and gradients match the sequential
model to float roundoff (asserted by ``tests/test_pipeline.py``).

Scope: decoder-only families (dense/moe/ssm/hybrid). Encoder-decoder and
VLM prefixes keep their sequential path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models import model as model_lib
from repro.models.common import rmsnorm, unembed
from repro.train.steps import cross_entropy


def to_pipeline_params(cfg: ModelConfig, params, n_stages: int):
    """Regroup the depth stacks for ``n_stages`` pipeline stages.

    ``blocks_<pos>``: (n_repeats, ...) -> (n_stages, per_stage, ...),
    keeping repeat order — stage s owns the contiguous repeats
    [s*per, (s+1)*per). Embedding / norms / unembed pass through
    (replicated on every stage).
    """
    if cfg.n_repeats % n_stages:
        raise ValueError(f"n_repeats {cfg.n_repeats} not divisible by "
                         f"{n_stages} pipeline stages")
    per = cfg.n_repeats // n_stages
    out = {k: v for k, v in params.items() if not k.startswith("blocks_")}
    for pos in range(len(cfg.block_pattern)):
        out[f"blocks_{pos}"] = jax.tree.map(
            lambda a: a.reshape((n_stages, per) + a.shape[1:]),
            params[f"blocks_{pos}"])
    return out


def from_pipeline_params(cfg: ModelConfig, params):
    """Inverse of `to_pipeline_params` (merge stages back to one stack)."""
    out = {k: v for k, v in params.items() if not k.startswith("blocks_")}
    for pos in range(len(cfg.block_pattern)):
        out[f"blocks_{pos}"] = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]),
            params[f"blocks_{pos}"])
    return out


def _stage_apply(cfg: ModelConfig, blocks, x, positions, positions3):
    """Apply one stage's layer slice. ``blocks``: {pos: (1, per, ...)}
    (the local shard — leading stage axis is 1 inside shard_map)."""
    aux = jnp.zeros((), jnp.float32)
    per = jax.tree.leaves(blocks[0])[0].shape[1]
    for layer in range(per):
        for pos, btype in enumerate(cfg.block_pattern):
            p = jax.tree.map(lambda a: a[0, layer], blocks[pos])
            x, a = blk.block_apply(cfg, btype, p, x, positions=positions,
                                   positions3=positions3)
            aux = aux + a
    return x, aux


def _pipe_hidden(cfg: ModelConfig, blocks, x_stack, positions, positions3,
                 mesh, n_micro: int):
    """GPipe schedule under shard_map: (n_micro, mb, S, D) -> same + aux."""
    ax = mesh.axis_names[0]
    n_stages = int(mesh.shape[ax])
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    step = functools.partial(_stage_apply, cfg)
    if cfg.remat:
        step = jax.checkpoint(step)

    def schedule(blocks, x_stack, positions, positions3):
        stage = jax.lax.axis_index(ax)
        state = jnp.zeros_like(x_stack[0])
        out = jnp.zeros_like(x_stack)
        aux = jnp.zeros((), jnp.float32)
        for t in range(n_micro + n_stages - 1):
            # stage 0 injects microbatch t (clamped reload in the drain
            # phase is bubble work, never collected).
            state = jnp.where(stage == 0, x_stack[min(t, n_micro - 1)],
                              state)
            state, a = step(blocks, state, positions, positions3)
            on_time = (t - stage >= 0) & (t - stage < n_micro)
            aux = aux + jnp.where(on_time, a, 0.0)
            m_out = t - (n_stages - 1)
            if m_out >= 0:      # last stage finished microbatch m_out
                out = out.at[m_out].set(
                    jnp.where(stage == n_stages - 1, state, out[m_out]))
            if t < n_micro + n_stages - 2:
                state = jax.lax.ppermute(state, ax, perm)
        # only the last stage holds real outputs; psum replicates them
        last = stage == n_stages - 1
        out = jax.lax.psum(jnp.where(last, out, jnp.zeros_like(out)), ax)
        aux = jax.lax.psum(aux, ax)
        return out, aux

    fn = shard_map(schedule, mesh=mesh,
                   in_specs=(P(ax), P(), P(), P()), out_specs=(P(), P()))
    return fn(blocks, x_stack, positions, positions3)


def _forward_with_aux(cfg: ModelConfig, params, tokens, mesh,
                      n_microbatches: int):
    if cfg.is_encdec or cfg.family == "vlm":
        raise NotImplementedError(
            "pipeline parallelism covers decoder-only token models; "
            f"{cfg.name} ({cfg.family}) needs the sequential path "
            "(cross-attention / multimodal prefixes are not staged)")
    B, S = tokens.shape
    if B % n_microbatches:
        raise ValueError(f"batch {B} not divisible by {n_microbatches} "
                         "microbatches")
    x, positions, positions3 = model_lib._embed_inputs(
        cfg, params, {"tokens": tokens})
    mb = B // n_microbatches
    x_stack = x.reshape((n_microbatches, mb) + x.shape[1:])
    blocks = {pos: params[f"blocks_{pos}"]
              for pos in range(len(cfg.block_pattern))}
    hidden, aux = _pipe_hidden(cfg, blocks, x_stack, positions, positions3,
                               mesh, n_microbatches)
    hidden = hidden.reshape((B,) + hidden.shape[2:])
    hidden = rmsnorm(params["final_norm"], hidden, cfg.norm_eps)
    logits = unembed(model_lib.unembed_params(cfg, params), hidden)
    # per-microbatch aux losses are means over equal-size microbatches;
    # their average is the full-batch mean the sequential model reports
    return logits, aux / n_microbatches


def pipeline_forward(cfg: ModelConfig, params, tokens, mesh,
                     n_microbatches: int = 1) -> jnp.ndarray:
    """Pipelined forward: logits identical to `model.forward` (f32)."""
    logits, _ = _forward_with_aux(cfg, params, tokens, mesh, n_microbatches)
    return logits


def pipeline_loss(cfg: ModelConfig, params, batch, mesh,
                  n_microbatches: int = 1) -> jnp.ndarray:
    """Pipelined training loss (CE + router aux), `jax.grad`-able."""
    logits, aux = _forward_with_aux(cfg, params, batch["tokens"], mesh,
                                    n_microbatches)
    return cross_entropy(logits, batch["labels"]) + cfg.router_aux_coef * aux
