"""Distributed execution of the ALTO stack (paper §4 at multi-device scale).

Two seams:

* `repro.dist.cpd` — CP decomposition with the row-sorted nonzero stream
  cut into per-device row-range shards; each device runs the existing
  single-device oriented segment reduction locally, and boundary-run
  carries plus Gram matrices are combined by ``psum`` (`shard_map`).
* `repro.dist.pipeline` — GPipe-style pipeline parallelism over the model
  stack (stage-sharded block parameters, microbatches rotated between
  stages with ``ppermute``).

Everything here runs identically on real accelerator meshes and on fake
host devices (``--xla_force_host_platform_device_count=N``), which is how
the seed test-suite exercises multi-device semantics on a CPU-only host.
"""
