"""Distributed CP-ALS / CP-APR over row-range shards (paper §4.1/§4.2).

ALTO's linearized nonzero stream is "streamed from memory and amenable to
parallel execution"; this module is that claim made literal on a device
mesh. The oriented view — device-built and process-cached by default
(`core.views`, backed by `core.alto.oriented_view_device`) — sorts
nonzeros by the target-mode row, and the sharding is the simplest one that
preserves every single-device invariant: cut the sorted stream into
per-device **contiguous, equal-size slices** (`shard_map` over the mesh's
first axis). The shard-local row-range slices are carved by `shard_map`'s
input specs from the device-resident view arrays, so from COO ingest to
psum merge nothing round-trips through the host: build_device → cached
view → in-jit padding → per-device slice. Each device runs the *existing* single-device oriented segment
reduction on its slice — reference jnp `segment_sum` or the Pallas kernel
plus `kernels.ops.segment_merge`, exactly as the plan dictates — into a
full-width dense ``(I_n, R)`` output, and the outputs are combined with
``psum``.

Invariants (the carry-merge correctness condition):

* the stream stays **row-sorted**; a shard is a contiguous slice, so each
  device's rows are a sorted run and `segment_sum(indices_are_sorted)` /
  the kernel's run-rank scan stay valid;
* row ids are **global**, so a row whose run spans a shard boundary
  yields one partial sum per adjacent device and the ``psum`` adds them —
  the cross-device analogue of the in-block boundary carry that
  `ops.segment_merge` resolves, and of the paper's "atomics only at
  partition boundaries";
* plans are **static and hashable** (mesh included), so the sharded
  executables cache and jit exactly like the single-device ones;
* padding replicates the last element with zero values, contributing
  nothing while keeping shard shapes equal (perfect workload balance, the
  §4.1 property, inherited by construction from the equal-size cut).

`distributed_cp_als` is the driver: it *is* `core.cpals.cp_als` run under
a mesh-bearing plan (MTTKRP placement comes from the plan routing) with
`sharded_gram` injected as the sweep's Gram hook — one sweep
implementation, so its fit sequence matches the single-device one to
float32 reduction-order noise (≪ 1e-3).

The shard-local reductions are pure functions of their slice, so the unit
tests simulate the mesh by calling them per shard and summing on the host
— bit-identical to what ``psum`` computes on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import alto
from repro.core import cpals
from repro.core import ingest as ingest_mod
from repro.core.encoding import make_encoding
from repro.core import heuristics
from repro.core import plan as plan_mod
from repro.core.alto import AltoTensor, OrientedView
from repro.core.mttkrp import krp_rows
from repro.kernels import mttkrp_oriented as _oriented
from repro.kernels import ops
from repro.sparse.tensor import SparseTensor


# The padding rule is part of the carry-merge correctness condition;
# there is exactly one implementation (shared with the kernel wrappers).
_pad_stream = ops.pad_sorted_stream


def _shard_mult(plan: plan_mod.ExecutionPlan, mode: int) -> int:
    """Global padding multiple: per-shard length must divide block_m on
    the Pallas path (the kernel's grid is exact, no partial blocks)."""
    bm = plan.modes[mode].block_m if plan.backend == "pallas" else 1
    return plan.n_shards * bm


# ---------------------------------------------------------------------------
# Shard-local reductions (pure — unit-testable without a mesh)
# ---------------------------------------------------------------------------

def local_mttkrp(plan: plan_mod.ExecutionPlan, mode: int, rows, words,
                 values, factors) -> jnp.ndarray:
    """One device's oriented MTTKRP over its slice: full-width (I_n, R).

    Exactly the single-device oriented reduction (plan-selected backend);
    summing this over all slices of a sorted stream equals the unsharded
    result because `ops.segment_merge` / `segment_sum` scatter to global
    rows (see module docstring).
    """
    meta = plan.meta
    I_n = meta.dims[mode]
    if plan.backend == "pallas":
        mp = plan.modes[mode]
        if mp.traversal is heuristics.Traversal.ORIENTED_CARRY:
            # Shard-local scratch-carry scan: the final (I_n, R) rows come
            # straight out of the kernel — boundary-run carries survive
            # only at shard boundaries, where the psum merges them.
            return _oriented.mttkrp_oriented_carry_pallas(
                meta.enc, mode, rows, words, values, list(factors),
                block_m=mp.block_m, r_block=mp.r_block,
                interpret=ops._auto_interpret(plan.interpret))
        partials = _oriented.mttkrp_oriented_partials_pallas(
            meta.enc, mode, rows, words, values, list(factors),
            block_m=mp.block_m, r_block=mp.r_block,
            interpret=ops._auto_interpret(plan.interpret))
        return ops.segment_merge(partials, rows, I_n)
    coords = alto.delinearize(meta.enc, words)
    contrib = values[:, None] * krp_rows(coords, factors, mode)
    return jax.ops.segment_sum(contrib, rows, num_segments=I_n,
                               indices_are_sorted=True)


def local_phi(plan: plan_mod.ExecutionPlan, mode: int, eps: float, rows,
              words, values, B, factors=None, pi=None) -> jnp.ndarray:
    """One device's fused CP-APR Φ over its slice: full-width (I_n, R).

    ``B`` is replicated (the Φ denominator needs the full-rank row
    ``B[i_n, :]``, available locally because rows are global ids); the Π
    rows (ALTO-PRE) travel with the stream shard.
    """
    meta = plan.meta
    I_n = meta.dims[mode]
    if plan.backend == "pallas":
        mp = plan.modes[mode]
        if mp.traversal is heuristics.Traversal.ORIENTED_CARRY:
            return _oriented.phi_oriented_carry_pallas(
                meta.enc, mode, eps, rows, words, values, B,
                factors=list(factors) if factors is not None else None,
                pi=pi, block_m=mp.block_m,
                interpret=ops._auto_interpret(plan.interpret))
        partials = _oriented.phi_oriented_partials_pallas(
            meta.enc, mode, eps, rows, words, values, B,
            factors=list(factors) if factors is not None else None, pi=pi,
            block_m=mp.block_m,
            interpret=ops._auto_interpret(plan.interpret))
        return ops.segment_merge(partials, rows, I_n)
    if pi is None:
        coords = alto.delinearize(meta.enc, words)
        pi = krp_rows(coords, factors, mode)
    denom = jnp.maximum(
        jnp.sum(jnp.take(B, rows, axis=0) * pi, axis=-1), eps)
    contrib = (values / denom)[:, None] * pi
    return jax.ops.segment_sum(contrib, rows, num_segments=I_n,
                               indices_are_sorted=True)


def local_gram(A_shard: jnp.ndarray) -> jnp.ndarray:
    """One device's Gram contribution over its row slice: AᵀA is a sum of
    rank-1 outer products, so row shards combine by plain addition."""
    return A_shard.T @ A_shard


# ---------------------------------------------------------------------------
# shard_map wrappers (the mesh-visible primitives)
# ---------------------------------------------------------------------------

def sharded_mttkrp(plan: plan_mod.ExecutionPlan, at: AltoTensor,
                   views: dict[int, OrientedView] | None, factors,
                   mode: int) -> jnp.ndarray:
    """MTTKRP for one mode with the stream row-range-sharded over the mesh.

    Entry point `core.plan.execute_mttkrp` routes mesh-bearing plans to.
    """
    if plan.mesh is None:
        raise ValueError("sharded_mttkrp needs a mesh-bearing plan")
    if not views or mode not in views:
        raise ValueError(
            "mesh-bearing plans orient every mode; build views with "
            "repro.core.plan.build_views(at, plan)")
    view = views[mode]
    ax = plan.mesh_axis
    local = functools.partial(local_mttkrp, plan, mode)

    def build():
        @functools.partial(shard_map, mesh=plan.mesh,
                           in_specs=(P(ax), P(ax), P(ax), P()),
                           out_specs=P(),
                           check_rep=False)  # pallas_call has no rep rule
        def sharded(rows, words, values, factors):
            return jax.lax.psum(local(rows, words, values, factors), ax)

        def run(rows, words, values, factors):
            rows, words, values, _ = _pad_stream(rows, words, values,
                                                 _shard_mult(plan, mode))
            return sharded(rows, words, values, factors)

        return jax.jit(run)

    fn = ops._cached_executable(("dist_mttkrp", plan, mode), build)
    return fn(view.rows, view.words, view.values, list(factors))


def sharded_phi(plan: plan_mod.ExecutionPlan, at: AltoTensor,
                view: OrientedView | None, B: jnp.ndarray, mode: int,
                factors=None, pi: jnp.ndarray | None = None,
                eps: float = 1e-10) -> jnp.ndarray:
    """CP-APR Φ row reduction, row-range-sharded (`execute_phi` routing)."""
    if plan.mesh is None:
        raise ValueError("sharded_phi needs a mesh-bearing plan")
    if view is None:
        raise ValueError("mesh-bearing plans orient every mode; pass the "
                         "mode's oriented view")
    ax = plan.mesh_axis
    pre_pi = pi is not None
    local = functools.partial(local_phi, plan, mode, eps)
    pi_spec = P(ax) if pre_pi else P()

    def build():
        @functools.partial(
            shard_map, mesh=plan.mesh,
            in_specs=(P(ax), P(ax), P(ax), P(), P(), pi_spec),
            out_specs=P(),
            check_rep=False)              # pallas_call has no rep rule
        def sharded(rows, words, values, B, factors, pi):
            return jax.lax.psum(
                local(rows, words, values, B, factors=factors, pi=pi), ax)

        def run(rows, words, values, B, factors, pi):
            rows, words, values, pi = _pad_stream(
                rows, words, values, _shard_mult(plan, mode), pi=pi)
            return sharded(rows, words, values, B, factors, pi)

        return jax.jit(run)

    fn = ops._cached_executable(("dist_phi", plan, mode, eps, pre_pi),
                                build)
    return fn(view.rows, view.words, view.values, B,
              list(factors) if factors is not None else None, pi)


def sharded_gram(mesh, A: jnp.ndarray) -> jnp.ndarray:
    """AᵀA with the rows of ``A`` sharded over the mesh's first axis and
    the per-device Grams combined by ``psum`` (zero-row padding)."""
    ax = mesh.axis_names[0]
    D = int(mesh.shape[ax])

    def build():
        @functools.partial(shard_map, mesh=mesh, in_specs=(P(ax),),
                           out_specs=P(), check_rep=False)
        def sharded(A_shard):
            return jax.lax.psum(local_gram(A_shard), ax)

        def run(A):
            pad = (-A.shape[0]) % D
            if pad:
                A = jnp.concatenate(
                    [A, jnp.zeros((pad, A.shape[1]), A.dtype)])
            return sharded(A)

        return jax.jit(run)

    fn = ops._cached_executable(("dist_gram", mesh), build)
    return fn(A)


# ---------------------------------------------------------------------------
# Distributed incremental ingest (sharded COO deltas)
# ---------------------------------------------------------------------------

def sharded_append_delta(at: AltoTensor, coords, values, mesh, *,
                         policy: str = "sum", dims=None,
                         n_partitions: int | None = None,
                         compute_reuse: bool | None = None,
                         invalidate_stale: bool = True) -> AltoTensor:
    """`core.ingest.append_delta` with the delta's linearization sharded
    over ``mesh`` — the distributed ingest entry point for COO deltas
    that arrive row-partitioned across hosts/devices.

    Linearization is the only embarrassingly parallel stage (pure
    per-element bit gather, no collective), so it runs shard-local under
    `shard_map` — the batch is zero-padded to a shard multiple, split
    over the mesh's first axis, and the reassembled words are sliced
    back to the real length before `ingest.append_linearized` runs the
    (inherently global) merge sort. Bitwise identical to the local
    `append_delta`: padding never reaches the merge, and the per-shard
    bit gather is elementwise.
    """
    coords = np.asarray(coords, dtype=np.int32).reshape(-1, len(at.dims))
    new_dims = alto.grown_dims(at.dims, coords, dims)
    D = coords.shape[0]
    if D == 0:
        return ingest_mod.append_delta(
            at, coords, values, policy=policy, dims=new_dims,
            n_partitions=n_partitions, compute_reuse=compute_reuse,
            invalidate_stale=invalidate_stale)
    enc = make_encoding(new_dims)
    ax = mesh.axis_names[0]
    S = int(mesh.shape[ax])
    pad = (-D) % S
    if pad:
        coords = np.concatenate(
            [coords, np.zeros((pad, coords.shape[1]), np.int32)])
    Dp = coords.shape[0]

    def build():
        @functools.partial(shard_map, mesh=mesh, in_specs=(P(ax),),
                           out_specs=P(ax))
        def sharded(c):
            return alto.linearize(enc, c)

        return jax.jit(sharded)

    fn = ops._cached_executable(("dist_delta_linearize", enc, mesh, Dp),
                                build)
    words = fn(jnp.asarray(coords))[:D]
    return ingest_mod.append_linearized(
        at, words, values, new_dims, policy=policy,
        n_partitions=n_partitions, compute_reuse=compute_reuse,
        invalidate_stale=invalidate_stale)


# ---------------------------------------------------------------------------
# Distributed CP-ALS driver
# ---------------------------------------------------------------------------

def distributed_cp_als(x: SparseTensor | AltoTensor, rank: int, mesh, *,
                       n_iters: int = 50, tol: float = 1e-5, seed: int = 0,
                       n_partitions: int | None = None,
                       backend: str | None = None,
                       interpret: bool | None = None,
                       tune: str = "off", warm_start=None):
    """CP-ALS with MTTKRP and Grams sharded over ``mesh`` (GPipe's sibling
    seam: data-parallel over the nonzero stream, model-replicated factors).

    This IS `core.cpals.cp_als` — same sweep, same host-side float64
    Kolda–Bader fit — run under a mesh-bearing plan (MTTKRP routed to
    `sharded_mttkrp` by `plan.execute_mttkrp`) with `sharded_gram` as the
    sweep's Gram hook. The only deltas from single-device are reduction
    order (shard partials added by psum), so fits match to well under
    1e-3. Returns ``(lam, factors, fits)``.

    Per-shard tile budgets come from the plan layer's corrected
    per-kernel footprints: `make_plan(mesh=...)` divides the VMEM budget
    by the shard count and sizes ``block_m`` against BOTH the oriented
    MTTKRP footprint and the fused Φ footprint (full-rank resident B,
    `plan.phi_oriented_vmem_bytes`), so shard-local blocks stay honest on
    big modes where B dominates. ``tune`` ("off"|"auto"|"force") swaps
    the analytic mesh plan for a measured one: the autotuner times the
    *actual sharded executables* per candidate and persists the winner
    keyed on the shard count (`core.autotune`).
    """
    if isinstance(x, AltoTensor):
        at = x
    else:
        # Device ingest: format generation is a jitted sort on device,
        # and the oriented views the sharded merge consumes come from
        # the shared cache (cpals' plan_mod.build_views) — no host
        # argsort or host→device stream copy anywhere in the chain.
        D = int(mesh.shape[mesh.axis_names[0]])
        at = alto.build_device(x, n_partitions=n_partitions or D)
    plan = plan_mod.make_plan(at.meta, rank, backend=backend,
                              interpret=interpret, mesh=mesh,
                              tune=tune, at=at)
    res = cpals.cp_als(at, rank, n_iters=n_iters, tol=tol, seed=seed,
                       plan=plan, warm_start=warm_start,
                       gram_fn=functools.partial(sharded_gram, mesh))
    return res.lam, res.factors, res.fits
