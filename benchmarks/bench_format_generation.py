"""Paper Fig. 13: format construction cost — REAL builds of each format.

ALTO generation = bit-gather linearize + single-packed-key argsort +
balanced partitioning. HiCOO = block-key split + lexsort + block boundary
scan. CSF-ALL = N mode orderings, each an N-key lexsort + per-level
prefix dedup (the SPLATT-ALL construction the paper benchmarks).
Derived = ALTO speedup over each baseline.

Device rows: `alto_device` is the jitted on-device generation
(`alto.build_device` — same single-key-sort structure, `jax.lax.sort`),
timed end-to-end including the meta-finalizing bounding-box transfer,
after a warmup that absorbs the one-time trace. `view_build/*` times the
oriented-view construction the drivers pay per output-oriented mode —
host numpy argsort vs the device masked-extract + stable sort vs a view
cache hit (`core.views`).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import alto, views as views_mod
from repro.sparse import baselines, synthetic


def _time(fn, iters=3):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = False):
    names = list(synthetic.PAPER_LIKE)[:3 if quick else None]
    for name in names:
        x = synthetic.paper_like(name)

        t_alto = _time(lambda: alto.build(x, n_partitions=8,
                                          compute_reuse=False).words)
        dev_build = lambda: alto.build_device(            # noqa: E731
            x, n_partitions=8, compute_reuse=False).words
        dev_build()                                       # trace warmup
        t_alto_dev = _time(dev_build)
        t_hicoo = _time(lambda: baselines.build_hicoo(x, block_bits=7))
        t_csf = _time(lambda: baselines.CsfAll(x))
        emit(f"format_gen/{name}/alto", t_alto, "speedup=1.00")
        emit(f"format_gen/{name}/alto_device", t_alto_dev,
             f"host_over_device={t_alto / t_alto_dev:.2f}")
        emit(f"format_gen/{name}/hicoo", t_hicoo,
             f"alto_speedup={t_hicoo / t_alto:.2f}")
        emit(f"format_gen/{name}/csf_all", t_csf,
             f"alto_speedup={t_csf / t_alto:.2f}")

        at = alto.build_device(x, n_partitions=8, compute_reuse=False)
        t_view = _time(lambda: alto.oriented_view(at, 0).words)
        dev_view = lambda: alto.oriented_view_device(at, 0).words  # noqa: E731
        dev_view()                                        # trace warmup
        t_view_dev = _time(dev_view)
        views_mod.cache_clear()
        views_mod.get_view(at, 0)                         # fill the cache
        t_view_hit = _time(lambda: views_mod.get_view(at, 0).words)
        emit(f"view_build/{name}/host", t_view, "host_over_device=1.00")
        emit(f"view_build/{name}/device", t_view_dev,
             f"host_over_device={t_view / t_view_dev:.2f}")
        emit(f"view_build/{name}/cache_hit", t_view_hit,
             f"host_over_hit={t_view / max(t_view_hit, 1e-3):.2f}")
        views_mod.cache_clear()


if __name__ == "__main__":
    run()
