"""Paper Fig. 13: format construction cost — REAL builds of each format.

ALTO generation = bit-gather linearize + single-packed-key argsort +
balanced partitioning. HiCOO = block-key split + lexsort + block boundary
scan. CSF-ALL = N mode orderings, each an N-key lexsort + per-level
prefix dedup (the SPLATT-ALL construction the paper benchmarks).
Derived = ALTO speedup over each baseline.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import alto
from repro.sparse import baselines, synthetic


def _time(fn, iters=3):
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = False):
    names = list(synthetic.PAPER_LIKE)[:3 if quick else None]
    for name in names:
        x = synthetic.paper_like(name)

        t_alto = _time(lambda: alto.build(x, n_partitions=8,
                                          compute_reuse=False))
        t_hicoo = _time(lambda: baselines.build_hicoo(x, block_bits=7))
        t_csf = _time(lambda: baselines.CsfAll(x))
        emit(f"format_gen/{name}/alto", t_alto, "speedup=1.00")
        emit(f"format_gen/{name}/hicoo", t_hicoo,
             f"alto_speedup={t_hicoo / t_alto:.2f}")
        emit(f"format_gen/{name}/csf_all", t_csf,
             f"alto_speedup={t_csf / t_alto:.2f}")


if __name__ == "__main__":
    run()
