"""Static-model vs measured-plan deltas per mode (docs/autotuning.md).

For each tensor in the shared jnp-vs-plan set the suite runs the
measured autotuner once per mode (tmpdir store — the user's plan cache
is never touched) and emits paired rows from the tuner's own report:

    autotune/zipf_small/mode0/static,3333.1,traversal=oriented;r_block=16;block_m=1024
    autotune/zipf_small/mode0/measured,265.2,traversal=oriented;r_block=16;block_m=128;candidates=9

Both timings come from the SAME median-of-k sweep (`ops.median_time`
through the compiled-executable cache), so measured ≤ static holds by
construction: the static analytic choice is candidate 0 of the space the
winner is the argmin of. A final `store_hit` row per tensor confirms the
persisted plan round-trips with zero timing runs.
"""
from __future__ import annotations

import os
import tempfile

from benchmarks.common import emit, plan_comparison_tensors

RANK = 16


def run(quick: bool = False):
    from repro.core import alto, autotune, plan as plan_mod
    from repro.kernels import ops

    tensors = plan_comparison_tensors()
    if quick:
        tensors = dict(list(tensors.items())[:1])
    with tempfile.TemporaryDirectory() as td:
        store = os.path.join(td, "plans.json")
        for name, (fn, kwargs) in tensors.items():
            kwargs = dict(kwargs)
            if quick:
                kwargs["nnz"] = min(kwargs["nnz"], 5_000)
            x = fn(**kwargs)
            at = alto.build(x, n_partitions=32)
            plan, report = autotune.tune_plan(
                at, RANK, backend="pallas",
                max_candidates=6 if quick else 12,
                store_path=store)
            for mr in report.modes:
                s, b = mr.static, mr.best
                emit(f"autotune/{name}/mode{mr.mode}/static",
                     s.median_s * 1e6,
                     f"traversal={s.traversal};r_block={s.r_block};"
                     f"block_m={s.block_m}")
                emit(f"autotune/{name}/mode{mr.mode}/measured",
                     b.median_s * 1e6,
                     f"traversal={b.traversal};r_block={b.r_block};"
                     f"block_m={b.block_m};"
                     f"candidates={len(mr.candidates)}")
                assert b.median_s <= s.median_s, (name, mr.mode)
            runs = ops.timing_runs()
            again = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                       tune="force", store_path=store)
            hit = again == plan and ops.timing_runs() == runs
            emit(f"autotune/{name}/store_hit", 0.0,
                 f"identical={hit};timing_runs=0")
            assert hit, f"store round-trip failed for {name}"
