"""Static-model vs measured-plan deltas per mode (docs/autotuning.md).

For each tensor in the shared jnp-vs-plan set the suite runs the
measured autotuner once per mode (tmpdir store — the user's plan cache
is never touched) and emits paired rows from the tuner's own report:

    autotune/zipf_small/mode0/static,3333.1,traversal=oriented;r_block=16;block_m=1024
    autotune/zipf_small/mode0/measured,265.2,traversal=oriented;r_block=16;block_m=128;candidates=9

Both timings come from the SAME median-of-k sweep (`ops.timing_stats`
through the compiled-executable cache), so measured ≤ static holds by
construction: the static analytic choice is candidate 0 of the space the
winner is the argmin of. A final `store_hit` row per tensor confirms the
persisted plan round-trips with zero timing runs.

The `search` rows are the budgeted-search acceptance gate: on the same
tensor and the same (now sample-warm) store, `core.search.search_plan`
gets a run budget of ceil(25% of the exhaustive tuner's timing runs) —
counted through the real `ops.timing_runs()` deltas on both sides — and
its winner must execute within 5% of the exhaustive winner (ratio 1.0
short-circuits when the winning plans are identical; otherwise both
plans are re-measured back-to-back through the same protocol).
"""
from __future__ import annotations

import math
import os
import tempfile

from benchmarks.common import emit, plan_comparison_tensors

RANK = 16
SEARCH_RUN_FRACTION = 0.25     # of the exhaustive tuner's timing runs
SEARCH_TIME_SLACK = 1.05       # search winner within 5% of exhaustive


def _plan_time_s(at, plan, factors, iters=5):
    """Sum of the per-mode winner medians, same protocol as both tuners."""
    from repro.core import plan as plan_mod, search as search_mod

    views = plan_mod.build_views(at, plan)
    total = 0.0
    for mode in range(at.meta.enc.ndim):
        median, _ = search_mod._time_mttkrp(plan, at, views, factors,
                                            mode, 1, iters)
        total += median
    return total


def run(quick: bool = False):
    import jax.numpy as jnp
    import numpy as np

    from repro.core import alto, autotune, plan as plan_mod, search
    from repro.kernels import ops

    tensors = plan_comparison_tensors()
    if quick:
        tensors = dict(list(tensors.items())[:1])
    with tempfile.TemporaryDirectory() as td:
        store = os.path.join(td, "plans.json")
        for name, (fn, kwargs) in tensors.items():
            kwargs = dict(kwargs)
            if quick:
                kwargs["nnz"] = min(kwargs["nnz"], 5_000)
            x = fn(**kwargs)
            at = alto.build(x, n_partitions=32)
            runs_exh0 = ops.timing_runs()
            plan, report = autotune.tune_plan(
                at, RANK, backend="pallas",
                max_candidates=6 if quick else 12,
                store_path=store)
            exhaustive_runs = ops.timing_runs() - runs_exh0
            for mr in report.modes:
                s, b = mr.static, mr.best
                emit(f"autotune/{name}/mode{mr.mode}/static",
                     s.median_s * 1e6,
                     f"traversal={s.traversal};r_block={s.r_block};"
                     f"block_m={s.block_m}")
                emit(f"autotune/{name}/mode{mr.mode}/measured",
                     b.median_s * 1e6,
                     f"traversal={b.traversal};r_block={b.r_block};"
                     f"block_m={b.block_m};"
                     f"candidates={len(mr.candidates)}")
                assert b.median_s <= s.median_s, (name, mr.mode)
            runs = ops.timing_runs()
            again = plan_mod.make_plan(at.meta, RANK, backend="pallas",
                                       tune="force", store_path=store)
            hit = again == plan and ops.timing_runs() == runs
            emit(f"autotune/{name}/store_hit", 0.0,
                 f"identical={hit};timing_runs=0")
            assert hit, f"store round-trip failed for {name}"

            # --- budgeted search vs exhaustive (the acceptance gate) ---
            budget = max(1, math.ceil(SEARCH_RUN_FRACTION
                                      * exhaustive_runs))
            runs_s0 = ops.timing_runs()
            splan, srep = search.search_plan(
                at, RANK, backend="pallas", budget_runs=budget, seed=0,
                persist=False, store_path=store)
            search_runs = ops.timing_runs() - runs_s0
            assert search_runs == srep.runs_used, (name, search_runs,
                                                   srep.runs_used)
            assert search_runs <= budget, (name, search_runs, budget)
            if splan.modes == plan.modes:
                ratio = 1.0        # identical winners: same measured time
            else:
                rng = np.random.default_rng(0)
                factors = [jnp.asarray(rng.standard_normal((I, RANK))
                                       .astype(np.float32))
                           for I in at.meta.dims]
                t_search = _plan_time_s(at, splan, factors)
                t_exh = _plan_time_s(at, plan, factors)
                ratio = t_search / t_exh
            winners = ";".join(
                f"mode{w.mode}={w.traversal},rb{w.r_block},bm{w.block_m}"
                for w in srep.winners)
            emit(f"autotune/{name}/search", ratio,
                 f"runs={search_runs};exhaustive_runs={exhaustive_runs};"
                 f"budget={budget};ratio={ratio:.3f};"
                 f"model_samples={srep.model_samples};"
                 f"neighbors={srep.neighbors};{winners}")
            assert ratio <= SEARCH_TIME_SLACK, (name, ratio)
