"""Out-of-core chunked execution vs in-core (docs/out-of-core.md).

Rows (per tensor, MTTKRP mode 0, scratch-carry tiling from the plan):

* ``outofcore/<t>/incore`` — the in-core carry kernel; derived carries
  ``nnz_per_s`` and the modeled in-core working set bytes;
* ``outofcore/<t>/chunked_c<k>`` — the chunked executor at ``k`` chunks;
  derived carries ``nnz_per_s``, the modeled ``chunk_bytes``
  (`plan.chunk_hbm_bytes`, the double-buffered device footprint), the
  prefetch overlap ratio (prefetches / chunks — 1-1/k by construction,
  every chunk beyond the first is prefetched ahead of compute), and
  ``overlap_eff``: (in-core compute time) / (chunked wall time), the
  fraction of the chunked wall clock not lost to the host loop + copies
  (→ 1.0 when prefetch fully hides transfers; ~structural noise on the
  CPU proxy host, see docs/known-issues.md).

Each chunked row ASSERTS bitwise parity with the in-core result before
timing — a bench that silently diverged would be measuring a different
computation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import alto, plan as plan_mod
from repro.kernels import ops
from repro.sparse import synthetic

RANK = 16
MODE = 0


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def run(quick: bool = False):
    cases = {"uniform_mid": dict(dims=(256, 128, 64), nnz=30_000)}
    if not quick:
        cases["uniform_wide"] = dict(dims=(2048, 512, 256), nnz=120_000)
    for name, kw in cases.items():
        x = synthetic.uniform_tensor(seed=0, **kw)
        at = alto.build(x, n_partitions=8)
        factors = _factors(x.dims, RANK)
        mp = plan_mod.static_mode_plan(at.meta, MODE, RANK,
                                       force_carry=True)
        bm, rb = mp.block_m, mp.r_block
        nnz = at.meta.nnz

        def incore(view, factors):
            return ops.mttkrp_oriented_carry(view, factors, block_m=bm,
                                             r_block=rb, interpret=None)

        view = alto.oriented_view(at, MODE)
        want = incore(view, factors)
        t_in = time_call(incore, view, factors)
        incore_bytes = plan_mod.incore_working_set_bytes(at.meta, RANK)
        emit(f"outofcore/{name}/incore", t_in,
             f"nnz_per_s={nnz / (t_in * 1e-6):.3e};"
             f"incore_bytes={incore_bytes};block_m={bm};r_block={rb}")

        # Chunk grids from coarse to fine; chunk_m stays block-aligned.
        padded = -(-at.meta.nnz // bm) * bm
        for n_chunks in (2, 8) if quick else (2, 8, 32):
            chunk_m = max(bm, (-(-padded // n_chunks) // bm) * bm)
            k = plan_mod.chunk_count(at.meta, chunk_m)

            def chunked(view, factors, chunk_m=chunk_m):
                return ops.mttkrp_oriented_chunked(
                    view, factors, chunk_m=chunk_m, block_m=bm,
                    r_block=rb, interpret=None)

            got = chunked(view, factors)
            assert jnp.array_equal(want, got), (
                f"{name}: chunked (chunk_m={chunk_m}) diverged from "
                "in-core — refusing to time a wrong computation")
            s0 = ops.chunk_stats()
            t_ch = time_call(chunked, view, factors)
            s1 = ops.chunk_stats()
            runs = (s1["chunks"] - s0["chunks"]) // k
            pf_ratio = ((s1["prefetches"] - s0["prefetches"])
                        / max(1, s1["chunks"] - s0["chunks"]))
            chunk_bytes = plan_mod.chunk_hbm_bytes(at.meta, chunk_m, RANK)
            emit(f"outofcore/{name}/chunked_c{k}", t_ch,
                 f"nnz_per_s={nnz / (t_ch * 1e-6):.3e};"
                 f"chunk_bytes={chunk_bytes};chunk_m={chunk_m};"
                 f"prefetch_ratio={pf_ratio:.3f};"
                 f"overlap_eff={min(1.0, t_in / t_ch):.3f};"
                 f"bitwise=1;runs={runs}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
