"""Benchmark helpers: timed jit calls, CSV emission, shared tensor sets."""
from __future__ import annotations

import time

import jax


def plan_comparison_tensors():
    """Moderate-size tensors for the jnp-vs-execution-plan sweeps, shared
    by the MTTKRP and CP-APR suites so their rows are comparable: one
    high-reuse shape (plan routes recursive) and one hyper-sparse shape
    (plan routes output-oriented), both with count data so the same
    tensors feed CP-APR."""
    from repro.sparse import synthetic
    return {
        "zipf_small": (synthetic.zipf_tensor,
                       dict(dims=(64, 48, 32), nnz=20_000, a=1.1,
                            count_data=True)),
        "hyper_small": (synthetic.uniform_tensor,
                        dict(dims=(4096, 2048, 1024), nnz=10_000,
                             count_data=True)),
    }


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-iters wall time of a blocking call, in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
