"""Benchmark helpers: timed jit calls + CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Best-of-iters wall time of a blocking call, in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
