# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller tensors / fewer cases")
    ap.add_argument("--only", default="",
                    help="comma list: mttkrp,cpapr,storage,format,"
                         "kernels,roofline,dist,autotune,carry,serving,"
                         "outofcore,incremental")
    args = ap.parse_args()

    from benchmarks import (bench_autotune, bench_cpapr, bench_dist,
                            bench_format_generation, bench_incremental,
                            bench_kernels, bench_mttkrp,
                            bench_mttkrp_formats, bench_outofcore,
                            bench_roofline, bench_serving, bench_storage)

    suites = {
        "mttkrp": bench_mttkrp_formats.run,      # paper Fig. 9
        "cpapr": bench_cpapr.run,                # paper Figs. 10/11
        "storage": bench_storage.run,            # paper Fig. 12
        "format": bench_format_generation.run,   # paper Fig. 13
        "kernels": bench_kernels.run,            # Pallas hot-spots
        "roofline": bench_roofline.run,          # EXPERIMENTS §Roofline
        "dist": bench_dist.run,                  # docs/distributed.md
        "autotune": bench_autotune.run,          # docs/autotuning.md
        "carry": bench_mttkrp.run,               # one-hot vs scratch-carry
        "serving": bench_serving.run,            # docs/serving.md
        "outofcore": bench_outofcore.run,        # docs/out-of-core.md
        "incremental": bench_incremental.run,    # docs/dynamic-tensors.md
    }
    wanted = [s for s in args.only.split(",") if s] or list(suites)

    print("name,us_per_call,derived")
    failed = 0
    for key in wanted:
        try:
            suites[key](quick=args.quick)
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{key}/SUITE_FAILED,0,", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
