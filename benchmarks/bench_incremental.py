"""Incremental ingest: append latency vs full rebuild, warm vs cold.

Rows (docs/dynamic-tensors.md):

* ``incremental/append_us`` — one `ingest.append_delta` call (jit-warm)
  merging a D-nonzero delta into an M-nonzero resident tensor; derived
  column is the speedup over the full rebuild row;
* ``incremental/rebuild_us`` — the baseline it replaces: host merge of
  the COO + `build_device` from scratch;
* ``incremental/warm_sweeps`` / ``incremental/cold_sweeps`` — CP-ALS
  sweeps to converge on the appended tensor starting from the previous
  result vs from scratch (derived column is the sweep count).

Merge parity (device append bitwise == host `alto.merge_reference`) is
asserted before anything is timed, so a broken merge can never post a
fast number.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import alto, ingest
from repro.core.cpals import cp_als
from repro.sparse.tensor import SparseTensor


def _lowrank(dims, rank, nnz, seed=0):
    rng = np.random.default_rng(seed)
    fac = [rng.uniform(0.1, 1.0, (d, rank)) for d in dims]
    coords = np.stack([rng.integers(0, d, nnz) for d in dims], axis=1)
    v = np.ones(nnz)
    for m, A in enumerate(fac):
        v = v * A[coords[:, m]].sum(axis=1)
    return SparseTensor(tuple(dims), coords.astype(np.int32),
                        v.astype(np.float32))


def run(quick: bool = False) -> None:
    dims = (64, 48, 40) if quick else (256, 192, 160)
    nnz = 4_000 if quick else 40_000
    D, L = 64, 8
    x = _lowrank(dims, 4, nnz, seed=0)
    at = alto.build_device(x, n_partitions=L)
    rng = np.random.default_rng(1)
    coords = np.stack([rng.integers(0, d, D) for d in dims],
                      axis=1).astype(np.int32)
    values = rng.standard_normal(D).astype(np.float32)

    # Parity gate: no timing until the merge is proven bit-identical.
    got = ingest.append_delta(at, coords, values)
    ref = alto.merge_reference(at, coords, values)
    assert got.meta == ref.meta
    assert np.array_equal(np.asarray(got.words), np.asarray(ref.words))
    assert np.array_equal(np.asarray(got.values), np.asarray(ref.values))

    append_us = time_call(
        lambda: ingest.append_delta(at, coords, values,
                                    invalidate_stale=False))

    def rebuild():
        merged = alto.merge_coo(alto.to_sparse(at), coords, values)
        return alto.build_device(merged, n_partitions=L)

    rebuild_us = time_call(rebuild)
    emit("incremental/append_us", append_us,
         f"{rebuild_us / max(append_us, 1e-9):.1f}x_vs_rebuild")
    emit("incremental/rebuild_us", rebuild_us, f"nnz={nnz}+{D}")

    # Warm vs cold sweeps on a perturbed tensor (small fittable case so
    # both converge inside the cap even under --quick).
    wdims = (14, 12, 10)
    wx = _lowrank(wdims, 3, 250, seed=0)
    wat = alto.build_device(wx, n_partitions=4)
    base = cp_als(wat, 3, n_iters=80, tol=1e-5, seed=1)
    dc = np.stack([rng.integers(0, d, 6) for d in wdims],
                  axis=1).astype(np.int32)
    dv = (0.02 * rng.standard_normal(6)).astype(np.float32)
    new_at = ingest.append_delta(wat, dc, dv)

    warm_us = time_call(
        lambda: cp_als(new_at, 3, n_iters=80, tol=1e-4, warm_start=base),
        warmup=1, iters=2)
    cold_us = time_call(
        lambda: cp_als(new_at, 3, n_iters=80, tol=1e-4, seed=1),
        warmup=1, iters=2)
    warm = cp_als(new_at, 3, n_iters=80, tol=1e-4, warm_start=base)
    cold = cp_als(new_at, 3, n_iters=80, tol=1e-4, seed=1)
    assert warm.n_iters < cold.n_iters, (warm.n_iters, cold.n_iters)
    emit("incremental/warm_sweeps", warm_us, f"sweeps={warm.n_iters}")
    emit("incremental/cold_sweeps", cold_us, f"sweeps={cold.n_iters}")
