"""Paper Fig. 9: parallel MTTKRP speedup across sparse formats.

Formats: COO (list-based scatter-add baseline), HiCOO (block-based
mode-agnostic), CSF-ALL (mode-specific, one tree per mode), and the three
ALTO variants. All modes are timed (the paper reports all-modes MTTKRP);
derived column = speedup vs COO, the paper's mode-agnostic baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, plan_comparison_tensors, time_call
from repro.core import alto, mttkrp, plan as plan_mod
from repro.sparse import baselines, synthetic

TENSORS = ["uber_like", "chicago_like", "darpa_like", "nell2_like",
           "enron_like", "fbm_like"]
RANK = 16


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def run(quick: bool = False):
    names = TENSORS[:3] if quick else TENSORS
    for name in names:
        x = synthetic.paper_like(name)
        at = alto.build(x, n_partitions=32)
        views = {m: alto.oriented_view(at, m) for m in range(x.ndim)}
        factors = _factors(x.dims, RANK)
        coords = jnp.asarray(x.coords)
        values = jnp.asarray(x.values)
        N = x.ndim

        def all_modes_coo(coords, values, factors):
            return [mttkrp.mttkrp_coo(coords, values, factors, m)
                    for m in range(N)]

        def all_modes_rec(at, factors):
            return [mttkrp.mttkrp_recursive(at, factors, m)
                    for m in range(N)]

        def all_modes_ori(views, factors):
            return [mttkrp.mttkrp_oriented(views[m], factors)
                    for m in range(N)]

        def all_modes_ada(at, views, factors):
            return [mttkrp.mttkrp_adaptive(at, views, factors, m)
                    for m in range(N)]

        hic = baselines.build_hicoo(x, block_bits=7)
        csf = baselines.CsfAll(x)

        def all_modes_hicoo(factors):           # closes over hic (static
            return [baselines.mttkrp_hicoo(hic, factors, m)  # np arrays)
                    for m in range(N)]

        def all_modes_csf(factors):
            return [csf.mttkrp(factors, m) for m in range(N)]

        t_coo = time_call(jax.jit(all_modes_coo), coords, values, factors)
        t_hic = time_call(jax.jit(all_modes_hicoo), factors)
        t_csf = time_call(jax.jit(all_modes_csf), factors)
        t_rec = time_call(jax.jit(all_modes_rec), at, factors)
        t_ori = time_call(jax.jit(all_modes_ori), views, factors)
        t_ada = time_call(jax.jit(all_modes_ada), at, views, factors)
        emit(f"mttkrp/{name}/coo", t_coo, "speedup_vs_coo=1.00")
        emit(f"mttkrp/{name}/hicoo", t_hic,
             f"speedup_vs_coo={t_coo / t_hic:.2f}")
        emit(f"mttkrp/{name}/csf_all", t_csf,
             f"speedup_vs_coo={t_coo / t_csf:.2f};mode_specific=N_copies")
        emit(f"mttkrp/{name}/alto_recursive", t_rec,
             f"speedup_vs_coo={t_coo / t_rec:.2f}")
        emit(f"mttkrp/{name}/alto_oriented", t_ori,
             f"speedup_vs_coo={t_coo / t_ori:.2f}")
        emit(f"mttkrp/{name}/alto_adaptive", t_ada,
             f"speedup_vs_coo={t_coo / t_ada:.2f};"
             f"reuse={min(at.meta.fiber_reuse):.1f}")

    run_plan_comparison(quick=quick)


def run_plan_comparison(quick: bool = False):
    """Per-mode jnp (reference backend) vs execution-plan (Pallas) rows.

    The plan path runs the Pallas kernels — interpret-lowered on CPU,
    Mosaic on TPU — through `kernels.ops`' compiled-executable cache, so
    steady-state timings measure the kernel, not re-tracing.
    """
    tensors = plan_comparison_tensors()
    names = list(tensors)[:1] if quick else list(tensors)
    for name in names:
        gen, kw = tensors[name]
        x = gen(seed=0, **kw)
        at = alto.build(x, n_partitions=8)
        factors = _factors(x.dims, RANK)
        plan_ref = plan_mod.make_plan(at.meta, RANK, backend="reference")
        plan_pal = plan_mod.make_plan(at.meta, RANK, backend="pallas")
        views = plan_mod.build_views(at, plan_pal)
        for m in range(x.ndim):
            def one_mode_jnp(at, views, factors, _m=m):
                return mttkrp.mttkrp_adaptive(at, views, factors, _m,
                                              plan=plan_ref)

            def one_mode_plan(at, views, factors, _m=m):
                # ops-level executables are cached+jitted internally
                return plan_mod.execute_mttkrp(plan_pal, at, views,
                                               factors, _m)

            t_jnp = time_call(jax.jit(one_mode_jnp), at, views, factors)
            t_plan = time_call(one_mode_plan, at, views, factors)
            trav = plan_pal.modes[m].traversal.value
            emit(f"mttkrp_plan/{name}/mode{m}/jnp", t_jnp,
                 f"traversal={trav};speedup_vs_jnp=1.00")
            emit(f"mttkrp_plan/{name}/mode{m}/plan", t_plan,
                 f"traversal={trav};speedup_vs_jnp={t_jnp / t_plan:.2f};"
                 f"r_block={plan_pal.modes[m].r_block};"
                 f"block_m={plan_pal.modes[m].block_m}")


if __name__ == "__main__":
    run()
