"""Paper Fig. 9: parallel MTTKRP speedup across sparse formats.

Formats: COO (list-based scatter-add baseline), HiCOO (block-based
mode-agnostic), CSF-ALL (mode-specific, one tree per mode), and the three
ALTO variants. All modes are timed (the paper reports all-modes MTTKRP);
derived column = speedup vs COO, the paper's mode-agnostic baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import alto, mttkrp
from repro.sparse import baselines, synthetic

TENSORS = ["uber_like", "chicago_like", "darpa_like", "nell2_like",
           "enron_like", "fbm_like"]
RANK = 16


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def run(quick: bool = False):
    names = TENSORS[:3] if quick else TENSORS
    for name in names:
        x = synthetic.paper_like(name)
        at = alto.build(x, n_partitions=32)
        views = {m: alto.oriented_view(at, m) for m in range(x.ndim)}
        factors = _factors(x.dims, RANK)
        coords = jnp.asarray(x.coords)
        values = jnp.asarray(x.values)
        N = x.ndim

        def all_modes_coo(coords, values, factors):
            return [mttkrp.mttkrp_coo(coords, values, factors, m)
                    for m in range(N)]

        def all_modes_rec(at, factors):
            return [mttkrp.mttkrp_recursive(at, factors, m)
                    for m in range(N)]

        def all_modes_ori(views, factors):
            return [mttkrp.mttkrp_oriented(views[m], factors)
                    for m in range(N)]

        def all_modes_ada(at, views, factors):
            return [mttkrp.mttkrp_adaptive(at, views, factors, m)
                    for m in range(N)]

        hic = baselines.build_hicoo(x, block_bits=7)
        csf = baselines.CsfAll(x)

        def all_modes_hicoo(factors):           # closes over hic (static
            return [baselines.mttkrp_hicoo(hic, factors, m)  # np arrays)
                    for m in range(N)]

        def all_modes_csf(factors):
            return [csf.mttkrp(factors, m) for m in range(N)]

        t_coo = time_call(jax.jit(all_modes_coo), coords, values, factors)
        t_hic = time_call(jax.jit(all_modes_hicoo), factors)
        t_csf = time_call(jax.jit(all_modes_csf), factors)
        t_rec = time_call(jax.jit(all_modes_rec), at, factors)
        t_ori = time_call(jax.jit(all_modes_ori), views, factors)
        t_ada = time_call(jax.jit(all_modes_ada), at, views, factors)
        emit(f"mttkrp/{name}/coo", t_coo, "speedup_vs_coo=1.00")
        emit(f"mttkrp/{name}/hicoo", t_hic,
             f"speedup_vs_coo={t_coo / t_hic:.2f}")
        emit(f"mttkrp/{name}/csf_all", t_csf,
             f"speedup_vs_coo={t_coo / t_csf:.2f};mode_specific=N_copies")
        emit(f"mttkrp/{name}/alto_recursive", t_rec,
             f"speedup_vs_coo={t_coo / t_rec:.2f}")
        emit(f"mttkrp/{name}/alto_oriented", t_ori,
             f"speedup_vs_coo={t_coo / t_ori:.2f}")
        emit(f"mttkrp/{name}/alto_adaptive", t_ada,
             f"speedup_vs_coo={t_coo / t_ada:.2f};"
             f"reuse={min(at.meta.fiber_reuse):.1f}")


if __name__ == "__main__":
    run()
