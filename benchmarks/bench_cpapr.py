"""Paper Figs. 10/11: CP-APR model-update (Φ) performance.

Compares the SparTen-style COO baseline (scatter-add Φ with precomputed Π,
no linearization) against ALTO Φ with the adaptive traversal, for both
ALTO-PRE and ALTO-OTF memory policies. Derived = speedup vs the COO
baseline (the paper's Fig. 10 y-axis) and the per-policy ratio (Fig. 11's
OTF-vs-PRE diamonds).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, plan_comparison_tensors, time_call
from repro.core import alto, heuristics, mttkrp, plan as plan_mod
from repro.core.cpapr import _phi
from repro.core.mttkrp import (krp_rows, row_reduce_oriented,
                               row_reduce_recursive)
from repro.sparse import synthetic

TENSORS = ["uber_like", "chicago_like", "darpa_like", "enron_like"]
RANK = 16
EPS = 1e-10


def _setup(name):
    x = synthetic.paper_like(name)
    at = alto.build(x, n_partitions=32)
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(np.abs(rng.standard_normal((I, RANK))
                                  ).astype(np.float32) + 0.05)
               for I in x.dims]
    return x, at, factors


def run(quick: bool = False):
    names = TENSORS[:2] if quick else TENSORS
    for name in names:
        x, at, factors = _setup(name)
        mode = 0
        B = jnp.abs(factors[mode]) + 0.1
        coords_coo = jnp.asarray(x.coords)
        values_coo = jnp.asarray(x.values)

        # SparTen-style baseline: COO + stored Π + atomic-style scatter-add
        def phi_coo(coords, values, B, pi):
            rows = coords[:, mode]
            contrib = _phi(rows, values, pi, B, EPS)
            out = jnp.zeros((B.shape[0], RANK), contrib.dtype)
            return out.at[rows].add(contrib)

        pi_coo = krp_rows(coords_coo, factors, mode)

        def phi_alto(at, B, factors):
            coords = alto.delinearize(at.meta.enc, at.words)
            krp = krp_rows(coords, factors, mode)   # OTF
            contrib = _phi(coords[:, mode], at.values, krp, B, EPS)
            return row_reduce_recursive(at, mode, contrib)

        def phi_alto_pre(at, B, pi):
            coords = alto.delinearize(at.meta.enc, at.words)
            contrib = _phi(coords[:, mode], at.values, pi, B, EPS)
            return row_reduce_recursive(at, mode, contrib)

        pi_alto = krp_rows(at.coords(), factors, mode)

        t_coo = time_call(jax.jit(phi_coo), coords_coo, values_coo, B,
                          pi_coo)
        t_otf = time_call(jax.jit(phi_alto), at, B, factors)
        t_pre = time_call(jax.jit(phi_alto_pre), at, B, pi_alto)
        pol = heuristics.choose_pi_policy(at.meta, RANK).value
        emit(f"cpapr_phi/{name}/sparten_coo", t_coo, "speedup=1.00")
        emit(f"cpapr_phi/{name}/alto_otf", t_otf,
             f"speedup={t_coo / t_otf:.2f}")
        emit(f"cpapr_phi/{name}/alto_pre", t_pre,
             f"speedup={t_coo / t_pre:.2f};chosen={pol}")

    run_plan_comparison(quick=quick)


def run_plan_comparison(quick: bool = False):
    """Φ through the execution plan: jnp reference vs Pallas, per mode."""
    tensors = plan_comparison_tensors()
    names = list(tensors)[:1] if quick else list(tensors)
    for name in names:
        gen, kw = tensors[name]
        x = gen(seed=0, **kw)
        at = alto.build(x, n_partitions=8)
        rng = np.random.default_rng(0)
        factors = [jnp.asarray(np.abs(rng.standard_normal((I, RANK))
                                      ).astype(np.float32) + 0.05)
                   for I in x.dims]
        plan_ref = plan_mod.make_plan(at.meta, RANK, backend="reference")
        plan_pal = plan_mod.make_plan(at.meta, RANK, backend="pallas")
        views = plan_mod.build_views(at, plan_pal)
        for m in range(x.ndim):
            B = jnp.abs(factors[m]) + 0.1
            view = views.get(m)

            def phi_jnp(at, view, B, factors, _m=m):
                return plan_mod.execute_phi(plan_ref, at, view, B, _m,
                                            factors=factors, eps=EPS)

            def phi_plan(at, view, B, factors, _m=m):
                return plan_mod.execute_phi(plan_pal, at, view, B, _m,
                                            factors=factors, eps=EPS)

            t_jnp = time_call(jax.jit(phi_jnp), at, view, B, factors)
            t_plan = time_call(phi_plan, at, view, B, factors)
            trav = plan_pal.modes[m].traversal.value
            emit(f"cpapr_phi_plan/{name}/mode{m}/jnp", t_jnp,
                 f"traversal={trav};speedup_vs_jnp=1.00")
            emit(f"cpapr_phi_plan/{name}/mode{m}/plan", t_plan,
                 f"traversal={trav};speedup_vs_jnp={t_jnp / t_plan:.2f}")


if __name__ == "__main__":
    run()
