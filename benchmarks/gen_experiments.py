"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSON artifacts (baseline + optimized sweeps).

  PYTHONPATH=src python -m benchmarks.gen_experiments > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.join(os.path.dirname(__file__), "..")
BASE = os.path.join(HERE, "experiments", "dryrun_baseline")
OPT = os.path.join(HERE, "experiments", "dryrun")

ARCH_ORDER = ["qwen2-1.5b", "glm4-9b", "smollm-360m", "minitron-8b",
              "whisper-base", "xlstm-1.3b", "qwen2-vl-72b",
              "granite-moe-3b-a800m", "kimi-k2-1t-a32b", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d):
    recs = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        mesh = "multipod" if "pod=2" in r["mesh"] else "pod"
        recs[(r["arch"], r["shape"], mesh)] = r
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def frac(rl):
    """Roofline fraction: compute term / dominant term (how close the cell
    is to being compute-limited, the best case)."""
    dom = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
    return rl["t_compute"] / dom if dom > 0 else 0.0


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | status | compile s | args GiB/dev | "
            "temp GiB/dev | peak GiB/dev | collectives (ar/ag/rs/a2a/cp) |",
            "|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                continue
            if r["status"] != "ok":
                rows.append(f"| {a} | {s} | SKIP ({r.get('reason','')}) "
                            f"| | | | | |")
                continue
            m = r["memory"]
            c = r["collectives_raw"]["counts"]
            cc = (f"{c['all-reduce']}/{c['all-gather']}/"
                  f"{c['reduce-scatter']}/{c['all-to-all']}/"
                  f"{c['collective-permute']}")
            rows.append(
                f"| {a} | {s} | ok | {r['compile_s']} | "
                f"{fmt_bytes(m['argument_bytes'])} | "
                f"{fmt_bytes(m['temp_bytes'])} | "
                f"{fmt_bytes(m['peak_est_bytes'])} | {cc} |")
    return "\n".join(rows)


def roofline_table(recs, mesh):
    rows = ["| arch | shape | t_compute s | t_memory s | t_collective s | "
            "bottleneck | MODEL_FLOPS | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok" or "roofline" not in r:
                continue
            rl = r["roofline"]
            rows.append(
                f"| {a} | {s} | {rl['t_compute']:.3f} | "
                f"{rl['t_memory']:.3f} | {rl['t_collective']:.3f} | "
                f"{rl['bottleneck']} | {rl['model_flops_global']:.2e} | "
                f"{rl['useful_ratio']:.2f} | {frac(rl):.2f} |")
    return "\n".join(rows)


def compare_table(base, opt, mesh):
    rows = ["| arch | shape | dominant term (base→opt) s | peak GiB/dev "
            "(base→opt) | useful (base→opt) |",
            "|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b = base.get((a, s, mesh))
            o = opt.get((a, s, mesh))
            if not b or not o or b["status"] != "ok" or o["status"] != "ok":
                continue
            if "roofline" not in b or "roofline" not in o:
                continue
            rb, ro = b["roofline"], o["roofline"]
            db = max(rb["t_compute"], rb["t_memory"], rb["t_collective"])
            do = max(ro["t_compute"], ro["t_memory"], ro["t_collective"])
            rows.append(
                f"| {a} | {s} | {db:.2f} → {do:.2f} "
                f"({db / max(do, 1e-9):.2f}x) | "
                f"{b['memory']['peak_est_bytes'] / 2**30:.1f} → "
                f"{o['memory']['peak_est_bytes'] / 2**30:.1f} | "
                f"{rb['useful_ratio']:.2f} → {ro['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    base = load(BASE)
    opt = load(OPT) if os.path.isdir(OPT) else {}
    print("### Dry-run, single pod 16x16 (optimized build)\n")
    print(dryrun_table(opt or base, "pod"))
    print("\n### Dry-run, multi-pod 2x16x16 (optimized build)\n")
    print(dryrun_table(opt or base, "multipod"))
    print("\n### Roofline (single pod, baseline build)\n")
    print(roofline_table(base, "pod"))
    if opt:
        print("\n### Roofline (single pod, optimized build)\n")
        print(roofline_table(opt, "pod"))
        print("\n### Baseline → optimized (single pod)\n")
        print(compare_table(base, opt, "pod"))
        print("\n### Baseline → optimized (multi-pod)\n")
        print(compare_table(base, opt, "multipod"))


if __name__ == "__main__":
    main()
