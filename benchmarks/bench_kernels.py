"""Pallas kernel micro-benchmarks (interpret mode on CPU — relative
numbers only; the TPU roofline story lives in EXPERIMENTS.md §Roofline).
Derived = rel. error vs the pure-jnp oracle, proving the timed artifact is
the validated one."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import alto, mttkrp as cm
from repro.kernels import ops, ref
from repro.sparse import synthetic


def run(quick: bool = False):
    x = synthetic.zipf_tensor((256, 256, 128), 20_000 if quick else 60_000,
                              seed=1, count_data=True)
    at = alto.build(x, n_partitions=8)
    rng = np.random.default_rng(0)
    factors = [jnp.asarray(np.abs(rng.standard_normal((I, 16))
                                  ).astype(np.float32) + 0.05)
               for I in x.dims]

    t = time_call(lambda: ops.delinearize(at.meta.enc, at.words))
    got = ops.delinearize(at.meta.enc, at.words)
    want = ref.ref_delinearize(at.meta.enc, at.words)
    emit("kernel/delinearize", t,
         f"exact={bool(jnp.array_equal(got, want))}")

    t = time_call(lambda: ops.mttkrp(at, factors, 0))
    got = ops.mttkrp(at, factors, 0)
    want = cm.mttkrp_recursive(at, factors, 0)
    rel = float(jnp.max(jnp.abs(got - want))) / (
        float(jnp.max(jnp.abs(want))) + 1e-9)
    emit("kernel/mttkrp", t, f"rel_err={rel:.1e}")

    B = jnp.abs(factors[0]) + 0.1
    t = time_call(lambda: ops.cpapr_phi(at, B, 0, factors=factors))
    emit("kernel/cpapr_phi_otf", t, "")


if __name__ == "__main__":
    run()
