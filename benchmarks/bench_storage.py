"""Paper Fig. 12 / Eqs. 1-3: tensor storage across formats, relative to COO.

Exact byte counts from the REAL format builds: COO, ALTO (runtime
multi-u32 index), HiCOO (block+offset arrays), CSF-ALL (N fiber trees,
the paper's 'SPLATT-ALL'), the analytic Z-Morton SFC size (Eq. 3), and
the adaptive extra cost of oriented views (only for limited-reuse modes).

`alto_resident` is the honest working set next to the paper's Fig. 12
numbers: `plan.resident_bytes` sums the arrays a CP-ALS run actually
holds on device — the padded stream, partition boxes, and every
materialized oriented-view copy the plan routes (which
`AltoTensor.storage_bytes`'s per-nonzero accounting undercounts).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import alto, heuristics, encoding as E
from repro.core import plan as plan_mod
from repro.core import views as views_mod
from repro.sparse import baselines, synthetic


def run(quick: bool = False):
    names = list(synthetic.PAPER_LIKE)[:3 if quick else None]
    for name in names:
        x = synthetic.paper_like(name)
        enc = E.make_encoding(x.dims)
        vb = x.values.dtype.itemsize
        coo = x.nnz * (enc.storage_bits_coo(32) // 8 + vb)
        at = alto.build(x, n_partitions=8)
        alto_b = at.storage_bytes()
        # adaptive oriented views (permutation + row ids) only where needed
        extra = 0
        for m in range(x.ndim):
            if heuristics.choose_traversal(at.meta, m) is \
                    heuristics.Traversal.OUTPUT_ORIENTED:
                extra += x.nnz * 8                     # perm + rows (i32)
        sfc = x.nnz * (max(1, -(-enc.storage_bits_sfc() // 32)) * 4 + vb)
        csf = baselines.CsfAll(x).storage_bytes()
        hic = baselines.build_hicoo(x, block_bits=7).storage_bytes()
        emit(f"storage/{name}/coo", 0.0, f"bytes={coo};rel=1.00")
        emit(f"storage/{name}/alto", 0.0,
             f"bytes={alto_b};rel={alto_b / coo:.2f}")
        emit(f"storage/{name}/alto_adaptive", 0.0,
             f"bytes={alto_b + extra};rel={(alto_b + extra) / coo:.2f}")
        plan = plan_mod.make_plan(at.meta, rank=16)
        views = plan_mod.build_views(at, plan)
        res = plan_mod.resident_bytes(at, views)
        emit(f"storage/{name}/alto_resident", 0.0,
             f"bytes={res};rel={res / coo:.2f};views={len(views)}")
        views_mod.cache_clear()
        emit(f"storage/{name}/hicoo", 0.0,
             f"bytes={hic};rel={hic / coo:.2f}")
        emit(f"storage/{name}/zmorton_sfc", 0.0,
             f"bytes={sfc};rel={sfc / coo:.2f}")
        emit(f"storage/{name}/csf_all", 0.0,
             f"bytes={csf};rel={csf / coo:.2f}")


if __name__ == "__main__":
    run()
