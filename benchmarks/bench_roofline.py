"""Roofline summary from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
emits one row per (arch × shape × mesh): name, dominant-term seconds (as
us_per_call), derived = bottleneck + per-term seconds + useful ratio.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run(quick: bool = False):
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/none", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return
    for f in files:
        rec = json.load(open(f))
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec.get("status") != "ok":
            emit(name, 0.0, f"status={rec.get('status')}")
            continue
        rl = rec.get("roofline")
        if not rl:
            emit(name, 0.0, "no-calibration")
            continue
        dom = max(rl["t_compute"], rl["t_memory"], rl["t_collective"])
        emit(name, dom * 1e6,
             f"bottleneck={rl['bottleneck']};"
             f"t_c={rl['t_compute']:.3f}s;t_m={rl['t_memory']:.3f}s;"
             f"t_x={rl['t_collective']:.3f}s;"
             f"useful={rl['useful_ratio']:.2f};"
             f"peak_dev_GiB={rec['memory']['peak_est_bytes'] / 2**30:.2f}")


if __name__ == "__main__":
    run()
