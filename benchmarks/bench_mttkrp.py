"""Scratch-carry vs one-hot oriented MTTKRP (ISSUE 4, ROADMAP kernel item).

Emits ``mttkrp_carry/<tensor>/mode<m>/{onehot,carry}`` rows. The derived
column carries the two quantities the carry rewrite is about:

* ``nnz_per_s`` — stream throughput of the timed call;
* ``partials_bytes`` — the materialized intermediate between kernel and
  final ``(I_n, R)`` rows: the one-hot path round-trips a
  ``(n_blocks, block_m, R)`` partials buffer through HBM for
  `ops.segment_merge` to re-scatter, the carry path materializes only
  the output itself (``I_n·R``; the reduction rides VMEM scratch).

On CPU the kernels run under the Pallas interpreter, so times are a
proxy ranking (docs/known-issues.md); the partials-bytes column is exact
on any backend. R = 32: at small ranks the one-hot matmul is cheap
enough that the merge pass can win under the interpreter; the carry path
is expected to be no worse from R >= 32 up.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, plan_comparison_tensors, time_call
from repro.core import alto, heuristics, plan as plan_mod
from repro.core.heuristics import Traversal
from repro.kernels import ops

RANK = 32


def _factors(dims, R, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((I, R)).astype(np.float32))
            for I in dims]


def partials_bytes(traversal: Traversal, stream_len: int, block_m: int,
                   out_rows: int, rank: int, dtype_bytes: int = 4) -> int:
    """Materialized-intermediate bytes between kernel and final rows."""
    if traversal is Traversal.ORIENTED_CARRY:
        return out_rows * rank * dtype_bytes           # the output itself
    padded = -(-stream_len // block_m) * block_m       # n_blocks * block_m
    return padded * rank * dtype_bytes


def run(quick: bool = False):
    tensors = plan_comparison_tensors()
    names = list(tensors)[:1] if quick else list(tensors)
    for name in names:
        gen, kw = tensors[name]
        x = gen(seed=0, **kw)
        at = alto.build(x, n_partitions=8)
        factors = _factors(x.dims, RANK)
        modes = range(1 if quick else x.ndim)
        for m in modes:
            view = alto.oriented_view(at, m)
            mp = plan_mod.static_mode_plan(at.meta, m, RANK,
                                           force_oriented=True)
            bm, rb = mp.block_m, mp.r_block
            stream = int(view.rows.shape[0])

            def onehot(view, factors):
                return ops.mttkrp_oriented(view, factors, block_m=bm,
                                           r_block=rb, interpret=None)

            def carry(view, factors):
                return ops.mttkrp_oriented_carry(view, factors, block_m=bm,
                                                 r_block=rb, interpret=None)

            t_one = time_call(onehot, view, factors)
            t_car = time_call(carry, view, factors)
            pb_one = partials_bytes(Traversal.OUTPUT_ORIENTED, stream, bm,
                                    x.dims[m], RANK)
            pb_car = partials_bytes(Traversal.ORIENTED_CARRY, stream, bm,
                                    x.dims[m], RANK)
            nnz_s_one = at.meta.nnz / (t_one * 1e-6)
            nnz_s_car = at.meta.nnz / (t_car * 1e-6)
            emit(f"mttkrp_carry/{name}/mode{m}/onehot", t_one,
                 f"nnz_per_s={nnz_s_one:.3e};partials_bytes={pb_one};"
                 f"block_m={bm};r_block={rb}")
            emit(f"mttkrp_carry/{name}/mode{m}/carry", t_car,
                 f"nnz_per_s={nnz_s_car:.3e};partials_bytes={pb_car};"
                 f"speedup_vs_onehot={t_one / t_car:.2f};"
                 f"partials_shrink={pb_one / max(1, pb_car):.1f}x")
            # On a hyper-sparse long mode (I_n > padded stream) the carry
            # output legitimately exceeds the one-hot partials — that is
            # exactly when the traffic heuristic routes one-hot, so the
            # claim under test is conditional on the routing decision.
            if heuristics.choose_oriented_variant(at.meta, m, RANK) \
                    is Traversal.ORIENTED_CARRY:
                assert pb_car <= pb_one, (
                    "carry chosen by the traffic model but materializes "
                    "more than the one-hot partials — model and bench "
                    "accounting disagree")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
