"""Distributed CP-ALS sweep scaling on 1/2/4/8 fake host devices.

Each device count runs in a fresh subprocess because
``--xla_force_host_platform_device_count`` must be set before the first
jax import. Rows: ``dist_cpals/<tensor>/dev<N>`` — one full sharded
CP-ALS sweep (sharded MTTKRP all modes + psum'd Grams) per call. On the
CPU host the fake devices timeshare one core, so this measures collective
+ partitioning overhead, not speedup — the scaling *shape* (flat ≈ free
sharding) is the signal; real speedups need one chip per shard.
"""
from __future__ import annotations

import os
import subprocess
import sys

DEVICE_COUNTS = (1, 2, 4, 8)


def run(quick: bool = False) -> None:
    for n in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("PYTHONPATH", "src:.")
        cmd = [sys.executable, "-m", "benchmarks.bench_dist",
               "--worker", str(n)] + (["--quick"] if quick else [])
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1800)
        sys.stdout.write(r.stdout)
        sys.stdout.flush()
        if r.returncode != 0:
            raise RuntimeError(f"dev{n} worker failed:\n{r.stderr[-2000:]}")


def _worker(n_dev: int, quick: bool) -> None:
    import functools

    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_call
    from repro.core import alto, cpals, plan as plan_mod
    from repro.dist import cpd
    from repro.sparse import synthetic

    mesh = jax.make_mesh((n_dev,), ("data",))
    rank = 8
    dims, nnz = ((1024, 256, 128), 30_000) if quick else \
        ((4096, 1024, 256), 120_000)
    x = synthetic.uniform_tensor(dims, nnz, seed=0)
    at = alto.build(x, n_partitions=8)
    plan = plan_mod.make_plan(at.meta, rank, mesh=mesh)
    views = plan_mod.build_views(at, plan)
    factors = cpals.init_factors(at.dims, rank, seed=0)
    lam = jnp.ones((rank,), jnp.float32)

    sweep = jax.jit(functools.partial(
        cpals._sweep, plan,
        gram_fn=functools.partial(cpd.sharded_gram, mesh)))
    us = time_call(lambda: sweep(at, views, factors, lam))
    emit(f"dist_cpals/uniform/dev{n_dev}", us,
         f"nnz={at.nnz};shards={plan.n_shards}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.worker:
        _worker(args.worker, args.quick)
    else:
        run(quick=args.quick)
