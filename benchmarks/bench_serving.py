"""Multi-tenant serving throughput: shape-class bucketing vs solo runs.

Rows (docs/serving.md):

* ``serving/tenants_per_s`` — tenants decomposed per second of bucket
  busy time through the batched layer;
* ``serving/traces_per_bucket`` — batched-sweep jit traces divided by
  buckets run (the bucketing payoff: well under 1 once a class is warm,
  asserted <= 1.0 here since every bucket of a class reuses one trace);
* ``serving/latency_p50`` / ``serving/latency_p99`` — submit-to-result
  wall clock per tenant (µs), bucket-mates included;
* ``serving/solo_us_per_tenant`` — the unbatched baseline: the same
  tenants through individual `cp_als` calls, one compile each;
* ``serving/guarded_us`` / ``serving/unguarded_us`` — the health-guard
  overhead bound (PR 9): the same bucket served with and without the
  per-sweep guards, median of several reps, ASSERTED within 5% (plus a
  small absolute slack for timer noise);
* ``serving/degraded_retry_us`` / ``serving/degraded_bisect_us`` —
  degraded-mode latency: a bucket that absorbed transient-fault retries
  with backoff, and a bucket that died and was bisected into solo
  re-runs (`docs/resilience.md` recovery ladders).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import alto, batched, cpals, faults
from repro.core import views as views_mod
from repro.launch.serve_cpd import CpdService
from repro.sparse.synthetic import uniform_tensor


def _tenants(n: int, quick: bool):
    """n tenants over a few pow2 envelopes -> a handful of classes."""
    scale = 1 if quick else 2
    shapes = [(9, 7, 5), (12, 6, 8), (16, 8, 8), (14, 8, 7)]
    rng = np.random.default_rng(0)
    out = []
    for t in range(n):
        dims = tuple(d * scale for d in shapes[t % len(shapes)])
        nnz = int(rng.integers(70, 128)) * scale
        out.append(uniform_tensor(dims, nnz, seed=t))
    return out

def run(quick: bool = False) -> None:
    n_tenants = 8 if quick else 16
    rank, iters = 4, 4
    xs = _tenants(n_tenants, quick)

    sweeps0 = batched.sweep_traces()["als"]
    svc = CpdService(rank, capacity=4, n_iters=iters, tol=0.0,
                     tune="off", backend="reference")
    for i, x in enumerate(xs):
        svc.submit(x, seed=i)
    t0 = time.perf_counter()
    responses = svc.process()
    wall = time.perf_counter() - t0
    stats = svc.stats()
    assert len(responses) == n_tenants

    buckets = stats["buckets_run"]
    traces = batched.sweep_traces()["als"] - sweeps0
    traces_per_bucket = traces / max(1, buckets)
    # The tentpole contract: trace count bounded by bucket count (and by
    # the class count — strictly fewer once any class runs two buckets).
    assert traces <= buckets, (traces, buckets)
    assert traces <= stats["shape_classes"], (traces, stats)

    emit("serving/tenants_per_s", 1e6 / max(stats["tenants_per_s"], 1e-9),
         f"{stats['tenants_per_s']:.2f}/s")
    emit("serving/traces_per_bucket", traces_per_bucket * 1e6,
         f"{traces}tr/{buckets}bk")
    emit("serving/latency_p50", stats["latency_p50_s"] * 1e6,
         f"{n_tenants}tenants")
    emit("serving/latency_p99", stats["latency_p99_s"] * 1e6,
         f"cap{svc.capacity}")
    emit("serving/batched_wall_us_per_tenant", wall * 1e6 / n_tenants,
         f"{stats['shape_classes']}classes")

    # Unbatched baseline: same tenants, one driver call (and one meta ->
    # one compile cascade) each.
    t0 = time.perf_counter()
    for x in xs:
        cpals.cp_als(alto.build(x), rank, n_iters=iters, tol=0.0)
    solo_wall = time.perf_counter() - t0
    emit("serving/solo_us_per_tenant", solo_wall * 1e6 / n_tenants,
         f"speedup={solo_wall / max(wall, 1e-9):.2f}x")

    _guard_overhead(rank, iters, quick)
    _degraded_modes(rank, iters)


def _serve_once(rank, iters, xs, *, guard, armed=None, **svc_kw):
    """One fresh service over ``xs``; returns (wall_s, responses, svc)."""
    svc = CpdService(rank, capacity=4, n_iters=iters, tol=0.0,
                     tune="off", backend="reference", guard=guard,
                     retry_base_s=1e-4, **svc_kw)
    for i, x in enumerate(xs):
        svc.submit(x, seed=i)
    if armed:
        faults.arm(*armed[0], **armed[1])
    t0 = time.perf_counter()
    responses = svc.process()
    wall = time.perf_counter() - t0
    assert all(r.ok for r in responses), [r.error for r in responses]
    return wall, responses, svc


def _guard_overhead(rank, iters, quick):
    """The guard cost bound: one fused jitted all-finite reduction per
    sweep must keep a guarded bucket within 5% of an unguarded one."""
    xs = _tenants(4, quick)
    reps = 5

    def median_wall(guard):
        walls = []
        for _ in range(reps):
            w, _, _ = _serve_once(rank, iters, xs, guard=guard)
            walls.append(w)
        return float(np.median(walls))

    median_wall(False)            # warm both paths' jit caches first
    median_wall(True)
    unguarded = median_wall(False)
    guarded = median_wall(True)
    pct = 100.0 * (guarded - unguarded) / max(unguarded, 1e-9)
    emit("serving/unguarded_us", unguarded * 1e6, f"{reps}reps")
    emit("serving/guarded_us", guarded * 1e6, f"{pct:+.1f}%")
    # 5% relative plus 50ms absolute slack (tiny CPU buckets: timer and
    # scheduler noise would otherwise dominate the relative bound)
    assert guarded <= unguarded * 1.05 + 0.05, (
        f"guard overhead {pct:.1f}% exceeds the 5% budget "
        f"(guarded {guarded*1e3:.1f}ms vs unguarded {unguarded*1e3:.1f}ms)")


def _degraded_modes(rank, iters):
    """Latency of the recovery ladders, as rows next to the happy path."""
    xs = _tenants(4, True)
    faults.reset()

    # transient-fault retry: the view build fails twice, backoff absorbs
    views_mod.cache_clear()
    wall, rs, svc = _serve_once(
        rank, iters, xs, guard=True,
        armed=(("views.build",), {"times": 2}))
    s = svc.stats()
    assert s["retries"] == 2, s
    emit("serving/degraded_retry_us", wall * 1e6,
         f"{s['retries']}retries")

    # bucket bisection: the bucket dies once, every member re-runs solo
    batched.sweep_cache_clear()
    wall, rs, svc = _serve_once(
        rank, iters, xs, guard=True,
        armed=(("batched.sweep",), {"times": 1}))
    assert all(r.bucket_size == 1 for r in rs), "expected solo re-runs"
    emit("serving/degraded_bisect_us", wall * 1e6,
         f"{len(rs)}solos")
    faults.reset()
